"""Table 1 reproduction: power + kFPS/W for every Lightator [W:A] variant.

Competitor rows are the published numbers from the paper (constants, marked
"published") — our contribution is the Lightator rows, computed end-to-end
from the OC scheduler + circuit power model on VGG9/CIFAR100 with CA.
"""

from __future__ import annotations

import time

from repro.core.power_model import PowerModel
from repro.core.quant import W4A4, W3A4, W2A4, MX_43, MX_42
from repro.models.vision import vgg9_ir, vision_schedules

PAPER = {   # scheme name -> (paper max power W, paper kFPS/W)
    "Lightator [4:4]": (5.28, 61.61),
    "Lightator [3:4]": (2.71, 117.65),
    "Lightator [2:4]": (1.46, 188.24),
    "Lightator-MX [4:4][3:4]": (3.64, 84.4),
    "Lightator-MX [4:4][2:4]": (1.97, 126.6),
}

PUBLISHED_BASELINES = [
    # name, process nm, max power W, kFPS/W  (Table 1 of the paper)
    ("LightBulb [1:1]", 32, 68.3, 57.75),
    ("HolyLight [4:4]", 32, 66.9, 3.3),
    ("HQNNA", 45, None, 34.6),
    ("Robin [1:4]", 45, 106.0, 46.5),
    ("CrossLight [4:4]", 45, 390.0, 52.59),
]


def run(csv=True):
    scheds = vision_schedules(vgg9_ir(use_ca=True, n_classes=100), 32)
    pm = PowerModel()
    rows = []
    schemes = [("Lightator [4:4]", W4A4), ("Lightator [3:4]", W3A4),
               ("Lightator [2:4]", W2A4),
               ("Lightator-MX [4:4][3:4]", MX_43),
               ("Lightator-MX [4:4][2:4]", MX_42)]
    out_lines = []
    for name, scheme in schemes:
        t0 = time.perf_counter()
        r = pm.model_report(scheds, scheme)
        us = (time.perf_counter() - t0) * 1e6
        p_ref, k_ref = PAPER[name]
        p_err = abs(r.max_power_w - p_ref) / p_ref * 100
        k_err = abs(r.kfps_per_w - k_ref) / k_ref * 100
        rows.append((name, r.max_power_w, r.avg_power_w, r.kfps_per_w,
                     p_ref, k_ref, p_err, k_err))
        out_lines.append(
            f"bench_table1.{name.replace(' ', '_')},{us:.1f},"
            f"max_W={r.max_power_w:.2f};kfpsW={r.kfps_per_w:.1f};"
            f"paper_W={p_ref};paper_kfpsW={k_ref};"
            f"errW%={p_err:.1f};errK%={k_err:.1f}")
    for name, nm, pw, kfps in PUBLISHED_BASELINES:
        out_lines.append(
            f"bench_table1.published.{name.replace(' ', '_')},0.0,"
            f"max_W={pw};kfpsW={kfps};source=paper")
    if csv:
        print("\n".join(out_lines))
    return rows


if __name__ == "__main__":
    run()
