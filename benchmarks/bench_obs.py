"""Observability overhead benchmark: the disabled path must be free.

``repro.obs`` is on in every hot path of the runtime — ``span()`` /
``event()`` calls sit inside the serving scheduler, the plan compile
pass and the executor — so the whole design rests on the disabled path
costing nothing. This benchmark pins that claim into
``BENCH_obs.json``:

* **frame_us_raw** — the 3-stage imaging chain (denoise_gauss ->
  edge_detect -> sharpen, compiled as ONE program via ``Program.then``)
  executed by calling the plan's jitted executor directly: no host
  wrapper at all, the floor.
* **frame_us_disabled** — the same executor through
  ``Executable.run_per_frame`` with tracing off: the production path,
  obs no-op checks included. ``overhead_disabled_pct`` is the gated
  number — ``scripts/check_bench.py`` fails if it exceeds 2%.
* **frame_us_traced** — same with a collector installed
  (``overhead_traced_pct`` is recorded for the docs, not gated: tracing
  is opt-in).
* **noop_span_ns / noop_event_ns** — the microcosts: one disabled
  ``obs.span()`` / ``obs.event()`` call.

All timings are best-of-``REPEATS`` medians (CPU CI is noisy; the min
over repeats is the classic de-noiser). Run:
``PYTHONPATH=src python -m benchmarks.bench_obs``.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import repro
from repro import obs

SCHEMA_VERSION = 1
OUT_PATH = Path(__file__).resolve().parent / "BENCH_obs.json"
BATCH = 8
HW = 32
REPEATS = 5
ITERS = 30
NOOP_ITERS = 200_000


def _chain() -> repro.Program:
    a = repro.Program.from_pipeline("denoise_gauss", HW, HW, 3)
    b = repro.Program.from_pipeline("edge_detect", *a.output_hwc)
    c = repro.Program.from_pipeline("sharpen", *b.output_hwc)
    return a.then(b).then(c)


def _best_us_per_frame(fn, frames) -> float:
    """min over REPEATS of (ITERS-loop mean) — us per frame."""
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            np.asarray(fn(frames))
        dt = time.perf_counter() - t0
        best = min(best, dt / (ITERS * frames.shape[0]) * 1e6)
    return best


def _noop_ns(fn) -> float:
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter_ns()
        for _ in range(NOOP_ITERS):
            fn()
        best = min(best, (time.perf_counter_ns() - t0) / NOOP_ITERS)
    return best


def run() -> dict:
    assert obs.get_trace() is None, "bench_obs must start untraced"
    prog = _chain()
    exe = prog.compile(repro.Options(backend="reference"))
    rng = np.random.default_rng(0)
    frames = rng.random((BATCH, HW, HW, 3)).astype(np.float32)

    # the floor: the jitted executor itself, no host wrapper
    executor = exe.plan.executor(per_frame=True)
    params, consts = prog.params, exe.plan.consts
    raw = lambda f: executor(params, f, consts)
    np.asarray(raw(frames))                      # warm the trace
    np.asarray(exe.run_per_frame(frames))
    frame_us_raw = _best_us_per_frame(raw, frames)

    # production path, tracing disabled (the gated number)
    frame_us_disabled = _best_us_per_frame(exe.run_per_frame, frames)

    # same with a live collector
    trace = obs.enable()
    np.asarray(exe.run_per_frame(frames))
    frame_us_traced = _best_us_per_frame(exe.run_per_frame, frames)
    obs.disable()
    traced_spans = len(trace.records())

    with obs.use_mode("off"):
        noop_span_ns = _noop_ns(lambda: obs.span("bench.noop"))
        noop_event_ns = _noop_ns(lambda: obs.event("bench.noop"))

    data = {
        "schema_version": SCHEMA_VERSION,
        "chain": {
            "name": prog.name, "hw": HW, "batch": BATCH,
            "frame_us_raw": frame_us_raw,
            "frame_us_disabled": frame_us_disabled,
            "frame_us_traced": frame_us_traced,
            "overhead_disabled_pct":
                (frame_us_disabled / frame_us_raw - 1.0) * 100.0,
            "overhead_traced_pct":
                (frame_us_traced / frame_us_raw - 1.0) * 100.0,
            "traced_records": traced_spans,
        },
        "noop": {
            "span_ns": noop_span_ns,
            "event_ns": noop_event_ns,
        },
    }
    OUT_PATH.write_text(json.dumps(data, indent=2) + "\n")
    c = data["chain"]
    print(f"bench_obs,{c['frame_us_disabled']:.1f},"
          f"overhead_disabled={c['overhead_disabled_pct']:+.2f}% "
          f"traced={c['overhead_traced_pct']:+.2f}% "
          f"noop_span={noop_span_ns:.0f}ns")
    return data


if __name__ == "__main__":
    run()
