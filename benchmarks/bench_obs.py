"""Observability overhead benchmark: the disabled path must be free.

``repro.obs`` is on in every hot path of the runtime — ``span()`` /
``event()`` calls sit inside the serving scheduler, the plan compile
pass and the executor — so the whole design rests on the disabled path
costing nothing. This benchmark pins that claim into
``BENCH_obs.json``:

* **frame_us_raw** — the 3-stage imaging chain (denoise_gauss ->
  edge_detect -> sharpen, compiled as ONE program via ``Program.then``)
  executed by calling the plan's jitted executor directly: no host
  wrapper at all, the floor.
* **frame_us_disabled** — the same executor through
  ``Executable.run_per_frame`` with tracing off: the production path,
  obs no-op checks included. ``overhead_disabled_pct`` is the gated
  number — ``scripts/check_bench.py`` fails if it exceeds 2%.
* **frame_us_traced** — same with a collector installed
  (``overhead_traced_pct`` is recorded for the docs, not gated: tracing
  is opt-in).
* **noop_span_ns / noop_event_ns** — the microcosts: one disabled
  ``obs.span()`` / ``obs.event()`` call.

Schema v2 adds the **flight** section, gating the always-on flight
recorder the same way (``check_bench.py`` fails above 5%): the 3-stage
chain served through a real ``serve.Server`` under closed-loop
saturation with the recorder uninstalled (``fps_flight_off``) vs
installed (``fps_flight_on``) — the recorder sits on every serving
span, so this is its end-to-end cost, not a microbenchmark — plus the
microcosts ``record_ns`` (one ring write) and ``dump_ms`` (serializing
a full default-capacity dump).

All timings are best-of-``REPEATS`` medians (CPU CI is noisy; the min
over repeats is the classic de-noiser). Run:
``PYTHONPATH=src python -m benchmarks.bench_obs``.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import repro
from repro import obs, serve

SCHEMA_VERSION = 2
OUT_PATH = Path(__file__).resolve().parent / "BENCH_obs.json"
BATCH = 8
HW = 32
REPEATS = 5
PAIR_REPEATS = 10
ITERS = 30
NOOP_ITERS = 200_000
RECORD_ITERS = 50_000
SERVE_REPEATS = 5
SERVE_REQUESTS = 24 * BATCH


def _chain() -> repro.Program:
    a = repro.Program.from_pipeline("denoise_gauss", HW, HW, 3)
    b = repro.Program.from_pipeline("edge_detect", *a.output_hwc)
    c = repro.Program.from_pipeline("sharpen", *b.output_hwc)
    return a.then(b).then(c)


def _one_us_per_frame(fn, frames) -> float:
    """One ITERS-loop mean — us per frame."""
    t0 = time.perf_counter()
    for _ in range(ITERS):
        np.asarray(fn(frames))
    dt = time.perf_counter() - t0
    return dt / (ITERS * frames.shape[0]) * 1e6


def _paired_us(fns, frames) -> list:
    """PAIR_REPEATS rows of per-fn timings, the fns back-to-back inside
    each repeat: gated ratios are then taken *within* a row (adjacent
    in time), and the min-over-rows ratio is the row least contaminated
    by the box's multi-second load drift — which dwarfs the sub-5%
    overheads being measured on a 1-core CI VM. (Finer interleaving
    makes it *worse*: alternating call paths every few iterations
    thrashes the dispatch caches both paths share.)"""
    return [[_one_us_per_frame(fn, frames) for fn in fns]
            for _ in range(PAIR_REPEATS)]


def _min_ratio_pct(rows) -> float:
    """min over rows of (b/a - 1) as a percent (rows of [a, b])."""
    return min(b / a for a, b in rows) * 100.0 - 100.0


def _noop_ns(fn) -> float:
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter_ns()
        for _ in range(NOOP_ITERS):
            fn()
        best = min(best, (time.perf_counter_ns() - t0) / NOOP_ITERS)
    return best


def _one_serving_fps(prog, options, frames) -> float:
    """One closed-loop saturation run of the chain through a Server."""
    server = serve.Server(serve.ServeConfig(max_batch=BATCH,
                                            max_wait_ms=1.0))
    server.register(prog.name, prog, options)
    server.start(warm=True)
    rep = serve.saturate(server, prog.name, frames,
                         n_requests=SERVE_REQUESTS)
    server.stop()
    return rep.achieved_fps


def run() -> dict:
    assert obs.get_trace() is None, "bench_obs must start untraced"
    # the flight recorder is installed by default at import: take it out
    # so the v1 sections keep measuring pure-tracing costs (the 2% gate
    # on the disabled path predates the recorder), restore it after
    prev_flight = obs.uninstall()
    prog = _chain()
    exe = prog.compile(repro.Options(backend="reference"))
    rng = np.random.default_rng(0)
    frames = rng.random((BATCH, HW, HW, 3)).astype(np.float32)

    # the floor: the jitted executor itself, no host wrapper
    executor = exe.plan.executor(per_frame=True)
    params, consts = prog.params, exe.plan.consts
    raw = lambda f: executor(params, f, consts)
    np.asarray(raw(frames))                      # warm the trace
    np.asarray(exe.run_per_frame(frames))

    # the floor vs the production path (the gated ratio), paired
    pairs = _paired_us([raw, exe.run_per_frame], frames)
    frame_us_raw = min(p[0] for p in pairs)
    frame_us_disabled = min(p[1] for p in pairs)
    overhead_disabled_pct = _min_ratio_pct(pairs)

    # same with a live collector
    trace = obs.enable()
    np.asarray(exe.run_per_frame(frames))
    frame_us_traced = min(_one_us_per_frame(exe.run_per_frame, frames)
                          for _ in range(REPEATS))
    obs.disable()
    traced_spans = len(trace.records())

    with obs.use_mode("off"):
        noop_span_ns = _noop_ns(lambda: obs.span("bench.noop"))
        noop_event_ns = _noop_ns(lambda: obs.event("bench.noop"))

    # --- flight recorder (schema v2): end-to-end serving overhead ---
    # off/on interleaved per repeat, same drift-cancelling schedule
    options = repro.Options(backend="reference")
    recorder = prev_flight if prev_flight is not None \
        else obs.FlightRecorder()
    serve_pairs = []
    try:
        _one_serving_fps(prog, options, frames)      # warm the server path
        for _ in range(SERVE_REPEATS):
            obs.uninstall()
            off = _one_serving_fps(prog, options, frames)
            obs.install(recorder)
            on = _one_serving_fps(prog, options, frames)
            serve_pairs.append((off, on))
        fps_flight_off = max(p[0] for p in serve_pairs)
        fps_flight_on = max(p[1] for p in serve_pairs)
        # overhead from the least drift-contaminated adjacent pair
        flight_overhead_pct = min(off / on for off, on in serve_pairs) \
            * 100.0 - 100.0
        # one ring write: an instant record with tracing off but the
        # recorder installed (the serving hot path's flight cost)
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter_ns()
            for _ in range(RECORD_ITERS):
                obs.event("bench.flight")
            best = min(best, (time.perf_counter_ns() - t0) / RECORD_ITERS)
        record_ns = best
        t0 = time.perf_counter()
        dump = recorder.dump(reason="bench")
        dump_ms = (time.perf_counter() - t0) * 1e3
        dump_records = dump["otherData"]["records"]
    finally:
        if prev_flight is None:
            obs.uninstall()
        else:
            obs.install(prev_flight)

    data = {
        "schema_version": SCHEMA_VERSION,
        "chain": {
            "name": prog.name, "hw": HW, "batch": BATCH,
            "frame_us_raw": frame_us_raw,
            "frame_us_disabled": frame_us_disabled,
            "frame_us_traced": frame_us_traced,
            "overhead_disabled_pct": overhead_disabled_pct,
            "overhead_traced_pct":
                (frame_us_traced / frame_us_raw - 1.0) * 100.0,
            "traced_records": traced_spans,
        },
        "noop": {
            "span_ns": noop_span_ns,
            "event_ns": noop_event_ns,
        },
        "flight": {
            "n_requests": SERVE_REQUESTS,
            "fps_flight_off": fps_flight_off,
            "fps_flight_on": fps_flight_on,
            "overhead_pct": flight_overhead_pct,
            "record_ns": record_ns,
            "dump_ms": dump_ms,
            "dump_records": dump_records,
        },
    }
    OUT_PATH.write_text(json.dumps(data, indent=2) + "\n")
    c, fl = data["chain"], data["flight"]
    print(f"bench_obs,{c['frame_us_disabled']:.1f},"
          f"overhead_disabled={c['overhead_disabled_pct']:+.2f}% "
          f"traced={c['overhead_traced_pct']:+.2f}% "
          f"noop_span={noop_span_ns:.0f}ns")
    print(f"bench_obs.flight,{fl['fps_flight_on']:.0f}fps,"
          f"overhead={fl['overhead_pct']:+.2f}% "
          f"record={fl['record_ns']:.0f}ns dump={fl['dump_ms']:.1f}ms")
    return data


if __name__ == "__main__":
    run()
