"""Fig. 10 reproduction: execution time, AlexNet + VGG16 on Lightator.

The electronic baselines (Eyeriss/YodaNN/AppCip/ENVISION) are represented by
the paper's published speedup factors (we have no RTL for them); our numbers
are the Lightator execution times computed from the OC schedule, and the
derived baseline times they imply.
"""

from __future__ import annotations

import time

from repro.core.power_model import PowerModel
from repro.core.quant import W4A4
from repro.models.vision import alexnet_ir, vgg16_ir, vision_schedules

PAPER_SPEEDUPS_ALEXNET = {"Eyeriss": 10.7, "YodaNN": 20.4, "AppCip": 18.1,
                          "ENVISION": 8.8}


def run(csv=True):
    pm = PowerModel()
    out = []
    results = {}
    for name, ir, hw in (("alexnet", alexnet_ir(), 227),
                         ("vgg16", vgg16_ir(), 224)):
        t0 = time.perf_counter()
        scheds = vision_schedules(ir, hw)
        r = pm.model_report(scheds, W4A4)
        us = (time.perf_counter() - t0) * 1e6
        results[name] = r
        total_cycles = sum(l.cycles + l.remap_cycles for l in r.layers)
        out.append(f"bench_fig10.lightator.{name},{us:.1f},"
                   f"exec_ms={r.exec_time_s*1e3:.3f};cycles={total_cycles};"
                   f"fps={r.fps:.0f}")
    for base, ratio in PAPER_SPEEDUPS_ALEXNET.items():
        t = results["alexnet"].exec_time_s * ratio
        out.append(f"bench_fig10.derived.{base},0.0,"
                   f"alexnet_exec_ms={t*1e3:.3f};paper_speedup={ratio}x")
    if csv:
        print("\n".join(out))
    return results


if __name__ == "__main__":
    run()
