"""Fig. 8 reproduction: LeNet layer-wise power breakdown, [4:4]/[3:4]/[2:4].

Checks the two claims carried by the figure: (i) power is dominated by the
weight-tuning DACs in every layer, (ii) dropping weight bits power-gates DAC
slices for ~2.4x average power efficiency.
"""

from __future__ import annotations

import time

from repro.core.power_model import PowerModel
from repro.core.quant import W4A4, W3A4, W2A4
from repro.models.vision import lenet_ir, vision_schedules


def run(csv=True):
    scheds = vision_schedules(lenet_ir(), 28)
    pm = PowerModel()
    out = []
    reports = {}
    for scheme, nm in ((W4A4, "4:4"), (W3A4, "3:4"), (W2A4, "2:4")):
        t0 = time.perf_counter()
        r = pm.model_report(scheds, scheme)
        us = (time.perf_counter() - t0) * 1e6
        reports[nm] = r
        for lp in r.layers:
            bd = ";".join(f"{k}={v*1e3:.2f}mW" for k, v in
                          lp.breakdown_w.items() if v > 0)
            out.append(f"bench_fig8.[{nm}].{lp.name},{us:.1f},"
                       f"total_W={lp.total_w:.3f};{bd}")
    eff = reports["4:4"].avg_power_w / reports["3:4"].avg_power_w
    eff2 = reports["3:4"].avg_power_w / reports["2:4"].avg_power_w
    out.append(f"bench_fig8.bit_drop_efficiency,0.0,"
               f"4to3={eff:.2f}x;3to2={eff2:.2f}x;paper_avg=2.4x")
    if csv:
        print("\n".join(out))
    return reports


if __name__ == "__main__":
    run()
