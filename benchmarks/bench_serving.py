"""Serving-runtime benchmark: latency/throughput under offered load.

Three measurements into ``BENCH_serving.json`` (all on the deterministic
``reference`` backend so the numbers are comparable across machines):

* **capacity** — the service ceiling: closed-loop saturation (every
  submit under backpressure, server permanently backlogged) through the
  full micro-batching scheduler, per program.
* **offered-load sweep** — open-loop Poisson arrivals at fractions of
  that capacity; per point: p50/p95/p99 client-side latency, achieved
  request rate, sheds/rejections, padding waste. The latency curve's
  knee as offered load crosses capacity is the serving story.
* **batch-bucket ablation** — the acceptance gate: the same saturating
  workload served request-at-a-time (``max_batch=1``, buckets ``(1,)``)
  vs micro-batched; micro-batching must sustain >= 2x the frames/s.

Run: ``PYTHONPATH=src python -m benchmarks.bench_serving [--quick]``.
"""

from __future__ import annotations

import dataclasses
import json
import sys
from pathlib import Path

import jax
import numpy as np

import repro
from repro import serve
from repro.core.quant import W4A4

SCHEMA_VERSION = 1
OUT_PATH = Path(__file__).resolve().parent / "BENCH_serving.json"
LOAD_FRACTIONS = (0.25, 0.5, 1.0, 1.5)
PROGRAMS = ("lenet", "edge_detect")


def _program(name: str) -> repro.Program:
    if name == "lenet":
        return repro.Program.from_model("lenet",
                                        key=jax.random.PRNGKey(0))
    return repro.Program.from_pipeline(name, 32, 32, 3)


def _pool(prog: repro.Program, n: int = 32, seed: int = 0) -> np.ndarray:
    h, w, c = prog.input_hwc
    rng = np.random.default_rng(seed)
    return rng.random((n, h, w, c)).astype(np.float32)


def _server(progs, max_batch: int, buckets=None,
            max_wait_ms: float = 2.0) -> serve.Server:
    srv = serve.Server(serve.ServeConfig(
        max_batch=max_batch, max_wait_ms=max_wait_ms,
        max_queue=max(16 * max_batch, 128)))
    options = repro.Options(scheme=W4A4, backend="reference")
    for name, prog in progs.items():
        srv.register(name, prog, options, buckets=buckets)
    return srv.start(warm=True)


def run(csv: bool = True, quick: bool = False,
        max_batch: int = 16, n_requests: int = 300):
    if quick:
        n_requests = 80
    progs = {name: _program(name) for name in PROGRAMS}
    pools = {name: _pool(prog) for name, prog in progs.items()}
    out_lines = []

    # -- capacity: closed-loop saturation through the micro-batcher --------
    capacity = {}
    for name in PROGRAMS:
        srv = _server({name: progs[name]}, max_batch)
        # best of two: the first saturation still pays residual process
        # warm-up (allocator growth, first host->device copies), which
        # would understate the capacity the sweep loads are scaled from
        fps = max(
            serve.saturate(srv, name, pools[name],
                           n_requests=n_requests).achieved_fps
            for _ in range(2))
        srv.stop()
        capacity[name] = fps
        out_lines.append(
            f"bench_serving.capacity.{name},{1e6 / fps:.0f},fps={fps:.0f}")

    # -- offered-load sweep (Poisson, open loop) on the primary program ----
    primary = PROGRAMS[0]
    sweep = []
    for frac in LOAD_FRACTIONS:
        rate = frac * capacity[primary]
        srv = _server({primary: progs[primary]}, max_batch)
        rep = serve.poisson_load(srv, primary, pools[primary],
                                 rate_rps=rate, n_requests=n_requests,
                                 seed=7)
        snap = srv.stats()["programs"][primary]
        srv.stop()
        point = dataclasses.asdict(rep)
        point["load_fraction"] = frac
        point["padding_waste"] = snap["padding_waste"]
        point["avg_batch"] = snap["avg_batch"]
        sweep.append(point)
        lat = rep.latency_ms
        out_lines.append(
            f"bench_serving.sweep.{primary}.x{frac:g},"
            f"{lat.get('p50', 0) * 1e3:.0f},"
            f"offered={rate:.0f}rps;achieved={rep.achieved_rps:.0f}rps;"
            f"p50={lat.get('p50', 0):.2f}ms;p95={lat.get('p95', 0):.2f}ms;"
            f"p99={lat.get('p99', 0):.2f}ms;shed={rep.shed};"
            f"rejected={rep.rejected};avg_batch={snap['avg_batch']:.1f}")

    # -- ablation: request-at-a-time vs micro-batched at saturation --------
    srv1 = _server({primary: progs[primary]}, max_batch=1, buckets=(1,))
    rep1 = serve.saturate(srv1, primary, pools[primary],
                          n_requests=n_requests)
    srv1.stop()
    srvN = _server({primary: progs[primary]}, max_batch)
    repN = serve.saturate(srvN, primary, pools[primary],
                          n_requests=n_requests)
    snapN = srvN.stats()["programs"][primary]
    srvN.stop()
    speedup = repN.achieved_fps / max(rep1.achieved_fps, 1e-9)
    ablation = {
        "program": primary,
        "batch1_fps": rep1.achieved_fps,
        "microbatch_fps": repN.achieved_fps,
        "max_batch": max_batch,
        "avg_batch": snapN["avg_batch"],
        "speedup": speedup,
    }
    out_lines.append(
        f"bench_serving.ablation.{primary},"
        f"{1e6 / repN.achieved_fps:.0f},"
        f"batch1_fps={rep1.achieved_fps:.0f};"
        f"microbatch_fps={repN.achieved_fps:.0f};speedup={speedup:.2f}x")

    payload = {
        "schema_version": SCHEMA_VERSION,
        "backend": "reference",
        "host": jax.default_backend(),
        "max_batch": max_batch,
        "n_requests": n_requests,
        "capacity_fps": capacity,
        "sweep": sweep,
        "ablation": ablation,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if csv:
        print("\n".join(out_lines))
        print(f"bench_serving.json,0.0,path={OUT_PATH}")
    return payload


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
