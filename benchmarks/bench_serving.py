"""Serving-runtime benchmark: latency/throughput under offered load.

Three measurements into ``BENCH_serving.json`` (all on the deterministic
``reference`` backend so the numbers are comparable across machines):

* **capacity** — the service ceiling: closed-loop saturation (every
  submit under backpressure, server permanently backlogged) through the
  full micro-batching scheduler, per program.
* **offered-load sweep** — open-loop Poisson arrivals at fractions of
  that capacity; per point: p50/p95/p99 client-side latency, achieved
  request rate, sheds/rejections, padding waste. The latency curve's
  knee as offered load crosses capacity is the serving story.
* **batch-bucket ablation** — the acceptance gate: the same saturating
  workload served request-at-a-time (``max_batch=1``, buckets ``(1,)``)
  vs micro-batched; micro-batching must sustain >= 2x the frames/s.
* **device-pool ablation** (schema v2) — the same saturating workload
  through a ``devices=1`` vs ``devices=4`` server, run in a subprocess
  with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
  device count is fixed at jax init). The **gated** number uses an
  *emulated* device: the ``Hooks.execute`` seam replaces the XLA call
  with a GIL-releasing sleep proportional to the padded bucket, so the
  measurement isolates the host runtime's ability to keep N devices
  fed — which is the thing the pool exists to prove, and the honest
  analogue of the paper's optical device computing off-host. (Real-XLA
  virtual devices share this machine's CPU core, so their scaling is
  reported alongside but not gated — a 1-core host cannot physically
  run 4 compute-bound XLA programs faster than 1.)

Run: ``PYTHONPATH=src python -m benchmarks.bench_serving [--quick]``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np

import repro
from repro import serve
from repro.core.quant import W4A4

SCHEMA_VERSION = 2
OUT_PATH = Path(__file__).resolve().parent / "BENCH_serving.json"
ROOT = Path(__file__).resolve().parent.parent
LOAD_FRACTIONS = (0.25, 0.5, 1.0, 1.5)
PROGRAMS = ("lenet", "edge_detect")
POOL_DEVICES = 4
POOL_PER_FRAME_MS = 2.0   # emulated device service time per batch slot


def _program(name: str) -> repro.Program:
    if name == "lenet":
        return repro.Program.from_model("lenet",
                                        key=jax.random.PRNGKey(0))
    return repro.Program.from_pipeline(name, 32, 32, 3)


def _pool(prog: repro.Program, n: int = 32, seed: int = 0) -> np.ndarray:
    h, w, c = prog.input_hwc
    rng = np.random.default_rng(seed)
    return rng.random((n, h, w, c)).astype(np.float32)


def _server(progs, max_batch: int, buckets=None,
            max_wait_ms: float = 2.0) -> serve.Server:
    srv = serve.Server(serve.ServeConfig(
        max_batch=max_batch, max_wait_ms=max_wait_ms,
        max_queue=max(16 * max_batch, 128)))
    options = repro.Options(scheme=W4A4, backend="reference")
    for name, prog in progs.items():
        srv.register(name, prog, options, buckets=buckets)
    return srv.start(warm=True)


def _pool_child(quick: bool = False) -> None:
    """Subprocess body for the device-pool ablation: measures devices=1
    vs devices=4 capacity and prints one JSON line. Run via
    ``--pool-child`` under ``--xla_force_host_platform_device_count=4``.
    """
    n_requests = 96 if quick else 240
    if len(jax.local_devices()) < POOL_DEVICES:
        print(json.dumps({"skipped": f"only {len(jax.local_devices())} "
                                     f"local device(s)"}))
        return
    prog = _program("lenet")
    frames = _pool(prog)
    options = repro.Options(scheme=W4A4, backend="reference")
    per_frame_s = POOL_PER_FRAME_MS / 1e3

    def emulated(program, device, frames_, bucket, default):
        # stand-in device: sleeps (GIL-free) for the padded batch's
        # service time, so N workers genuinely overlap — measures the
        # host runtime, not this machine's core count
        time.sleep(per_frame_s * bucket)
        return np.zeros((frames_.shape[0], 8), np.float32)

    def capacity(ndev, hooks=None, warm=False):
        srv = serve.Server(serve.ServeConfig(
            max_batch=4, max_wait_ms=1.0, max_queue=128, devices=ndev),
            hooks=hooks)
        srv.register("lenet", prog, options)
        srv.start(warm=warm)
        fps = serve.saturate(srv, "lenet", frames,
                             n_requests=n_requests).achieved_fps
        pool_stats = srv.stats()["pool"]
        srv.stop()
        return fps, pool_stats

    hooks = serve.Hooks(execute=emulated)
    em1, _ = capacity(1, hooks)
    em4, st4 = capacity(POOL_DEVICES, hooks)
    x1, _ = capacity(1, warm=True)
    x4, _ = capacity(POOL_DEVICES, warm=True)
    print(json.dumps({
        "devices": POOL_DEVICES,
        "n_requests": n_requests,
        "per_frame_ms": POOL_PER_FRAME_MS,
        "emulated": {"pool1_fps": em1, "pool4_fps": em4,
                     "speedup": em4 / max(em1, 1e-9),
                     "steals": st4["steals"]},
        "xla": {"pool1_fps": x1, "pool4_fps": x4,
                "speedup": x4 / max(x1, 1e-9),
                "host_cores": os.cpu_count()},
    }))


def _pool_ablation(quick: bool = False) -> dict:
    """Run :func:`_pool_child` in a 4-virtual-device subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{POOL_DEVICES}").strip()
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep +
                         env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_serving", "--pool-child"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=ROOT, timeout=900)
    lines = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    if proc.returncode != 0 or not lines:
        return {"skipped": f"pool child failed (rc={proc.returncode}): "
                           f"{proc.stderr.strip()[-500:]}"}
    return json.loads(lines[-1])


def run(csv: bool = True, quick: bool = False,
        max_batch: int = 16, n_requests: int = 300):
    if quick:
        n_requests = 80
    progs = {name: _program(name) for name in PROGRAMS}
    pools = {name: _pool(prog) for name, prog in progs.items()}
    out_lines = []

    # -- capacity: closed-loop saturation through the micro-batcher --------
    capacity = {}
    for name in PROGRAMS:
        srv = _server({name: progs[name]}, max_batch)
        # best of two: the first saturation still pays residual process
        # warm-up (allocator growth, first host->device copies), which
        # would understate the capacity the sweep loads are scaled from
        fps = max(
            serve.saturate(srv, name, pools[name],
                           n_requests=n_requests).achieved_fps
            for _ in range(2))
        srv.stop()
        capacity[name] = fps
        out_lines.append(
            f"bench_serving.capacity.{name},{1e6 / fps:.0f},fps={fps:.0f}")

    # -- offered-load sweep (Poisson, open loop) on the primary program ----
    primary = PROGRAMS[0]
    sweep = []
    for frac in LOAD_FRACTIONS:
        rate = frac * capacity[primary]
        srv = _server({primary: progs[primary]}, max_batch)
        rep = serve.poisson_load(srv, primary, pools[primary],
                                 rate_rps=rate, n_requests=n_requests,
                                 seed=7)
        snap = srv.stats()["programs"][primary]
        srv.stop()
        point = dataclasses.asdict(rep)
        point["load_fraction"] = frac
        point["padding_waste"] = snap["padding_waste"]
        point["avg_batch"] = snap["avg_batch"]
        sweep.append(point)
        lat = rep.latency_ms
        out_lines.append(
            f"bench_serving.sweep.{primary}.x{frac:g},"
            f"{lat.get('p50', 0) * 1e3:.0f},"
            f"offered={rate:.0f}rps;achieved={rep.achieved_rps:.0f}rps;"
            f"p50={lat.get('p50', 0):.2f}ms;p95={lat.get('p95', 0):.2f}ms;"
            f"p99={lat.get('p99', 0):.2f}ms;shed={rep.shed};"
            f"rejected={rep.rejected};avg_batch={snap['avg_batch']:.1f}")

    # -- ablation: request-at-a-time vs micro-batched at saturation --------
    srv1 = _server({primary: progs[primary]}, max_batch=1, buckets=(1,))
    rep1 = serve.saturate(srv1, primary, pools[primary],
                          n_requests=n_requests)
    srv1.stop()
    srvN = _server({primary: progs[primary]}, max_batch)
    repN = serve.saturate(srvN, primary, pools[primary],
                          n_requests=n_requests)
    snapN = srvN.stats()["programs"][primary]
    srvN.stop()
    speedup = repN.achieved_fps / max(rep1.achieved_fps, 1e-9)
    ablation = {
        "program": primary,
        "batch1_fps": rep1.achieved_fps,
        "microbatch_fps": repN.achieved_fps,
        "max_batch": max_batch,
        "avg_batch": snapN["avg_batch"],
        "speedup": speedup,
    }
    out_lines.append(
        f"bench_serving.ablation.{primary},"
        f"{1e6 / repN.achieved_fps:.0f},"
        f"batch1_fps={rep1.achieved_fps:.0f};"
        f"microbatch_fps={repN.achieved_fps:.0f};speedup={speedup:.2f}x")

    # -- device-pool ablation (4 virtual devices, subprocess) --------------
    pool_abl = _pool_ablation(quick)
    if "skipped" in pool_abl:
        out_lines.append(f"bench_serving.pool_ablation,0,"
                         f"skipped={pool_abl['skipped'][:80]}")
    else:
        em = pool_abl["emulated"]
        out_lines.append(
            f"bench_serving.pool_ablation.emulated,"
            f"{1e6 / max(em['pool4_fps'], 1e-9):.0f},"
            f"pool1_fps={em['pool1_fps']:.0f};"
            f"pool4_fps={em['pool4_fps']:.0f};"
            f"speedup={em['speedup']:.2f}x;steals={em['steals']}")
        xl = pool_abl["xla"]
        out_lines.append(
            f"bench_serving.pool_ablation.xla,"
            f"{1e6 / max(xl['pool4_fps'], 1e-9):.0f},"
            f"pool1_fps={xl['pool1_fps']:.0f};"
            f"pool4_fps={xl['pool4_fps']:.0f};"
            f"speedup={xl['speedup']:.2f}x;"
            f"host_cores={xl['host_cores']} (ungated)")

    payload = {
        "schema_version": SCHEMA_VERSION,
        "backend": "reference",
        "host": jax.default_backend(),
        "max_batch": max_batch,
        "n_requests": n_requests,
        "capacity_fps": capacity,
        "sweep": sweep,
        "ablation": ablation,
        "pool_ablation": pool_abl,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if csv:
        print("\n".join(out_lines))
        print(f"bench_serving.json,0.0,path={OUT_PATH}")
    return payload


if __name__ == "__main__":
    if "--pool-child" in sys.argv:
        _pool_child(quick="--quick" in sys.argv)
    else:
        run(quick="--quick" in sys.argv)
