# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   bench_table1    Table 1 (power + kFPS/W per [W:A] + published baselines)
#   bench_fig8      Fig. 8  (LeNet layer-wise power breakdown)
#   bench_fig9      Fig. 9  (VGG9 breakdown, DAC share, CA L1 reduction)
#   bench_fig10     Fig. 10 (execution time, AlexNet/VGG16)
#   bench_accuracy  Table 1 accuracy axis (QAT trend on synthetic digits)
#   bench_kernels   Pallas kernels vs oracles
#   bench_pipeline  eager vs compiled device pipeline frames/s (core.plan)
#   bench_imaging   imaging pipelines frames/s + PSNR/SSIM per scheme
#   bench_serving   serving runtime: offered-load sweep + batching ablation
#   bench_obs       observability overhead: disabled-path cost vs raw executor
#   bench_analysis  plan-verifier compile overhead + concurrency-lint cost

import os
import sys

# Tuned CPU launch env: silence the XLA/TF C++ banner before jax loads.
# scripts/ci.sh sets the same knob and additionally preloads tcmalloc when
# it is installed (LD_PRELOAD has to be set before the process starts, so
# it cannot be applied from here).
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")


def main() -> None:
    from benchmarks import (bench_analysis, bench_table1, bench_fig8,
                            bench_fig9, bench_fig10, bench_accuracy,
                            bench_kernels, bench_lm_photonic, bench_obs,
                            bench_pipeline, bench_imaging, bench_serving)
    bench_table1.run()
    bench_fig8.run()
    bench_fig9.run()
    bench_fig10.run()
    quick = "--quick" in sys.argv
    bench_accuracy.run(steps=30 if quick else 40)
    bench_kernels.run(sizes=(64, 128) if quick
                      else bench_kernels.SWEEP_SIZES)
    bench_lm_photonic.run()
    bench_pipeline.run(batches=(1, 8) if quick else bench_pipeline.BATCHES)
    bench_imaging.run(pipelines=("edge_detect", "compress_recon")
                      if quick else None)
    bench_serving.run(quick=quick)
    bench_obs.run()
    bench_analysis.run()


if __name__ == '__main__':
    main()
