"""Fig. 9 reproduction: VGG9 [3:4] layer-wise breakdown, DAC share, CA gain.

Claims checked: DACs contribute >85% of total power in every layer; the CA
front-end cuts first-layer power (paper: 42.2%; our mechanism gives ~66% —
the CA here removes both the RGB channels AND 3/4 of the positions, see
EXPERIMENTS.md discussion).
"""

from __future__ import annotations

import time

from repro.core.power_model import PowerModel
from repro.core.quant import W3A4
from repro.models.vision import vgg9_ir, vision_schedules


def run(csv=True):
    pm = PowerModel()
    out = []
    t0 = time.perf_counter()
    r_ca = pm.model_report(vision_schedules(vgg9_ir(use_ca=True), 32), W3A4)
    r_no = pm.model_report(vision_schedules(vgg9_ir(use_ca=False), 32), W3A4)
    us = (time.perf_counter() - t0) * 1e6
    for lp in r_ca.layers:
        dac_share = lp.breakdown_w["DAC"] / lp.total_w if lp.total_w else 0
        out.append(f"bench_fig9.layer.{lp.name},{us:.1f},"
                   f"total_W={lp.total_w:.3f};DAC_share={dac_share:.2f}")
    comps = r_ca.component_totals()
    total = sum(comps.values())
    pie = ";".join(f"{k}={v/total*100:.1f}%" for k, v in comps.items())
    out.append(f"bench_fig9.pie,0.0,{pie}")
    l1_ca = next(l for l in r_ca.layers if l.name == "conv1")
    l1_no = next(l for l in r_no.layers if l.name == "conv1")
    red = (1 - l1_ca.total_w / l1_no.total_w) * 100
    out.append(f"bench_fig9.ca_L1_power_reduction,0.0,"
               f"ours={red:.1f}%;paper=42.2%")
    if csv:
        print("\n".join(out))
    return r_ca


if __name__ == "__main__":
    run()
