"""Kernel micro-benchmarks: photonic_mvm / ca_pool / conv_bank vs oracles.

Absolute times on this CPU container are interpret-mode (not TPU) — the
meaningful outputs are correctness deltas and the MAC counts / arithmetic
intensities recorded for the §Perf analysis.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import W4A4
from repro.kernels.ca_pool.ops import ca_pool
from repro.kernels.ca_pool.ref import ca_pool_ref
from repro.kernels.conv_bank.ops import conv_bank
from repro.kernels.conv_bank.ref import conv_bank_quant_ref
from repro.kernels.photonic_mvm.ops import photonic_mvm
from repro.kernels.photonic_mvm.ref import photonic_mvm_ref


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv=True):
    out = []
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)

    # photonic_mvm: a VGG9-fc1-shaped MVM
    x = jax.random.normal(k1, (256, 1024))
    w = jax.random.normal(k2, (1024, 512)) * 0.1
    us_k = _time(lambda a, b: photonic_mvm(a, b, W4A4), x, w)
    us_r = _time(lambda a, b: photonic_mvm_ref(a, b, W4A4), x, w)
    err = float(jnp.max(jnp.abs(photonic_mvm(x, w, W4A4)
                                - photonic_mvm_ref(x, w, W4A4))))
    macs = 256 * 1024 * 512
    out.append(f"bench_kernels.photonic_mvm,{us_k:.1f},"
               f"ref_us={us_r:.1f};macs={macs};err={err:.1e}")

    # ca_pool on a full sensor frame (256x256 RGB, the paper's imager)
    img = jax.random.uniform(k1, (1, 256, 256, 3))
    us_k = _time(lambda i: ca_pool(i, 2), img)
    us_r = _time(lambda i: ca_pool_ref(i, 2), img)
    err = float(jnp.max(jnp.abs(ca_pool(img, 2) - ca_pool_ref(img, 2))))
    out.append(f"bench_kernels.ca_pool,{us_k:.1f},"
               f"ref_us={us_r:.1f};taps={2*2*3};err={err:.1e}")

    # conv_bank 3x3 (the OC's native kernel size)
    xc = jax.random.uniform(k1, (4, 32, 32, 64))
    wc = jax.random.normal(k2, (3, 3, 64, 64)) * 0.1
    us_k = _time(lambda a, b: conv_bank(a, b, W4A4), xc, wc)
    us_r = _time(lambda a, b: conv_bank_quant_ref(a, b, W4A4), xc, wc)
    err = float(jnp.max(jnp.abs(conv_bank(xc, wc, W4A4)
                                - conv_bank_quant_ref(xc, wc, W4A4))))
    macs = 4 * 32 * 32 * 64 * 9 * 64
    out.append(f"bench_kernels.conv_bank3x3,{us_k:.1f},"
               f"ref_us={us_r:.1f};macs={macs};err={err:.1e}")
    if csv:
        print("\n".join(out))
    return out


if __name__ == "__main__":
    run()
