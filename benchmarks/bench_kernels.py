"""Kernel micro-benchmarks + the conv strategy sweep + the fusion ablation.

Three parts:

  * micro — photonic_mvm / ca_pool / conv_bank vs their oracles (correctness
    deltas + MAC counts; absolute CPU times are interpret-mode, not TPU).
  * conv_strategy_sweep — quantized conv at several frame sizes through all
    three execution paths: resident Pallas kernel (whole image in VMEM),
    strip-mined Pallas kernel (halo DMA per strip), and the XLA reference
    oracle. Records per-path microseconds, the strip geometry the
    VMEM-budget heuristic picks, and the max abs error vs the oracle. The
    raw integer accumulates are bit-identical across all three paths (see
    tests/test_kernels_conv_bank.py); the errors here are the dequant
    multiply's float epsilon, identical for resident and strip. The
    depthwise entry compares the strip kernel against the grouped
    per-channel-im2col path it replaces (raw accumulate: err exactly 0).
  * fused_chain — megakernel fusion ablation: the 3-stage imaging chain
    (denoise_gauss -> edge_detect -> sharpen, 4 convs) at 256x256 compiled
    once with ``Options(fuse="on")`` (all four convs execute as one fused
    segment, intermediates never leave the stage loop) and once with
    ``fuse="off"`` (one launch + requant round trip per conv). Records
    per-frame milliseconds for both, the speedup, and asserts the outputs
    are *bitwise* identical — fusion is a pure scheduling change.
    ``scripts/check_bench.py`` gates the speedup ratio in CI.

Writes ``BENCH_kernels.json`` (see docs/benchmarks.md for the schema) next
to this file.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import W4A4
from repro.kernels import dispatch
from repro.kernels.ca_pool.ops import ca_pool
from repro.kernels.ca_pool.ref import ca_pool_ref
from repro.kernels.conv_bank.ops import conv_bank
from repro.kernels.conv_bank.ref import conv_bank_quant_ref
from repro.kernels.photonic_mvm.ops import photonic_mvm
from repro.kernels.photonic_mvm.ref import photonic_mvm_ref

SCHEMA_VERSION = 2
SWEEP_SIZES = (64, 128, 256)
FUSED_CHAIN_HW = 256
SWEEP_CIN, SWEEP_COUT, SWEEP_K = 8, 16, 3
OUT_PATH = Path(__file__).resolve().parent / "BENCH_kernels.json"


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _micro(out, results):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)

    # photonic_mvm: a VGG9-fc1-shaped MVM
    x = jax.random.normal(k1, (256, 1024))
    w = jax.random.normal(k2, (1024, 512)) * 0.1
    us_k = _time(lambda a, b: photonic_mvm(a, b, W4A4), x, w)
    us_r = _time(lambda a, b: photonic_mvm_ref(a, b, W4A4), x, w)
    err = float(jnp.max(jnp.abs(photonic_mvm(x, w, W4A4)
                                - photonic_mvm_ref(x, w, W4A4))))
    macs = 256 * 1024 * 512
    results["photonic_mvm"] = {"kernel_us": us_k, "ref_us": us_r,
                               "macs": macs, "max_abs_err": err}
    out.append(f"bench_kernels.photonic_mvm,{us_k:.1f},"
               f"ref_us={us_r:.1f};macs={macs};err={err:.1e}")

    # ca_pool on a full sensor frame (256x256 RGB, the paper's imager)
    img = jax.random.uniform(k1, (1, 256, 256, 3))
    us_k = _time(lambda i: ca_pool(i, 2), img)
    us_r = _time(lambda i: ca_pool_ref(i, 2), img)
    err = float(jnp.max(jnp.abs(ca_pool(img, 2) - ca_pool_ref(img, 2))))
    results["ca_pool"] = {"kernel_us": us_k, "ref_us": us_r,
                          "taps": 2 * 2 * 3, "max_abs_err": err}
    out.append(f"bench_kernels.ca_pool,{us_k:.1f},"
               f"ref_us={us_r:.1f};taps={2*2*3};err={err:.1e}")

    # conv_bank 3x3 (the OC's native kernel size), resident path
    xc = jax.random.uniform(k1, (4, 32, 32, 64))
    wc = jax.random.normal(k2, (3, 3, 64, 64)) * 0.1
    us_k = _time(lambda a, b: conv_bank(a, b, W4A4), xc, wc)
    us_r = _time(lambda a, b: conv_bank_quant_ref(a, b, W4A4), xc, wc)
    err = float(jnp.max(jnp.abs(conv_bank(xc, wc, W4A4)
                                - conv_bank_quant_ref(xc, wc, W4A4))))
    macs = 4 * 32 * 32 * 64 * 9 * 64
    results["conv_bank3x3"] = {"kernel_us": us_k, "ref_us": us_r,
                               "macs": macs, "max_abs_err": err}
    out.append(f"bench_kernels.conv_bank3x3,{us_k:.1f},"
               f"ref_us={us_r:.1f};macs={macs};err={err:.1e}")


def _conv_sweep(out, results, sizes):
    """Quantized conv, resident vs strip-mined vs reference, per frame size."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    w = jax.random.normal(k2, (SWEEP_K, SWEEP_K, SWEEP_CIN, SWEEP_COUT)) * 0.1
    for hw in sizes:
        x = jax.random.uniform(k1, (1, hw, hw, SWEEP_CIN))
        want = conv_bank_quant_ref(x, w, W4A4)
        entry = {}
        for strat in ("resident", "strip"):
            us = _time(lambda a, b, s=strat: conv_bank(a, b, W4A4,
                                                       strategy=s), x, w)
            got = conv_bank(x, w, W4A4, strategy=strat)
            entry[f"{strat}_us"] = us
            entry[f"{strat}_max_abs_err"] = float(
                jnp.max(jnp.abs(got - want)))
        entry["reference_us"] = _time(
            lambda a, b: conv_bank_quant_ref(a, b, W4A4), x, w)
        geo = dispatch.select_conv_strategy(hw, hw, SWEEP_CIN, SWEEP_COUT,
                                            SWEEP_K, mode="strip")
        auto = dispatch.select_conv_strategy(hw, hw, SWEEP_CIN, SWEEP_COUT,
                                             SWEEP_K)
        entry.update(strip_rows=geo.strip_rows, n_strips=geo.n_strips,
                     auto_kind=auto.kind,
                     macs=hw * hw * SWEEP_K * SWEEP_K * SWEEP_CIN
                     * SWEEP_COUT)
        results[str(hw)] = entry
        out.append(
            f"bench_kernels.conv_sweep.{hw},{entry['strip_us']:.1f},"
            f"resident_us={entry['resident_us']:.1f};"
            f"reference_us={entry['reference_us']:.1f};"
            f"auto={auto.kind};strips={geo.n_strips}x{geo.strip_rows}rows;"
            f"err={entry['strip_max_abs_err']:.1e}")

    # depthwise: the strip kernel vs the grouped per-channel im2col it replaces
    hw, c, kk = sizes[-1], 3, 5
    codes = jnp.round(jax.random.uniform(k1, (1, hw, hw, c)) * 15)
    wq = jnp.round(jax.random.uniform(k2, (kk, kk, 1, c)) * 14) - 7
    pads = ((kk // 2, kk // 2), (kk // 2, kk // 2))
    strip = dispatch.select_conv_strategy(hw, hw, c, c, kk, groups=c,
                                          mode="strip")
    with dispatch.use_backend("pallas"):
        us_s = _time(lambda: dispatch.conv_int(codes, wq, 1, pads, groups=c,
                                               strategy=strip))
        us_g = _time(lambda: dispatch.conv_int(
            codes, wq, 1, pads, groups=c,
            strategy=dispatch.ConvStrategy("resident")))
        err = float(jnp.max(jnp.abs(
            dispatch.conv_int(codes, wq, 1, pads, groups=c, strategy=strip)
            - dispatch.conv_int(codes, wq, 1, pads, groups=c,
                                strategy=dispatch.ConvStrategy("resident")))))
    results[f"depthwise_{hw}"] = {"strip_us": us_s, "grouped_im2col_us": us_g,
                                  "max_abs_err": err}
    out.append(f"bench_kernels.depthwise_{hw},{us_s:.1f},"
               f"grouped_im2col_us={us_g:.1f};err={err:.1e}")


def _fused_chain(out, results, hw=FUSED_CHAIN_HW):
    """Megakernel fusion ablation on the 3-stage imaging chain."""
    from repro.core.program import Options, Program
    prog = Program.from_pipeline("denoise_gauss", hw, hw, 1).then(
        Program.from_pipeline("edge_detect", hw, hw, 1)).then(
        Program.from_pipeline("sharpen", hw, hw, 1))
    frames = jnp.asarray(np.random.RandomState(3).rand(1, hw, hw, 1),
                         jnp.float32)
    # per-frame calibration is the fusion-legal serving case; B=1 keeps the
    # timing a clean per-frame number
    on = prog.compile(Options(backend="reference", fuse="on"))
    off = prog.compile(Options(backend="reference", fuse="off"))
    us_on = _time(lambda f: on.run_per_frame(f), frames, reps=10)
    us_off = _time(lambda f: off.run_per_frame(f), frames, reps=10)
    bitwise = bool(np.array_equal(np.asarray(on.run_per_frame(frames)),
                                  np.asarray(off.run_per_frame(frames))))
    assert bitwise, "fused chain output diverged from unfused (must be exact)"
    seg, = on.plan.fused_segments      # the whole chain is one segment
    results[str(hw)] = {
        "fused_us": us_on, "unfused_us": us_off,
        "speedup": us_off / us_on, "bitwise_equal": bitwise,
        "segment_names": list(seg.names), "halo_rows": seg.halo_rows,
        "vmem_bytes": seg.vmem_bytes,
    }
    out.append(f"bench_kernels.fused_chain.{hw},{us_on:.1f},"
               f"unfused_us={us_off:.1f};speedup={us_off / us_on:.2f}x;"
               f"segment={'+'.join(seg.names)};bitwise={bitwise}")


def run(csv=True, sizes=SWEEP_SIZES):
    out = []
    micro, sweep, fused = {}, {}, {}
    _micro(out, micro)
    _conv_sweep(out, sweep, sizes)
    _fused_chain(out, fused)
    payload = {
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "interpret": dispatch.default_interpret(),
        "micro": micro,
        "conv_strategy_sweep": sweep,
        "fused_chain": fused,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if csv:
        print("\n".join(out))
        print(f"bench_kernels.json,0.0,path={OUT_PATH}")
    return out


if __name__ == "__main__":
    run()
