"""Beyond-paper: the Lightator OC cost model applied to the assigned LMs.

The paper's architecture-level simulator prices any MVM in optical cycles
(core.optical_core.schedule_matmul). This bench asks: what would one decode
step of each (edge-scale) assigned LM cost on the 96-bank OC, and how does
the [W:A] configuration trade power for accuracy headroom — the paper's
Table-1 axes transplanted onto the LM architectures the framework serves.

(The OC is a 5184-MAC edge device: only the sub-2B archs are edge-plausible;
big archs are included as "cycles scale" reference rows.)
"""

from __future__ import annotations

import time

from repro.configs import get_config
from repro.core import optical_core as ocore
from repro.core.power_model import PowerModel
from repro.core.quant import W4A4, W3A4, W2A4

ARCHS = ["smollm-360m", "tinyllama-1.1b", "mamba2-1.3b", "hymba-1.5b",
         "stablelm-3b", "yi-34b"]


def decode_schedules(cfg):
    """OC schedules for every projection touched by ONE decoded token."""
    s = []
    d = cfg.d_model
    if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
        s.append(ocore.schedule_matmul("wq", 1, d, cfg.attn_dim))
        s.append(ocore.schedule_matmul("wk", 1, d, cfg.kv_dim))
        s.append(ocore.schedule_matmul("wv", 1, d, cfg.kv_dim))
        s.append(ocore.schedule_matmul("wo", 1, cfg.attn_dim, d))
    if cfg.family in ("ssm", "hybrid"):
        gn = cfg.ssm_groups * cfg.ssm_state
        s.append(ocore.schedule_matmul(
            "ssm_in", 1, d, 2 * cfg.d_inner + 2 * gn + cfg.ssm_heads))
        s.append(ocore.schedule_matmul("ssm_out", 1, cfg.d_inner, d))
    if cfg.family != "ssm":
        n_mats = 3 if cfg.ffn == "swiglu" else 2
        for i in range(n_mats):
            a, b = (d, cfg.d_ff) if i < n_mats - 1 else (cfg.d_ff, d)
            s.append(ocore.schedule_matmul(f"ffn{i}", 1, a, b))
    # one layer's schedules x n_layers: replicate by scaling cycles
    return s


def run(csv=True):
    pm = PowerModel()
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        t0 = time.perf_counter()
        per_layer = decode_schedules(cfg)
        layer_cycles = sum(s.cycles + s.weight_remaps * 128 for s in per_layer)
        total_cycles = layer_cycles * cfg.n_layers
        us = (time.perf_counter() - t0) * 1e6
        for spec, nm in ((W4A4, "4:4"), (W3A4, "3:4"), (W2A4, "2:4")):
            rep = pm.model_report(per_layer * cfg.n_layers, spec)
            out.append(
                f"bench_lm_photonic.{arch}.[{nm}],{us:.0f},"
                f"cycles_per_token={total_cycles};"
                f"tok_per_s={rep.fps:.1f};avg_W={rep.avg_power_w:.2f};"
                f"tok_per_J={rep.fps / max(rep.avg_power_w, 1e-9):.1f}")
    if csv:
        print("\n".join(out))
    return out


if __name__ == "__main__":
    run()
