"""Imaging pipelines: frames/s + quantized-vs-float quality per scheme.

For every pipeline in ``repro.imaging.PIPELINES`` x [W:A] scheme, compiles
through the Program/Options/Executable front door, measures compiled
frames/s on the host backend, and scores the
quantized device output against the float reference path (PSNR/SSIM); recon
pipelines are additionally scored against the original grayscale frame
(reconstruction quality). Pipelines whose conv runs fuse (``Options(fuse=)``)
also get a megakernel ablation: per-frame frames/s with fusion forced on vs
off (bit-identical by construction; see tests/test_fused_chain.py). Writes
``BENCH_imaging.json`` next to this file.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax.numpy as jnp

import repro
from repro.core.quant import W4A4, MX_43
from repro.data.synthetic import synthetic_textures
from repro.imaging import PIPELINES, apply_float, gray_target, psnr, ssim

SCHEMA_VERSION = 2
SCHEMES = {"w4a4": W4A4, "mx43": MX_43}
HW = 64
BATCH = 8
OUT_PATH = Path(__file__).resolve().parent / "BENCH_imaging.json"


def _time_loop(fn, min_reps: int = 3, min_time_s: float = 0.2) -> float:
    """Per-call seconds; repeats until both floors are met."""
    fn()                                     # warmup (jit/eager caches)
    reps, t0 = 0, time.perf_counter()
    while True:
        fn()
        reps += 1
        dt = time.perf_counter() - t0
        if reps >= min_reps and dt >= min_time_s:
            return dt / reps


def run(csv: bool = True, pipelines=None):
    import jax
    names = sorted(pipelines or PIPELINES)
    imgs, _ = synthetic_textures(BATCH, hw=HW, seed=0)
    frames = jnp.asarray(imgs)
    results = {}
    out_lines = []
    for name in names:
        pipe = PIPELINES[name]
        prog = pipe.program(HW, HW, 3)
        ref = apply_float(prog.layers, prog.params, frames)
        per_scheme = {}
        for sname, scheme in SCHEMES.items():
            exe = prog.compile(repro.Options(scheme=scheme))
            out = exe.run(frames)
            t = _time_loop(lambda: exe.run(frames).block_until_ready())
            fps = BATCH / t
            entry = {
                "fps": fps,
                "psnr_db": float(psnr(ref, out)),
                "ssim": float(ssim(ref, out)),
                "device_fps": exe.report.fps,
                "device_kfps_per_w": exe.report.kfps_per_w,
            }
            if pipe.kind == "recon":
                tgt = gray_target(frames)
                entry["recon_psnr_db"] = float(psnr(tgt, out))
                entry["recon_psnr_float_db"] = float(psnr(tgt, ref))
            per_scheme[sname] = entry
            out_lines.append(
                f"bench_imaging.{name}.{sname},{t * 1e6:.0f},"
                f"fps={fps:.0f};psnr={entry['psnr_db']:.2f}dB;"
                f"ssim={entry['ssim']:.4f}")
        # megakernel ablation: per-frame calibration (the fusion-legal
        # serving case) with fusion forced on vs off
        fused = None
        on = prog.compile(repro.Options(fuse="on"))
        if on.report.fused_segments:
            off = prog.compile(repro.Options(fuse="off"))
            t_on = _time_loop(
                lambda: on.run_per_frame(frames).block_until_ready())
            t_off = _time_loop(
                lambda: off.run_per_frame(frames).block_until_ready())
            fused = {"fps_fused": BATCH / t_on, "fps_unfused": BATCH / t_off,
                     "speedup": t_off / t_on,
                     "segments": ["+".join(s["names"])
                                  for s in on.report.fused_segments]}
            out_lines.append(
                f"bench_imaging.{name}.fused,{t_on * 1e6 / BATCH:.0f},"
                f"unfused_us={t_off * 1e6 / BATCH:.0f};"
                f"speedup={fused['speedup']:.2f}x;"
                f"segments={';'.join(fused['segments'])}")
        results[name] = {"kind": pipe.kind,
                         "description": pipe.description,
                         "schemes": per_scheme,
                         "fused_ablation": fused}

    payload = {
        "schema_version": SCHEMA_VERSION,
        "input": f"synthetic_textures {BATCH}x{HW}x{HW}x3",
        "backend": jax.default_backend(),
        "pipelines": results,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if csv:
        print("\n".join(out_lines))
        print(f"bench_imaging.json,0.0,path={OUT_PATH}")
    return results


if __name__ == "__main__":
    run()
