"""Eager-vs-compiled device pipeline: frames/s + compile-time trajectory.

Two things are tracked in ``BENCH_pipeline.json``:

* **throughput** — the seed ``LightatorDevice.run_eager`` per-layer
  interpreter vs the compiled path (one cached plan, one jit) on the LeNet
  smoke model at batch 1/8/32, with a bit-identity assertion between the
  two;
* **API-layer compile overhead** (schema v2) — per model, the cold
  ``Program.compile`` (scheduling + power model from scratch) vs a
  cached-plan re-compile (pure front-door overhead: options resolution +
  cache hit). Keeps the Program/Options/Executable layer honest: the
  cached path must stay microseconds.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

import repro
from repro.core import plan as plan_mod
from repro.core.accelerator import LightatorDevice
from repro.core.quant import W4A4
from repro.models.vision import lenet_ir, init_vision, vision_program

SCHEMA_VERSION = 2
BATCHES = (1, 8, 32)
COMPILE_MODELS = ("lenet", "vgg9", "vgg16")
OUT_PATH = Path(__file__).resolve().parent / "BENCH_pipeline.json"


def _time_loop(fn, min_reps: int = 3, min_time_s: float = 0.3) -> float:
    """Per-call seconds; repeats until both floors are met."""
    fn()                                     # warmup (jit/eager caches)
    reps, t0 = 0, time.perf_counter()
    while True:
        fn()
        reps += 1
        dt = time.perf_counter() - t0
        if reps >= min_reps and dt >= min_time_s:
            return dt / reps


def _compile_times(model: str, options: repro.Options) -> dict:
    """Cold (empty plan cache) vs cached-plan compile milliseconds."""
    # params={} skips weight init — compile timing only needs the IR
    prog = vision_program(model, params={})
    plan_mod.clear_plan_cache()
    t0 = time.perf_counter()
    prog.compile(options)
    cold_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    prog.compile(options)
    cached_ms = (time.perf_counter() - t0) * 1e3
    assert plan_mod.plan_cache_stats()["hits"] >= 1
    return {"cold_ms": cold_ms, "cached_ms": cached_ms}


def run(csv: bool = True, batches=BATCHES):
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(0), layers)
    prog = repro.Program(layers, params, (28, 28, 1), name="lenet")
    options = repro.Options(scheme=W4A4)
    dev = LightatorDevice()
    results = {}
    out_lines = []
    for bs in batches:
        frames = jax.random.uniform(jax.random.PRNGKey(1), (bs, 28, 28, 1))
        exe = prog.compile(options)

        le, _ = dev.run_eager(layers, params, frames, W4A4)
        lc = exe.run(frames)
        identical = bool(jnp.array_equal(le, lc))
        if not identical:
            raise RuntimeError(
                f"bench_pipeline: compiled logits diverged from eager at "
                f"batch {bs} (max|diff|="
                f"{float(jnp.max(jnp.abs(le - lc))):.3e})")

        t_eager = _time_loop(
            lambda: dev.run_eager(layers, params, frames, W4A4)[0]
            .block_until_ready())
        t_comp = _time_loop(lambda: exe.run(frames).block_until_ready())
        eager_fps = bs / t_eager
        comp_fps = bs / t_comp
        speedup = comp_fps / eager_fps
        results[str(bs)] = {
            "eager_fps": eager_fps,
            "compiled_fps": comp_fps,
            "speedup": speedup,
            "logits_identical": identical,
        }
        out_lines.append(
            f"bench_pipeline.lenet_w4a4.b{bs},{t_comp * 1e6:.0f},"
            f"eager_fps={eager_fps:.0f};compiled_fps={comp_fps:.0f};"
            f"speedup={speedup:.2f}x;identical={identical}")

    compile_ms = {m: _compile_times(m, options) for m in COMPILE_MODELS}
    for m, t in compile_ms.items():
        out_lines.append(
            f"bench_pipeline.compile.{m},{t['cold_ms'] * 1e3:.0f},"
            f"cold_ms={t['cold_ms']:.2f};cached_ms={t['cached_ms']:.4f}")

    payload = {
        "schema_version": SCHEMA_VERSION,
        "model": "lenet",
        "scheme": "w4a4",
        "backend": jax.default_backend(),
        "batches": results,
        "compile_ms": compile_ms,
    }
    # merge with prior runs so a --quick sweep doesn't drop trajectory
    # points recorded at other batch sizes — but only when the prior file
    # describes the same model/scheme/backend AND schema (mixed hardware or
    # schema generations would corrupt the trajectory)
    if OUT_PATH.exists():
        try:
            prior = json.loads(OUT_PATH.read_text())
            same_config = all(
                prior.get(k) == payload[k]
                for k in ("schema_version", "model", "scheme", "backend"))
            if same_config:
                merged = prior.get("batches", {})
                merged.update(payload["batches"])
                payload["batches"] = merged
        except (json.JSONDecodeError, AttributeError):
            pass
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if csv:
        print("\n".join(out_lines))
        print(f"bench_pipeline.json,0.0,path={OUT_PATH}")
    return results


if __name__ == "__main__":
    run()
