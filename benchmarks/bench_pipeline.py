"""Eager-vs-compiled device pipeline: frames/s over a batch sweep.

The refactor under test (core.plan): the seed ``LightatorDevice.run`` was an
eager per-layer interpreter that re-scheduled and re-ran the power model on
every frame; the compiled path resolves all of that once and executes under
a single jax.jit. This benchmark measures both on the LeNet smoke model at
batch 1/8/32, asserts the logits stay bit-identical, and writes
``BENCH_pipeline.json`` next to this file so future PRs have a perf
trajectory to compare against.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.accelerator import LightatorDevice
from repro.core.quant import W4A4
from repro.models.vision import lenet_ir, init_vision

SCHEMA_VERSION = 1
BATCHES = (1, 8, 32)
OUT_PATH = Path(__file__).resolve().parent / "BENCH_pipeline.json"


def _time_loop(fn, min_reps: int = 3, min_time_s: float = 0.3) -> float:
    """Per-call seconds; repeats until both floors are met."""
    fn()                                     # warmup (jit/eager caches)
    reps, t0 = 0, time.perf_counter()
    while True:
        fn()
        reps += 1
        dt = time.perf_counter() - t0
        if reps >= min_reps and dt >= min_time_s:
            return dt / reps


def run(csv: bool = True, batches=BATCHES):
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(0), layers)
    dev = LightatorDevice()
    results = {}
    out_lines = []
    for bs in batches:
        frames = jax.random.uniform(jax.random.PRNGKey(1), (bs, 28, 28, 1))
        plan = dev.compile(layers, frames.shape, W4A4)

        le, _ = dev.run_eager(layers, params, frames, W4A4)
        lc = plan_mod.execute(plan, params, frames)
        identical = bool(jnp.array_equal(le, lc))
        if not identical:
            raise RuntimeError(
                f"bench_pipeline: compiled logits diverged from eager at "
                f"batch {bs} (max|diff|="
                f"{float(jnp.max(jnp.abs(le - lc))):.3e})")

        t_eager = _time_loop(
            lambda: dev.run_eager(layers, params, frames, W4A4)[0]
            .block_until_ready())
        t_comp = _time_loop(
            lambda: plan_mod.execute(plan, params, frames)
            .block_until_ready())
        eager_fps = bs / t_eager
        comp_fps = bs / t_comp
        speedup = comp_fps / eager_fps
        results[str(bs)] = {
            "eager_fps": eager_fps,
            "compiled_fps": comp_fps,
            "speedup": speedup,
            "logits_identical": identical,
        }
        out_lines.append(
            f"bench_pipeline.lenet_w4a4.b{bs},{t_comp * 1e6:.0f},"
            f"eager_fps={eager_fps:.0f};compiled_fps={comp_fps:.0f};"
            f"speedup={speedup:.2f}x;identical={identical}")

    payload = {
        "schema_version": SCHEMA_VERSION,
        "model": "lenet",
        "scheme": "w4a4",
        "backend": jax.default_backend(),
        "batches": results,
    }
    # merge with prior runs so a --quick sweep doesn't drop trajectory
    # points recorded at other batch sizes — but only when the prior file
    # describes the same model/scheme/backend (mixed hardware would corrupt
    # the trajectory)
    if OUT_PATH.exists():
        try:
            prior = json.loads(OUT_PATH.read_text())
            same_config = all(prior.get(k) == payload[k]
                              for k in ("model", "scheme", "backend"))
            if same_config:
                merged = prior.get("batches", {})
                merged.update(payload["batches"])
                payload["batches"] = merged
        except (json.JSONDecodeError, AttributeError):
            pass
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if csv:
        print("\n".join(out_lines))
        print(f"bench_pipeline.json,0.0,path={OUT_PATH}")
    return results


if __name__ == "__main__":
    run()
