"""Table 1 accuracy axis: QAT accuracy trend across [W:A] configurations.

No MNIST/CIFAR offline — synthetic procedural digits stand in (DESIGN.md
§2). The claim under test is the *trend*: fp32 ~= [4:4] > [3:4] > [2:4],
with MX recovering most of the gap. LeNet, short QAT (the paper fine-tunes
6 epochs; we train-from-scratch a small number of steps on CPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import W4A4, W3A4, W2A4, MX_43
from repro.data.synthetic import synthetic_digits
from repro.models.vision import lenet_ir, init_vision, apply_vision
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _train_eval(scheme, steps=120, seed=0):
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(seed), layers)
    opt_cfg = AdamWConfig(lr=2e-3, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    xtr, ytr = synthetic_digits(512, seed=1)
    xte, yte = synthetic_digits(256, seed=2)
    xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr)

    @jax.jit
    def step(params, opt, xb, yb):
        def loss_fn(p):
            logits = apply_vision(p, layers, xb, scheme)
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, yb[:, None], -1)[:, 0]
            return jnp.mean(logz - gold)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    bs = 64
    for i in range(steps):
        sl = slice((i * bs) % 512, (i * bs) % 512 + bs)
        params, opt, loss = step(params, opt, xtr[sl], ytr[sl])
    logits = apply_vision(params, layers, jnp.asarray(xte), scheme)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))
    return acc


def run(csv=True, steps=40):
    # NOTE: 40 steps is the budget-limited regime that exposes the [W:A]
    # precision ordering; at >=120 steps EVERY config (incl. [2:4]) reaches
    # 1.000 on the synthetic digits — QAT converges at all widths on easy
    # data, itself a faithful echo of the paper's "favorable accuracy".
    out = []
    accs = {}
    for name, scheme in (("fp32", None), ("4:4", W4A4), ("3:4", W3A4),
                         ("2:4", W2A4), ("MX43", MX_43)):
        t0 = time.perf_counter()
        acc = _train_eval(scheme, steps=steps)
        us = (time.perf_counter() - t0) * 1e6
        accs[name] = acc
        out.append(f"bench_accuracy.lenet_digits.{name},{us:.0f},"
                   f"acc={acc:.3f}")
    trend_ok = accs["4:4"] >= accs["2:4"] - 0.02
    out.append(f"bench_accuracy.trend,0.0,"
               f"w4_ge_w2={trend_ok};paper_trend=accuracy drops with bits")
    if csv:
        print("\n".join(out))
    return accs


if __name__ == "__main__":
    run()
