"""Static-analysis overhead benchmark: verification must be ~free.

``Options(verify=)`` defaults to "auto" — every first compile of a plan
runs the full verifier (accumulator proof, shape re-walk, VMEM audit).
That is only acceptable if the pass costs a vanishing fraction of the
compile it rides on, so this benchmark pins the claim into
``BENCH_analysis.json``:

* **compile_us_off / compile_us_on** — a cold ``Program.compile`` of the
  deepest registered CNN (vgg9: conv chain + FC head, the most steps to
  verify) with the plan cache cleared each iteration, verification off
  vs on. ``overhead_pct`` is the gated number — ``scripts/
  check_bench.py`` fails if verification adds >= 5% to compile time.
* **verify_us** — ``analysis.verify_plan`` alone on the compiled plan
  (the marginal cost of an ``Options(verify="on")`` cache-hit re-check).
* **lint** — the concurrency lint over the real serve/obs trees: wall
  time and finding count (0 errors is separately gated by the ci.sh
  lint leg; recorded here so the docs can quote the cost).

All timings are best-of-``REPEATS`` (min de-noises CPU CI). Run:
``PYTHONPATH=src python -m benchmarks.bench_analysis``.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro import analysis
from repro.core import plan as plan_mod

SCHEMA_VERSION = 1
OUT_PATH = Path(__file__).resolve().parent / "BENCH_analysis.json"
MODEL = "vgg9"
REPEATS = 5
VERIFY_ITERS = 50
SRC = Path(__file__).resolve().parent.parent / "src" / "repro"


def _best_compile_us(prog, options) -> float:
    import repro  # noqa: F401  (jax already imported by caller)
    best = float("inf")
    for _ in range(REPEATS):
        plan_mod.clear_plan_cache()
        t0 = time.perf_counter()
        prog.compile(options)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def run() -> dict:
    import repro

    prog = repro.Program.from_model(MODEL, params={})
    off = _best_compile_us(prog, repro.Options(verify="off"))
    on = _best_compile_us(prog, repro.Options(verify="on"))
    overhead_pct = (on - off) / off * 100.0

    exe = prog.compile(repro.Options(verify="off"))
    best_verify = float("inf")
    n_diags = 0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(VERIFY_ITERS):
            diags = analysis.verify_plan(exe.plan)
        best_verify = min(
            best_verify, (time.perf_counter() - t0) / VERIFY_ITERS * 1e6)
        n_diags = len(diags)

    lint_paths = [SRC / "serve", SRC / "obs"]
    best_lint = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        findings = analysis.lint_paths(lint_paths)
        best_lint = min(best_lint, (time.perf_counter() - t0) * 1e6)

    out = {
        "schema_version": SCHEMA_VERSION,
        "verify": {
            "model": MODEL,
            "compile_us_off": off,
            "compile_us_on": on,
            "overhead_pct": overhead_pct,
            "verify_us": best_verify,
            "diagnostics": n_diags,
        },
        "lint": {
            "paths": [str(p.relative_to(SRC.parent.parent)) for p in
                      lint_paths],
            "lint_us": best_lint,
            "findings": len(findings),
            "errors": len(analysis.errors(findings)),
        },
    }
    OUT_PATH.write_text(json.dumps(out, indent=2) + "\n")
    print(f"bench_analysis: compile {MODEL} off={off:.0f}us on={on:.0f}us "
          f"(+{overhead_pct:.2f}%), verify alone {best_verify:.0f}us, "
          f"lint {best_lint:.0f}us ({len(findings)} finding(s))")
    return out


if __name__ == "__main__":
    run()
