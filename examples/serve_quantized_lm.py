"""Photonic-quantized LM serving (deliverable b): batched generation with
weight-only int-carrier storage — the Lightator deployment mode for the
assigned LM architectures.

    PYTHONPATH=src python examples/serve_quantized_lm.py \
        [--arch tinyllama-1.1b] [--gen 24]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_variant
from repro.models import lm as lm_mod
from repro.models.lm import greedy_generate as generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    for quant in ("none", "w4a4", "w2a4"):
        cfg = dataclasses.replace(smoke_variant(args.arch),
                                  quant_scheme=quant)
        params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, 8)),
                             jnp.int32)
        t0 = time.time()
        toks = generate(params, cfg, prompt, args.gen)
        dt = time.time() - t0
        print(f"quant={quant:<5} generated {toks.shape[1] - 8} tokens x "
              f"{args.batch} seqs in {dt:.2f}s; "
              f"sample: {np.asarray(toks[0, 8:16]).tolist()}")


if __name__ == "__main__":
    main()
