"""End-to-end driver (deliverable b): QAT-train LeNet across [W:A] configs
on synthetic digits, then deploy each onto the LightatorDevice and report
the paper's Table-1 axes (accuracy vs power vs kFPS/W).

    PYTHONPATH=src python examples/train_lenet_qat.py [--steps 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.accelerator import LightatorDevice
from repro.core.quant import W4A4, W3A4, W2A4, MX_43
from repro.data.synthetic import synthetic_digits
from repro.models.vision import lenet_ir, init_vision, apply_vision
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def train(scheme, steps, seed=0):
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(seed), layers)
    opt_cfg = AdamWConfig(lr=2e-3, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    xtr, ytr = synthetic_digits(2048, seed=1)
    xtr, ytr = jnp.asarray(xtr), jnp.asarray(ytr)

    @jax.jit
    def step(params, opt, xb, yb):
        def loss_fn(p):
            logits = apply_vision(p, layers, xb, scheme)
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, yb[:, None], -1)[:, 0]
            return jnp.mean(logz - gold)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    bs = 64
    for i in range(steps):
        sl = slice((i * bs) % 2048, (i * bs) % 2048 + bs)
        params, opt, loss = step(params, opt, xtr[sl], ytr[sl])
    return layers, params, float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    xte, yte = synthetic_digits(512, seed=9)
    xte, yte = jnp.asarray(xte), jnp.asarray(yte)
    dev = LightatorDevice()
    print(f"{'scheme':<8} {'acc':>6} {'power W':>8} {'kFPS/W':>8} "
          f"{'us/frame':>9}")
    for name, scheme in (("fp32", None), ("[4:4]", W4A4), ("[3:4]", W3A4),
                         ("[2:4]", W2A4), ("MX43", MX_43)):
        layers, params, _ = train(scheme, args.steps)
        logits = apply_vision(params, layers, xte, scheme)
        acc = float(jnp.mean(jnp.argmax(logits, -1) == yte))
        if scheme is None:
            print(f"{name:<8} {acc:>6.3f} {'-':>8} {'-':>8} {'-':>9}")
            continue
        # deploy on the device simulator
        dev_logits, report = dev.run(layers, params, xte[:8], scheme)
        dev_acc = float(jnp.mean(jnp.argmax(dev_logits, -1) == yte[:8]))
        print(f"{name:<8} {acc:>6.3f} {report.avg_power_w:>8.2f} "
              f"{report.kfps_per_w:>8.0f} {report.exec_time_s * 1e6:>9.2f}"
              f"   (device-exec acc on 8 frames: {dev_acc:.2f})")


if __name__ == "__main__":
    main()
