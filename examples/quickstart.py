"""Quickstart: the Lightator stack in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. capture a frame, run the ADC-less CRC + Compressive Acquisitor
2. run a photonic-quantized MVM through the Pallas kernel (== oracle)
3. execute LeNet on the LightatorDevice and read the power report
4. spin up an assigned LM arch (smoke size) with photonic quantization
"""

import jax
import jax.numpy as jnp

from repro.core.accelerator import LightatorDevice
from repro.core.compressive import compressive_acquire
from repro.core.quant import W4A4, MX_43
from repro.kernels.photonic_mvm.ops import photonic_mvm
from repro.kernels.photonic_mvm.ref import photonic_mvm_ref
from repro.models.vision import lenet_ir, init_vision

key = jax.random.PRNGKey(0)

# -- 1. sensor: frame -> CRC codes -> compressive acquisition --------------
frame = jax.random.uniform(key, (1, 256, 256, 3))        # the 256x256 imager
compressed = compressive_acquire(frame, pool=2)          # fused gray+pool
print(f"CA: {frame.shape} -> {compressed.shape} "
      f"(one optical cycle per {96 * 3} outputs)")

# -- 2. the optical core's MVM as a TPU kernel ------------------------------
x = jax.random.normal(key, (32, 512))
w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) * 0.1
y_kernel = photonic_mvm(x, w, W4A4)
y_oracle = photonic_mvm_ref(x, w, W4A4)
print(f"photonic_mvm [4:4]: max|kernel - oracle| = "
      f"{float(jnp.max(jnp.abs(y_kernel - y_oracle))):.2e}")

# -- 3. a full model on the device simulator --------------------------------
# run() = cached compile pass + single-jit batched execute pass (core.plan)
layers = lenet_ir()
params = init_vision(jax.random.PRNGKey(2), layers)
digit = jax.random.uniform(jax.random.PRNGKey(3), (1, 28, 28, 1))
dev = LightatorDevice()
logits, report = dev.run(layers, params, digit, MX_43)
print(f"LeNet on Lightator-MX: logits {logits.shape}, "
      f"{report.exec_time_s * 1e6:.2f} us/frame, "
      f"{report.avg_power_w:.2f} W, {report.kfps_per_w:.0f} kFPS/W")

# the two passes can also be driven directly — compile once, stream batches
from repro.core import plan as plan_mod
frames = jax.random.uniform(jax.random.PRNGKey(6), (8, 28, 28, 1))
plan = dev.compile(layers, frames.shape, MX_43)
batch_logits = plan_mod.execute(plan, params, frames)
print(f"compiled plan: {len(plan.schedules)} schedules cached, "
      f"batched logits {batch_logits.shape}")

# -- 4. the paper's technique on an assigned LM architecture ----------------
import dataclasses
from repro.configs import smoke_variant
from repro.models import lm as lm_mod

cfg = dataclasses.replace(smoke_variant("tinyllama-1.1b"),
                          quant_scheme="w4a4")
lm_params = lm_mod.init_lm(jax.random.PRNGKey(4), cfg)
toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
lm_logits, _ = lm_mod.lm_forward(lm_params, {"tokens": toks}, cfg)
print(f"tinyllama-smoke W4A4: logits {lm_logits.shape} "
      f"finite={bool(jnp.all(jnp.isfinite(lm_logits.astype(jnp.float32))))}")
print("quickstart OK")
