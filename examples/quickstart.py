"""Quickstart: the Lightator stack in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. capture a frame, run the ADC-less CRC + Compressive Acquisitor
2. run a photonic-quantized MVM through the Pallas kernel (== oracle)
3. compile + run LeNet through the unified Program/Options/Executable API
4. spin up an assigned LM arch (smoke size) with photonic quantization
"""

import jax
import jax.numpy as jnp

import repro
from repro.core.compressive import compressive_acquire
from repro.core.quant import W4A4, MX_43
from repro.kernels.photonic_mvm.ops import photonic_mvm
from repro.kernels.photonic_mvm.ref import photonic_mvm_ref

key = jax.random.PRNGKey(0)

# -- 1. sensor: frame -> CRC codes -> compressive acquisition --------------
frame = jax.random.uniform(key, (1, 256, 256, 3))        # the 256x256 imager
compressed = compressive_acquire(frame, pool=2)          # fused gray+pool
print(f"CA: {frame.shape} -> {compressed.shape} "
      f"(one optical cycle per {96 * 3} outputs)")

# -- 2. the optical core's MVM as a TPU kernel ------------------------------
x = jax.random.normal(key, (32, 512))
w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) * 0.1
y_kernel = photonic_mvm(x, w, W4A4)
y_oracle = photonic_mvm_ref(x, w, W4A4)
print(f"photonic_mvm [4:4]: max|kernel - oracle| = "
      f"{float(jnp.max(jnp.abs(y_kernel - y_oracle))):.2e}")

# -- 3. a full model through the one front door -----------------------------
# Program (layer IR + params + frame shape) -> compile(Options) -> Executable
prog = repro.Program.from_model("lenet", key=jax.random.PRNGKey(2))
exe = prog.compile(repro.Options(scheme=MX_43))
digit = jax.random.uniform(jax.random.PRNGKey(3), (1, 28, 28, 1))
logits = exe.run(digit)
r = exe.report
print(f"LeNet on Lightator-MX: logits {logits.shape}, "
      f"{r.exec_time_s * 1e6:.2f} us/frame, "
      f"{r.avg_power_w:.2f} W, {r.kfps_per_w:.0f} kFPS/W")

# the plan is cached: streaming any batch size reuses the same Executable
frames = jax.random.uniform(jax.random.PRNGKey(6), (8, 28, 28, 1))
batch_logits = exe.run(frames)
print(f"compiled plan: {len(exe.plan.schedules)} schedules cached, "
      f"batched logits {batch_logits.shape}")

# imaging pipelines are Programs too — and chain into ONE compiled plan
chain = (repro.Program.from_pipeline("denoise_box", 64, 64, 3)
         .then(repro.Program.from_pipeline("edge_detect", 64, 64, 3)))
out = chain.compile(repro.Options(scheme=W4A4)).run(
    jax.random.uniform(jax.random.PRNGKey(7), (2, 64, 64, 3)))
print(f"chained {chain.name}: {out.shape} in a single plan")

# -- 4. the paper's technique on an assigned LM architecture ----------------
import dataclasses
from repro.configs import smoke_variant
from repro.models import lm as lm_mod

cfg = dataclasses.replace(smoke_variant("tinyllama-1.1b"),
                          quant_scheme="w4a4")
lm_params = lm_mod.init_lm(jax.random.PRNGKey(4), cfg)
toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
lm_logits, _ = lm_mod.lm_forward(lm_params, {"tokens": toks}, cfg)
print(f"tinyllama-smoke W4A4: logits {lm_logits.shape} "
      f"finite={bool(jnp.all(jnp.isfinite(lm_logits.astype(jnp.float32))))}")
print("quickstart OK")
