"""Train a ~100M-param LM for a few hundred steps (deliverable b: the
end-to-end training driver at example scale).

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 300]

Uses a 100M-ish slice of the smollm-360m family (12 layers, d=768) on the
planted-bigram synthetic stream; checkpoints + straggler monitoring +
failure-drill flags come from the same RestartableLoop the production
driver uses. Expect a clear CE drop as the model learns the bigram rule.
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()
    # a ~100M config: register a custom variant through the train driver
    import dataclasses
    import repro.configs.base as base
    from repro.configs import get_config
    cfg100 = dataclasses.replace(
        get_config("smollm-360m"), name="smollm-100m", n_layers=12,
        d_model=768, n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
        vocab=8192, remat="none", max_seq=512)
    smoke = dataclasses.replace(cfg100, name="smollm-100m-smoke")
    base.register(cfg100, smoke)

    # batch 4 x seq 128 keeps a CPU step ~20s; on a real mesh raise both.
    losses = train_main([
        "--arch", "smollm-100m", "--steps", str(args.steps),
        "--batch", "4", "--seq", "128", "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    ])
    print(f"[example] first-10 mean CE {sum(losses[:10]) / 10:.3f} -> "
          f"last-10 mean CE {sum(losses[-10:]) / 10:.3f}")


if __name__ == "__main__":
    main()
