"""Versatile image processing on the Lightator device — all pipelines.

    PYTHONPATH=src python examples/imaging_demo.py [--quick]

Runs every fixed-function pipeline in ``repro.imaging.PIPELINES`` on a
synthetic RGB scene, twice: through the float reference path and through
the compiled quantized device path ([4:4]) — all via the unified
``Program.compile(Options) -> Executable`` API. Prints a quality/power
table, shows a denoise->edge chain fused into one compiled plan, then
trains the compress_recon_deconv head and shows the reconstruction PSNR
improvement over plain bilinear. ``--quick`` shrinks frames/steps for CI
smoke runs.
"""

import argparse

import jax.numpy as jnp

import repro
from repro.core.quant import W4A4
from repro.data.synthetic import synthetic_textures
from repro.imaging import (PIPELINES, apply_float, fit_recon_head,
                           gray_target, psnr, ssim)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small frames / few training steps (CI smoke)")
    args = ap.parse_args(argv)
    hw, batch, steps = (32, 2, 30) if args.quick else (64, 8, 150)

    imgs, _ = synthetic_textures(batch, hw=hw, seed=0)
    frames = jnp.asarray(imgs)
    options = repro.Options(scheme=W4A4)

    print(f"{'pipeline':24s} {'out':>14s} {'PSNR':>8s} {'SSIM':>7s} "
          f"{'dev FPS':>12s} {'kFPS/W':>9s}")
    for name, pipe in PIPELINES.items():
        prog = pipe.program(hw, hw, 3)
        exe = prog.compile(options)
        out = exe.run(frames)
        ref = apply_float(prog.layers, prog.params, frames)
        r = exe.report
        print(f"{name:24s} {str(tuple(out.shape[1:])):>14s} "
              f"{float(psnr(ref, out)):7.2f}d {float(ssim(ref, out)):7.4f} "
              f"{r.fps:12,.0f} {r.kfps_per_w:9.1f}")

    # program composition: denoise -> edge detect as ONE compiled plan
    chain = (PIPELINES["denoise_gauss"].program(hw, hw, 3)
             .then(PIPELINES["edge_detect"].program(hw, hw, 3)))
    exe = chain.compile(options)
    out = exe.run(frames)
    ref = apply_float(chain.layers, chain.params, frames)
    print(f"\n[chain] {chain.name}: {len(exe.plan.schedules)} schedules in "
          f"one plan, PSNR {float(psnr(ref, out)):.2f} dB, "
          f"{exe.report.fps:,.0f} dev FPS")

    # learned reconstruction: fit the deconv head, compare against bilinear
    prog = PIPELINES["compress_recon_deconv"].program(hw, hw, 3)
    tgt = gray_target(frames)
    before = apply_float(prog.layers, prog.params, frames)
    fitted = fit_recon_head(prog.layers, prog.params, frames, steps=steps)
    after = apply_float(prog.layers, fitted, frames)
    dev_after = repro.Program(prog.layers, fitted, prog.input_hwc,
                              name=prog.name).compile(options).run(frames)
    print(f"\n[recon] bilinear       {float(psnr(tgt, before)):.2f} dB vs "
          f"original (float)")
    print(f"[recon] + trained head {float(psnr(tgt, after)):.2f} dB vs "
          f"original (float)")
    print(f"[recon] + trained head {float(psnr(tgt, dev_after)):.2f} dB vs "
          f"original (quantized device, {W4A4.name})")


if __name__ == "__main__":
    main()
