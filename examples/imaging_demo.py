"""Versatile image processing on the Lightator device — all pipelines.

    PYTHONPATH=src python examples/imaging_demo.py

Runs every fixed-function pipeline in ``repro.imaging.PIPELINES`` on a
synthetic RGB scene, twice: through the float reference path and through
the compiled quantized device path ([4:4]). Prints a quality/power table,
then trains the compress_recon_deconv head and shows the reconstruction
PSNR improvement over plain bilinear.
"""

import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.quant import W4A4
from repro.data.synthetic import synthetic_textures
from repro.imaging import (PIPELINES, apply_float, fit_recon_head,
                           gray_target, psnr, ssim)

HW, BATCH = 64, 8


def main():
    imgs, _ = synthetic_textures(BATCH, hw=HW, seed=0)
    frames = jnp.asarray(imgs)

    print(f"{'pipeline':24s} {'out':>14s} {'PSNR':>8s} {'SSIM':>7s} "
          f"{'dev FPS':>12s} {'kFPS/W':>9s}")
    for name, pipe in PIPELINES.items():
        layers, params = pipe.build(HW, HW, 3)
        plan = plan_mod.compile_model(layers, frames.shape, W4A4)
        out = plan_mod.execute(plan, params, frames)
        ref = apply_float(layers, params, frames)
        r = plan.report
        print(f"{name:24s} {str(tuple(out.shape[1:])):>14s} "
              f"{float(psnr(ref, out)):7.2f}d {float(ssim(ref, out)):7.4f} "
              f"{r.fps:12,.0f} {r.kfps_per_w:9.1f}")

    # learned reconstruction: fit the deconv head, compare against bilinear
    pipe = PIPELINES["compress_recon_deconv"]
    layers, params = pipe.build(HW, HW, 3)
    tgt = gray_target(frames)
    before = apply_float(layers, params, frames)
    fitted = fit_recon_head(layers, params, frames, steps=150)
    after = apply_float(layers, fitted, frames)
    plan = plan_mod.compile_model(layers, frames.shape, W4A4)
    dev_after = plan_mod.execute(plan, fitted, frames)
    print(f"\n[recon] bilinear       {float(psnr(tgt, before)):.2f} dB vs "
          f"original (float)")
    print(f"[recon] + trained head {float(psnr(tgt, after)):.2f} dB vs "
          f"original (float)")
    print(f"[recon] + trained head {float(psnr(tgt, dev_after)):.2f} dB vs "
          f"original (quantized device, {W4A4.name})")


if __name__ == "__main__":
    main()
