"""Serving metrics: latency percentiles, throughput, padding waste.

Lock-guarded counters + a bounded latency reservoir per hosted program,
snapshotted into plain JSON-able dicts by ``Server.stats()``. The paper's
headline efficiency axis (kFPS/W) rides along from each executable's power
report, so a stats snapshot pairs *measured* frames/s with the *modeled*
device FPS/W it should be judged against.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional

import numpy as np

PERCENTILES = (50.0, 95.0, 99.0)


class ProgramMetrics:
    """Counters + latency reservoir for one hosted program (thread-safe)."""

    def __init__(self, window: int = 8192):
        self._lock = threading.Lock()
        self._latencies_ms: deque = deque(maxlen=window)
        self.submitted = 0          # requests admitted to the queue
        self.served = 0             # requests fulfilled
        self.shed = 0               # requests dropped at a missed deadline
        self.rejected = 0           # requests refused at admission
        self.failed = 0             # requests failed by an execution error
                                    # or a no-drain stop
        self.frames_served = 0
        self.batches = 0            # device dispatches
        self.slots = 0              # device batch slots consumed (incl. pad)
        self.queued_frames = 0      # gauge, maintained by the server
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- recording (called from the server's threads) ----------------------

    def record_admit(self, n_requests: int = 1) -> None:
        with self._lock:
            self.submitted += n_requests

    def record_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def record_shed(self, n: int = 1) -> None:
        with self._lock:
            self.shed += n

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def record_batch(self, slots: int, t_dispatch: float) -> None:
        with self._lock:
            self.batches += 1
            self.slots += slots
            if self._t_first is None:
                self._t_first = t_dispatch

    def record_served(self, latency_s: float, frames: int,
                      t_done: float) -> None:
        with self._lock:
            self.served += 1
            self.frames_served += frames
            self._latencies_ms.append(latency_s * 1e3)
            self._t_last = t_done

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            span = ((self._t_last - self._t_first)
                    if self._t_first is not None and self._t_last is not None
                    and self._t_last > self._t_first else None)
            snap = {
                "requests": {
                    "submitted": self.submitted,
                    "served": self.served,
                    "shed_deadline": self.shed,
                    "rejected": self.rejected,
                    "failed": self.failed,
                    "pending": (self.submitted - self.served - self.shed
                                - self.failed),
                },
                "frames_served": self.frames_served,
                "queue_depth": self.queued_frames,
                "batches": self.batches,
                "avg_batch": (self.frames_served / self.batches
                              if self.batches else 0.0),
                # fraction of device batch slots burned on padding
                "padding_waste": (1.0 - self.frames_served / self.slots
                                  if self.slots else 0.0),
                # first dispatch -> last completion: the serving window,
                # idle tails excluded
                "achieved_fps": (self.frames_served / span if span else 0.0),
                "latency_ms": latency_summary(lat),
            }
        return snap


def latency_summary(lat_ms: np.ndarray) -> Dict[str, float]:
    """p50/p95/p99 + mean/max of a latency sample (empty-safe)."""
    if lat_ms.size == 0:
        return {"count": 0}
    out = {"count": int(lat_ms.size),
           "mean": float(lat_ms.mean()),
           "max": float(lat_ms.max())}
    for p, v in zip(PERCENTILES, np.percentile(lat_ms, PERCENTILES)):
        out[f"p{p:g}"] = float(v)
    return out


def now() -> float:
    """The one clock every serving timestamp uses (monotonic seconds)."""
    return time.perf_counter()
