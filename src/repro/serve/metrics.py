"""Serving metrics: latency percentiles, throughput, padding waste.

Since the ``repro.obs`` layer landed, :class:`ProgramMetrics` is a thin
facade over a private :class:`repro.obs.Registry` per hosted program:
the counters/gauges/histograms are registry metrics (named
``serve.<program>.*``, dumpable via ``obs.prometheus_text``), every
update and the snapshot run under the registry's single lock (so a
snapshot is internally consistent), and the ``Server.stats()`` snapshot
shape is unchanged. The paper's headline efficiency axis (kFPS/W) rides
along from each executable's power report, so a stats snapshot pairs
*measured* frames/s with the *modeled* device FPS/W it should be judged
against — and ``Server.stats`` now also reports the drift between the
two.

Consistency contract (pinned in tests/test_obs.py): the
``queued_frames`` gauge is only ever written through :meth:`add_queued`
(under the lock — the server thread used to mutate it bare), and
``achieved_fps`` clamps its serving window so a single-batch run
(``_t_first == _t_last`` at clock resolution) can never divide by zero.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Dict, Optional

import numpy as np

from repro import obs

PERCENTILES = (50.0, 95.0, 99.0)

# Occupancy/waste are ratios in [0, 1]; obs.RATIO_BUCKETS fits both.
_MIN_WINDOW_S = 1e-9          # achieved_fps divisor clamp (clock ticks)


class ProgramMetrics:
    """Counters + latency reservoir for one hosted program (thread-safe).

    A facade over ``obs`` registry metrics; the recording API and the
    :meth:`snapshot` shape are unchanged from the pre-obs version, and
    the legacy attribute reads (``metrics.submitted`` etc.) keep working
    as properties.
    """

    def __init__(self, window: int = 8192, name: str = "program",
                 registry: Optional[obs.Registry] = None):
        # a PRIVATE registry by default: two Servers hosting the same
        # program name must never alias each other's counters
        self.registry = registry if registry is not None else obs.Registry()
        self._lock = self.registry._lock
        p = f"serve.{name}"
        self._submitted = self.registry.counter(f"{p}.submitted")
        self._served = self.registry.counter(f"{p}.served")
        self._shed = self.registry.counter(f"{p}.shed_deadline")
        self._rejected = self.registry.counter(f"{p}.rejected")
        self._failed = self.registry.counter(f"{p}.failed")
        self._frames_served = self.registry.counter(f"{p}.frames_served")
        self._batches = self.registry.counter(f"{p}.batches")
        self._slots = self.registry.counter(f"{p}.slots")
        self._queued = self.registry.gauge(f"{p}.queued_frames")
        self._occupancy = self.registry.histogram(f"{p}.batch_occupancy")
        self._waste = self.registry.histogram(f"{p}.padding_waste")
        self._latencies_ms: deque = deque(maxlen=window)
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- legacy attribute reads (kept for callers/tests) -------------------

    @property
    def submitted(self) -> int:
        return self._submitted.get()

    @property
    def served(self) -> int:
        return self._served.get()

    @property
    def shed(self) -> int:
        return self._shed.get()

    @property
    def rejected(self) -> int:
        return self._rejected.get()

    @property
    def failed(self) -> int:
        return self._failed.get()

    @property
    def frames_served(self) -> int:
        return self._frames_served.get()

    @property
    def batches(self) -> int:
        return self._batches.get()

    @property
    def slots(self) -> int:
        return self._slots.get()

    @property
    def queued_frames(self) -> int:
        return int(self._queued.get())

    # -- recording (called from the server's threads) ----------------------

    def record_admit(self, n_requests: int = 1) -> None:
        self._submitted.inc(n_requests)

    def record_reject(self) -> None:
        self._rejected.inc()

    def record_shed(self, n: int = 1) -> None:
        self._shed.inc(n)

    def record_failed(self, n: int = 1) -> None:
        self._failed.inc(n)

    def add_queued(self, delta: int) -> None:
        """Adjust the queued-frames gauge (the ONLY sanctioned writer —
        takes the lock, unlike the bare ``+=`` the server used to do)."""
        self._queued.add(delta)

    def record_batch(self, slots: int, t_dispatch: float,
                     frames: Optional[int] = None) -> None:
        with self._lock:
            self._batches.inc()
            self._slots.inc(slots)
            if frames is not None and slots > 0:
                self._occupancy.observe(frames / slots)
                self._waste.observe(1.0 - frames / slots)
            if self._t_first is None:
                self._t_first = t_dispatch

    def record_served(self, latency_s: float, frames: int,
                      t_done: float) -> None:
        with self._lock:
            self._served.inc()
            self._frames_served.inc(frames)
            self._latencies_ms.append(latency_s * 1e3)
            self._t_last = t_done

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            span = None
            if self._t_first is not None and self._t_last is not None:
                # first dispatch -> last completion: the serving window,
                # idle tails excluded; clamped so a single-batch run
                # (both stamps within clock resolution) stays finite
                span = max(self._t_last - self._t_first, _MIN_WINDOW_S)
            frames_served = self._frames_served.get()
            batches = self._batches.get()
            slots = self._slots.get()
            submitted = self._submitted.get()
            served = self._served.get()
            shed = self._shed.get()
            failed = self._failed.get()
            snap = {
                "requests": {
                    "submitted": submitted,
                    "served": served,
                    "shed_deadline": shed,
                    "rejected": self._rejected.get(),
                    "failed": failed,
                    "pending": submitted - served - shed - failed,
                },
                "frames_served": frames_served,
                "queue_depth": int(self._queued.get()),
                "batches": batches,
                "avg_batch": (frames_served / batches if batches else 0.0),
                # fraction of device batch slots burned on padding
                "padding_waste": (1.0 - frames_served / slots
                                  if slots else 0.0),
                "achieved_fps": (frames_served / span if span else 0.0),
                "latency_ms": latency_summary(lat),
            }
        return snap

    def histograms(self) -> Dict[str, Dict]:
        """Batch-occupancy / padding-waste histogram summaries
        (``Server.stats(verbose=True)``)."""
        return {"batch_occupancy": self._occupancy.summary(),
                "padding_waste": self._waste.summary()}


def latency_summary(lat_ms: np.ndarray) -> Dict[str, float]:
    """p50/p95/p99 + mean/max of a latency sample.

    An empty reservoir returns the explicit ``{"count": 0}`` shape —
    never NaN percentiles (``scripts/check_bench.py`` rejects NaN
    scalars in every ``BENCH_*.json``, so a NaN here would fail CI even
    if it slipped into an artifact).
    """
    if lat_ms.size == 0:
        return {"count": 0}
    out = {"count": int(lat_ms.size),
           "mean": float(lat_ms.mean()),
           "max": float(lat_ms.max())}
    for p, v in zip(PERCENTILES, np.percentile(lat_ms, PERCENTILES)):
        out[f"p{p:g}"] = float(v)
    return out


def now() -> float:
    """The one clock every serving timestamp uses (monotonic seconds)."""
    return time.perf_counter()


def format_stats(stats: Dict[str, object]) -> str:
    """Render ``Server.stats(verbose=True)`` as a breakdown table.

    One row per program: request accounting, latency percentiles,
    achieved fps, batching efficiency, and measured-vs-modeled kFPS/W —
    plus the plan-cache and conv-dispatch footer. Pure formatting; the
    numbers are the snapshot's.
    """
    lines = []
    hdr = (f"{'program':<18} {'served':>7} {'shed':>5} {'fail':>5} "
           f"{'p50ms':>8} {'p99ms':>8} {'fps':>9} {'avg_b':>6} "
           f"{'waste':>6} {'kFPS/W':>8} {'model':>8} {'drift':>7}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for name, p in sorted(stats.get("programs", {}).items()):
        lat = p.get("latency_ms", {})
        model = p.get("model", {})
        req = p.get("requests", {})
        lines.append(
            f"{name:<18} {req.get('served', 0):>7} "
            f"{req.get('shed_deadline', 0):>5} {req.get('failed', 0):>5} "
            f"{lat.get('p50', float('nan')):>8.2f} "
            f"{lat.get('p99', float('nan')):>8.2f} "
            f"{p.get('achieved_fps', 0.0):>9.0f} "
            f"{p.get('avg_batch', 0.0):>6.1f} "
            f"{p.get('padding_waste', 0.0):>6.1%} "
            f"{p.get('measured_kfps_per_w', 0.0):>8.3f} "
            f"{model.get('kfps_per_w', 0.0):>8.1f} "
            f"{p.get('kfps_per_w_drift', 0.0):>7.1e}")
        hists = p.get("histograms")
        if hists:
            occ = hists["batch_occupancy"]
            lines.append(f"{'':<18}   occupancy mean={occ['mean']:.2f} "
                         f"min={occ['min']} max={occ['max']} "
                         f"batches={occ['count']}")
    pool = stats.get("pool")
    if pool:
        occ = " ".join(
            f"d{d['device']}={d['occupancy']:.0%}"
            for d in pool.get("per_device", ()))
        lines.append(
            f"pool: {pool['devices']} device(s) "
            f"[{pool['placement']}] steals={pool['steals']} "
            f"occupancy {occ}")
    cache = stats.get("plan_cache")
    if cache:
        lines.append(f"plan cache: {cache['hits']} hits / "
                     f"{cache['misses']} misses "
                     f"(hit rate {cache['hit_rate']:.1%})")
    disp = stats.get("conv_dispatch")
    if disp:
        lines.append("conv dispatch: " + " ".join(
            f"{k}={v}" for k, v in sorted(disp.items())))
    return "\n".join(lines)
