"""The device pool: N local devices, one warmed Executable each.

Lightator's efficiency story is fleet-scale — an N-device board behind
one host runtime — but the PR-5 scheduler drove exactly one warmed
``Executable``, so the host saturated long before a multi-device board
would. This module is the missing layer between the scheduler and the
devices::

    scheduler ──placement──> per-device queues ──> worker threads ──┐
                (least-loaded,    (steal when idle)   (dispatch +    │
                 pluggable)                            block, double-│
                                                       buffered)     v
                                            shared completion queue ──> completer

* **Placement** — the scheduler hands each closed micro-batch to
  :meth:`Pool.dispatch`, which asks the placement policy for a device
  index given every worker's current load (queued + in-flight frames).
  The default :class:`LeastLoaded` picks the least-loaded worker and
  rotates ties, so an all-idle pool spreads consecutive batches across
  devices instead of hammering device 0. :class:`RoundRobin` ignores
  load entirely (deterministic placement for tests). Policies are plain
  objects with a ``choose(loads) -> index`` method — inject any via
  ``Server(placement=...)``.
* **Work stealing** — placement is a guess made at dispatch time; loads
  drift while batches wait. A worker whose own queue is empty steals the
  *oldest* batch from the most-backlogged peer before going to sleep, so
  one slow device cannot strand queued work while others idle.
* **Per-device pipelining** — each worker dispatches a batch to its
  device asynchronously, then blocks on the *previous* batch's result
  while the new one computes (``ServeConfig.max_inflight >= 2``; 1 runs
  synchronously). The blocking wait happens on the worker thread, so the
  shared completer never waits on a device — it only splits results and
  resolves futures, and a slow device can never head-of-line-block
  another device's completions.
* **Fault isolation** — an exception from a device worker (or the
  injectable ``Hooks.execute`` seam around it) fails exactly that
  batch's requests with a typed :class:`WorkerError` (original exception
  chained as ``__cause__``); the worker, the pool, and every other batch
  keep running, and the failure is counted per device.

Results are **bit-identical** to single-device execution: every worker
runs the same per-frame-calibrated executor (``Executable.run_padded``)
on a device-bound view of the same compiled plan, and per-frame
calibration makes each frame's result a pure function of that frame —
device placement, batch composition, padding and steal order can never
perturb it (property suite: ``tests/test_serve_pool.py``).
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
from collections import deque
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.serve.clock import Clock

# Chrome-trace lane ids for per-device execute spans: the execute span is
# recorded retrospectively (dispatch happened one loop iteration before
# the blocking wait returns), so it goes on a synthetic per-device lane
# instead of the worker thread's live span stack.
_DEVICE_LANE_BASE = 1 << 21


class WorkerError(RuntimeError):
    """A device worker failed to execute a batch.

    Exactly the failed batch's requests receive this error (the original
    exception is chained as ``__cause__``); other batches, the worker,
    and the rest of the pool are unaffected. Carries ``program`` and
    ``device`` so callers can tell *where* the batch died.
    """

    def __init__(self, message: str, program: str = "", device: int = -1):
        super().__init__(message)
        self.program = program
        self.device = device


# ---------------------------------------------------------------------------
# Placement policies
# ---------------------------------------------------------------------------

class LeastLoaded:
    """Pick the device with the fewest queued + in-flight frames.

    Ties rotate: the scan starts just past the previous winner, so an
    all-idle pool (every load 0 — the common case at low offered load)
    spreads consecutive batches round-robin instead of always choosing
    device 0. Strictly-lower load always wins regardless of rotation.
    """

    def __init__(self):
        self._start = 0

    def choose(self, loads: Sequence[int]) -> int:
        n = len(loads)
        best, best_load = None, None
        for k in range(n):
            i = (self._start + k) % n
            if best_load is None or loads[i] < best_load:
                best, best_load = i, loads[i]
        self._start = (best + 1) % n
        return best


class RoundRobin:
    """Strict rotation, load-blind — deterministic placement for tests."""

    def __init__(self):
        self._next = 0

    def choose(self, loads: Sequence[int]) -> int:
        i = self._next % len(loads)
        self._next = i + 1
        return i


PLACEMENTS = {"least_loaded": LeastLoaded, "round_robin": RoundRobin}


# ---------------------------------------------------------------------------
# Batch / completion currency between scheduler, workers and completer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class Batch:
    """One closed micro-batch in flight through the pool.

    Identity semantics (``eq=False``): batches are tracked in per-worker
    in-flight lists and removed by ``is``-equality — field-wise ``==``
    over numpy frames would be both wrong and ambiguous.
    """

    hosted: object                    # serve.server.HostedProgram
    live: list                        # [_Request] whose futures to resolve
    frames: np.ndarray                # [n, H, W, C] concatenated
    bucket: int
    n: int                            # real frames (== frames.shape[0])
    t_closed: float
    t_dispatch: float = 0.0           # stamped by the worker at dispatch


@dataclasses.dataclass
class Done:
    """A finished (or failed) batch, handed to the shared completer."""

    batch: Batch
    device: int
    out: Optional[np.ndarray]         # host-side result (None on error)
    error: Optional[BaseException]
    t_ready: float


_STOP = object()


class _Worker:
    """One device: bound executable index, FIFO queue, metrics, thread."""

    def __init__(self, index: int, registry: obs.Registry):
        self.index = index
        self.queue: deque = deque()
        self.queued_frames = 0
        self.inflight_frames = 0
        self.inflight: List[Batch] = []   # dispatched, not yet completed
        p = f"serve.pool.device{index}"
        self.batches = registry.counter(f"{p}.batches")
        self.frames = registry.counter(f"{p}.frames")
        self.steals = registry.counter(f"{p}.steals")
        self.failures = registry.counter(f"{p}.failures")
        self.busy_s = registry.gauge(f"{p}.busy_s")
        # last completion time on this device (worker-thread private):
        # under dispatch-ahead pipelining a batch is dispatched before
        # its predecessor's results are ready, so its device-busy span
        # starts at max(t_dispatch, predecessor ready) — the device
        # executes serially even when the host runs ahead
        self.last_ready: Optional[float] = None
        self.thread: Optional[threading.Thread] = None

    @property
    def load(self) -> int:
        return self.queued_frames + self.inflight_frames


class Pool:
    """N device workers + placement + a shared completion queue.

    The pool does not know about requests or futures — it moves
    :class:`Batch` objects from :meth:`dispatch` to the ``done`` queue,
    executing each on one device via the hosted program's device-bound
    executable (``hosted.bound[device_index]``). The server's completer
    consumes ``done``.
    """

    def __init__(self, n_devices: int, policy, done: queue_mod.Queue,
                 clock: Optional[Clock] = None, execute_hook:
                 Optional[Callable] = None, pipeline: int = 2):
        if n_devices < 1:
            raise ValueError(f"pool needs >= 1 device, got {n_devices}")
        self.registry = obs.Registry()
        self._policy = policy
        self._done = done
        self._clock = clock or Clock()
        self._execute_hook = execute_hook
        self._pipeline = max(int(pipeline), 1)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._stopping = False
        self._t_start: Optional[float] = None
        self._steals = self.registry.counter("serve.pool.steals")
        self._placement_us = self.registry.histogram(
            "serve.pool.placement_us",
            buckets=(1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0, 5000.0))
        self._workers: List[_Worker] = [
            _Worker(i, self.registry) for i in range(n_devices)]

    @property
    def size(self) -> int:
        return len(self._workers)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "Pool":
        self._t_start = self._clock.now()
        for w in self._workers:
            w.thread = threading.Thread(
                target=self._run, args=(w,),
                name=f"repro-serve-device{w.index}", daemon=True)
            w.thread.start()
        return self

    def stop(self, timeout: Optional[float] = None) -> None:
        """Drain every queue, flush pending batches, join the workers.

        Every dispatched batch's completion is on the ``done`` queue by
        the time this returns **provided every worker joined** (workers
        enqueue before exiting) — then the caller can safely sentinel
        its completer. Under a finite ``timeout`` a wedged worker may
        outlive the join; check :meth:`alive` and reclaim its work via
        :meth:`take_outstanding` before putting any sentinel, or those
        batches' futures are stranded unresolved.
        """
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        for w in self._workers:
            if w.thread is not None:
                w.thread.join(timeout)

    def alive(self) -> bool:
        """True while any worker thread is still running (a finite
        ``stop(timeout)`` may return before the pool is quiescent)."""
        return any(w.thread is not None and w.thread.is_alive()
                   for w in self._workers)

    def workers_alive(self) -> int:
        """How many worker threads are currently running."""
        return sum(1 for w in self._workers
                   if w.thread is not None and w.thread.is_alive())

    def healthy(self) -> bool:
        """True only when *every* worker thread is running.

        :meth:`alive` answers "is the pool still doing anything" (the
        stop/drain question); this answers the ``/healthz`` question —
        a pool that lost one of four workers is degraded even though
        it still serves.
        """
        return all(w.thread is not None and w.thread.is_alive()
                   for w in self._workers)

    def take_outstanding(self):
        """Reclaim work a timed-out :meth:`stop` left behind.

        Returns ``(queued, inflight)``: ``queued`` batches are *removed*
        from the worker queues (no worker can pick them up afterwards,
        so they will never reach the ``done`` queue — the caller owns
        failing their futures); ``inflight`` is a snapshot of batches
        dispatched to a device but not yet completed — a wedged worker
        may still complete one later, so the caller must settle their
        futures idempotently.
        """
        queued: List[Batch] = []
        inflight: List[Batch] = []
        with self._cond:
            for w in self._workers:
                while w.queue:
                    batch = w.queue.popleft()
                    w.queued_frames -= batch.n
                    queued.append(batch)
                inflight.extend(w.inflight)
        return queued, inflight

    # -- dispatch (scheduler thread) ---------------------------------------

    def dispatch(self, batch: Batch) -> int:
        """Place ``batch`` on a device queue; returns the device index."""
        t0 = self._clock.now()
        with self._cond:
            idx = self._policy.choose([w.load for w in self._workers])
            w = self._workers[idx]
            w.queue.append(batch)
            w.queued_frames += batch.n
            self._cond.notify_all()
        self._placement_us.observe((self._clock.now() - t0) * 1e6)
        if obs.recording():
            obs.event("serve.pool.place",
                      attrs={"device": idx, "program": batch.hosted.name,
                             "frames": batch.n, "bucket": batch.bucket})
        return idx

    # -- worker loop -------------------------------------------------------

    def _next(self, w: _Worker, block: bool):
        """Own queue first, then steal the oldest batch from the most
        backlogged peer; ``_STOP`` when stopping and fully drained, and
        ``None`` when idle but a pending batch still needs finishing
        (``block=False``)."""
        with self._cond:
            while True:
                if w.queue:
                    batch = w.queue.popleft()
                    w.queued_frames -= batch.n
                    return batch
                victim = max((v for v in self._workers if v.queue),
                             key=lambda v: v.queued_frames, default=None)
                if victim is not None:
                    batch = victim.queue.popleft()    # oldest: FIFO-fair
                    victim.queued_frames -= batch.n
                    w.steals.inc()
                    self._steals.inc()
                    if obs.recording():
                        obs.event("serve.pool.steal",
                                  attrs={"thief": w.index,
                                         "victim": victim.index,
                                         "frames": batch.n})
                    return batch
                if self._stopping:
                    return _STOP
                if not block:
                    return None
                self._cond.wait()

    def _run(self, w: _Worker) -> None:
        pending = None                 # (batch, lazy device result)
        while True:
            nxt = self._next(w, block=pending is None)
            if nxt is None:            # idle: finish the in-flight batch
                self._finish(w, *pending)
                pending = None
                continue
            if nxt is _STOP:
                if pending is not None:
                    self._finish(w, *pending)
                return
            out = self._dispatch_one(w, nxt)
            if pending is not None:
                self._finish(w, *pending)
                pending = None
            if out is not None:        # dispatch succeeded
                if self._pipeline > 1:
                    pending = (nxt, out)    # overlap wait with next dispatch
                else:
                    self._finish(w, nxt, out)

    def _dispatch_one(self, w: _Worker, batch: Batch):
        """Async-dispatch ``batch`` on this worker's device. Returns the
        lazy device result, or None after routing a failure to ``done``."""
        batch.t_dispatch = self._clock.now()
        with self._lock:
            w.inflight_frames += batch.n
            w.inflight.append(batch)
        exe = batch.hosted.bound[w.index]
        name = batch.hosted.name

        def default():
            return exe.run_padded(batch.frames, batch.bucket)

        try:
            if self._execute_hook is not None:
                return self._execute_hook(name, w.index, batch.frames,
                                          batch.bucket, default)
            return default()
        except Exception as e:          # noqa: BLE001 — isolate the batch
            self._fail(w, batch, e)
            return None

    def _finish(self, w: _Worker, batch: Batch, out) -> None:
        """Block until the device result is ready; hand it to ``done``."""
        try:
            out_np = np.asarray(out)
        except Exception as e:          # noqa: BLE001 — isolate the batch
            self._fail(w, batch, e)
            return
        t_ready = self._clock.now()
        with self._lock:
            w.inflight_frames -= batch.n
            w.inflight.remove(batch)
        # clamp the busy interval to this device's previous completion:
        # a pipelined batch was dispatched while its predecessor still
        # ran, but the device itself is serial — without the clamp the
        # device lane's spans would overlap and busy_s would double-
        # count the overlap (occupancy > 1)
        t_busy0 = batch.t_dispatch
        if w.last_ready is not None and w.last_ready > t_busy0:
            t_busy0 = w.last_ready
        w.last_ready = t_ready
        w.batches.inc()
        w.frames.inc(batch.n)
        w.busy_s.add(t_ready - t_busy0)
        if obs.recording():
            obs.span_at("serve.device.execute", t_busy0, t_ready,
                        attrs={"device": w.index,
                               "program": batch.hosted.name,
                               "bucket": batch.bucket, "frames": batch.n,
                               "queued_ms":
                                   (t_busy0 - batch.t_dispatch) * 1e3},
                        lane_tid=_DEVICE_LANE_BASE + w.index,
                        lane=f"device{w.index}")
        self._done.put(Done(batch, w.index, out_np, None, t_ready))

    def _fail(self, w: _Worker, batch: Batch, exc: BaseException) -> None:
        with self._lock:
            w.inflight_frames -= batch.n
            w.inflight.remove(batch)
        w.failures.inc()
        err = WorkerError(
            f"device {w.index} failed executing a bucket-{batch.bucket} "
            f"batch of {batch.hosted.name!r}: {exc}",
            program=batch.hosted.name, device=w.index)
        err.__cause__ = exc
        if obs.recording():
            obs.event("serve.pool.failure",
                      attrs={"device": w.index,
                             "program": batch.hosted.name,
                             "error": type(exc).__name__})
        self._done.put(Done(batch, w.index, None, err, self._clock.now()))

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """JSON-able pool snapshot for ``Server.stats()``: per-device
        batch/frame/steal/failure counts, in-flight frames, busy seconds
        and occupancy (busy / wall since start), plus pool-wide steal
        count and the placement-latency histogram summary."""
        wall = None
        if self._t_start is not None:
            wall = max(self._clock.now() - self._t_start, 1e-9)
        with self._lock:
            per_device = [{
                "device": w.index,
                "batches": w.batches.get(),
                "frames": w.frames.get(),
                "steals": w.steals.get(),
                "failures": w.failures.get(),
                "queued_frames": w.queued_frames,
                "inflight_frames": w.inflight_frames,
                "busy_s": w.busy_s.get(),
                "occupancy": (w.busy_s.get() / wall if wall else 0.0),
            } for w in self._workers]
        return {
            "devices": len(self._workers),
            "placement": type(self._policy).__name__,
            "pipeline": self._pipeline,
            "steals": self._steals.get(),
            "placement_us": self._placement_us.summary(),
            "per_device": per_device,
        }
