"""repro.serve — production serving runtime over compiled Executables.

Turns any :class:`repro.Program` / :class:`repro.Executable` into a
long-lived service: a multi-program router with an async micro-batching
scheduler (collect up to ``max_batch`` / ``max_wait_ms``, pad to the
nearest compiled batch bucket, split results per request — bit-identical
to direct per-request ``Executable.run``), a device pool fanning batches
across N local devices (least-loaded placement with work stealing,
per-device pipelining; ``ServeConfig(devices=N)``), bounded-queue
admission control with backpressure, deadline-based shedding, and a
stats snapshot API (p50/p95/p99 latency, achieved frames/s, padding
waste, per-device occupancy, modeled device kFPS/W). See docs/serving.md.

    from repro import serve

    with serve.Server(serve.ServeConfig(max_batch=16, devices=4)) as _:
        ...   # register before start; or the explicit form:

    server = serve.Server()
    server.register("lenet", repro.Program.from_model("lenet"))
    server.start()
    out = server.submit("lenet", frame).result()
    print(server.stats()["programs"]["lenet"]["latency_ms"])
    server.stop()
"""

from repro.serve.admin import AdminServer
from repro.serve.batcher import (padded_slots, pick_bucket,
                                 power_of_two_buckets, should_close_early,
                                 split_results)
from repro.serve.clock import Clock, VirtualClock
from repro.serve.loadgen import LoadReport, poisson_load, saturate
from repro.serve.metrics import ProgramMetrics, format_stats, latency_summary
from repro.serve.pool import (PLACEMENTS, LeastLoaded, Pool, RoundRobin,
                              WorkerError)
from repro.serve.server import (AdmissionError, DeadlineExceeded, Hooks,
                                HostedProgram, ServeConfig, Server,
                                ServerClosed)

__all__ = [
    "AdminServer", "AdmissionError", "Clock", "DeadlineExceeded", "Hooks",
    "HostedProgram", "LeastLoaded", "LoadReport", "PLACEMENTS", "Pool",
    "ProgramMetrics", "RoundRobin", "ServeConfig", "Server", "ServerClosed",
    "VirtualClock", "WorkerError", "format_stats", "latency_summary",
    "padded_slots", "pick_bucket", "poisson_load", "power_of_two_buckets",
    "saturate", "should_close_early", "split_results",
]
