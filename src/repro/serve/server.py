"""The serving runtime: a multi-program router + async micro-batching
scheduler over a pool of device-bound :class:`repro.Executable`\\ s.

Architecture (scheduler + N device workers + completer, plus callers)::

    submit() ──> per-program FIFO queues ──> scheduler ──placement──┐
    (any thread;   bounded: admission         (collect, shed,       │
     returns a      control + back-            pad to bucket)       v
     Future)        pressure)              per-device queues + workers
                                            (steal when idle; device-
                                             bound exe; double-buffered)
                                                       │
                                   shared done queue ──┴──> completer
                                                            (split,
                                                             fulfill,
                                                             metrics)

* **Micro-batching** — the scheduler picks the program whose head request
  is oldest, then holds the batch open up to ``max_wait_ms`` (measured
  from that head request's arrival) or until ``max_batch`` frames are
  collected, whichever comes first. The batch is padded to the nearest
  compiled bucket and executed with *per-frame* CRC calibration
  (``Executable.run_padded``), which makes coalescing and padding
  provably invisible to every request: results are bit-identical to
  per-request ``Executable.run`` calls.
* **Device pool** — ``ServeConfig(devices=N)`` warms one device-bound
  view of every hosted executable per local device
  (``Executable.bind``); closed batches are placed by a pluggable policy
  (least-loaded with rotating ties by default) onto per-device queues,
  idle workers steal from backlogged peers, and each worker overlaps its
  device wait with the next dispatch (``max_inflight`` is the per-device
  pipeline depth). Per-frame calibration makes device placement exactly
  as invisible as padding — see ``serve.pool``.
* **Admission control + backpressure** — the total queued frame count is
  bounded by ``max_queue``: ``submit(block=False)`` raises
  :class:`AdmissionError` when full, ``block=True`` (default) applies
  backpressure to the producer instead.
* **Deadline shedding** — a request carrying ``deadline_ms`` that is
  already past due when its batch is formed is dropped with
  :class:`DeadlineExceeded` instead of burning device time on a result
  nobody is waiting for.
* **Test seams** — every timestamp and timed wait goes through an
  injectable :class:`~repro.serve.clock.Clock` (a
  :class:`~repro.serve.clock.VirtualClock` makes the timing tests
  deterministic), and :class:`Hooks` exposes the batch-close decision
  and the device execute call (fault injection, emulated devices).

Thread-safety notes: the kernel backend/interpret pins are per-thread
(``kernels.dispatch``), so a pool worker pinning an Executable's backend
cannot clobber concurrent callers; all metrics are lock-guarded.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue as queue_mod
import threading
from collections import deque
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.program import Executable, Options, Program
from repro.obs.slo import SLO, SLOMonitor
from repro.serve import batcher, pool as pool_mod
from repro.serve.clock import Clock
from repro.serve.metrics import ProgramMetrics, now

# Chrome-trace lane ids for per-request timelines: each request's
# queue-wait -> batch-assembly -> device -> split spans are recorded
# retrospectively (their life crosses three threads), so they go on a
# synthetic per-request lane instead of overlapping any live thread's
# span stack (see obs.trace).
_REQ_LANE_BASE = 1 << 20


class AdmissionError(RuntimeError):
    """The bounded request queue is full (non-blocking submit, or the
    blocking wait timed out)."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before the device got to it."""


class ServerClosed(RuntimeError):
    """The server is stopped (or stopping) and not accepting work."""


@dataclasses.dataclass
class Hooks:
    """Injectable observation/override points for tests and benchmarks.

    ``batch_close``  called by the scheduler the moment a micro-batch
                     stops collecting, with ``(program, reason, frames)``
                     where reason is one of ``"full"`` (hit the batch
                     cap), ``"speculative"`` (a device was idle),
                     ``"window"`` (``max_wait_ms`` elapsed) or ``"stop"``
                     (server draining). Lets tests assert *why* a batch
                     closed instead of racing wall-clock timings.
    ``execute``      wraps every device execution: called as
                     ``execute(program, device, frames, bucket, default)``
                     where ``default()`` runs the real device-bound
                     executable. Return a result array to substitute it,
                     call ``default()`` to pass through, or raise to
                     fault-inject exactly that batch (the pool converts
                     it to a typed :class:`~repro.serve.pool.WorkerError`
                     on just that batch's requests).
    """

    batch_close: Optional[Callable[[str, str, int], None]] = None
    execute: Optional[Callable] = None


@dataclasses.dataclass
class ServeConfig:
    """Scheduler/queue knobs for a :class:`Server`.

    ``max_batch``      largest device batch a micro-batch may collect (and
                       the top of the default bucket ladder).
    ``max_wait_ms``    how long the scheduler holds a batch open for more
                       requests, measured from its oldest request's
                       arrival. 0 dispatches every request immediately.
    ``max_queue``      admission bound, in *frames*, summed across all
                       hosted programs.
    ``max_inflight``   per-device pipeline depth: batches dispatched to
                       one device but not yet completed (>= 2 overlaps
                       the device wait with the next dispatch; 1 runs
                       each device synchronously).
    ``batch_buckets``  default compiled batch sizes per program (``None``:
                       powers of two up to ``max_batch``).
    ``default_deadline_ms``  deadline applied to requests that don't carry
                       their own (``None``: no deadline).
    ``speculative_close``  dispatch a collecting batch as soon as the queue
                       is drained and some device is idle, instead of
                       waiting out ``max_wait_ms`` — the hold-open window
                       only helps while every device is busy, so on an
                       idle pool it is pure added latency
                       (``batcher.should_close_early``).
    ``devices``        device-pool width: warm one bound executable per
                       local device and fan batches out across them
                       (``None``/1 = single device, exactly the PR-5
                       runtime). Validated against the actual local
                       device count at :meth:`Server.start`.
    ``placement``      placement policy name (``"least_loaded"`` or
                       ``"round_robin"``; see ``serve.pool.PLACEMENTS``).
                       A policy *object* can be injected via
                       ``Server(placement=...)``.
    ``admin_port``     serve the ops endpoint (``/metrics`` ``/healthz``
                       ``/readyz`` ``/statusz`` ``/tracez`` — see
                       ``serve.admin``) on this port for the server's
                       lifetime. ``0`` binds an ephemeral port (read it
                       from ``Server.admin.port``); ``None`` (default)
                       disables the endpoint.
    ``admin_host``     bind address for the ops endpoint (loopback by
                       default — fleet schedulers probe via a sidecar).
    ``log_path``       structured JSON-lines event log destination
                       (``None``: in-memory tail only; see ``obs.log``).
    ``flight_dump_dir``  directory for automatically triggered flight-
                       recorder dumps (SLO breach / worker failure /
                       stop-timeout stranding). ``None`` keeps dumps
                       in-memory only (``Server.flight_dumps()``).
    ``flight_dump_interval_s``  rate limit between automatic dumps — a
                       sustained breach must not turn the black box into
                       a disk firehose; suppressed triggers are counted.
    ``flight_dump_keep``  how many dumps the in-memory ring retains.
    """

    max_batch: int = 8
    max_wait_ms: float = 2.0
    max_queue: int = 256
    max_inflight: int = 2
    batch_buckets: Optional[Tuple[int, ...]] = None
    default_deadline_ms: Optional[float] = None
    speculative_close: bool = True
    devices: Optional[int] = None
    placement: str = "least_loaded"
    admin_port: Optional[int] = None
    admin_host: str = "127.0.0.1"
    log_path: Optional[str] = None
    flight_dump_dir: Optional[str] = None
    flight_dump_interval_s: float = 30.0
    flight_dump_keep: int = 4

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {self.max_inflight}")
        if self.devices is not None and self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.placement not in pool_mod.PLACEMENTS:
            raise ValueError(
                f"unknown placement {self.placement!r}; known: "
                f"{sorted(pool_mod.PLACEMENTS)}")
        if self.admin_port is not None and not (0 <= self.admin_port <= 65535):
            raise ValueError(
                f"admin_port must be in [0, 65535], got {self.admin_port}")
        if self.flight_dump_interval_s < 0:
            raise ValueError(
                f"flight_dump_interval_s must be >= 0, got "
                f"{self.flight_dump_interval_s}")
        if self.flight_dump_keep < 1:
            raise ValueError(
                f"flight_dump_keep must be >= 1, got {self.flight_dump_keep}")


@dataclasses.dataclass
class _Request:
    frames: np.ndarray                # [n, H, W, C]
    n: int
    future: Future
    t_submit: float
    deadline: Optional[float]         # absolute, server-clock seconds
    trace_id: str = ""                # per-request id, spans every thread
    seq: int = 0                      # request ordinal (trace lane id)


@dataclasses.dataclass
class HostedProgram:
    """One program slot in the router: executable + queue + metrics.

    ``bound`` is the pool's view: one executable per device. With one
    device it is the original (unbound) executable — byte-for-byte the
    PR-5 single-device path, ``Options(shard_batch=True)`` included;
    with N devices each entry is an ``Executable.bind(device)`` view
    sharing the same compiled plan.
    """

    name: str
    program: Program
    executable: Executable
    buckets: Tuple[int, ...]
    queue: deque = dataclasses.field(default_factory=deque)
    metrics: ProgramMetrics = dataclasses.field(default_factory=ProgramMetrics)
    bound: Tuple[Executable, ...] = ()
    slo: Optional[SLOMonitor] = None  # rolling-window objectives (obs.slo)

    @property
    def queued_frames(self) -> int:
        return self.metrics.queued_frames


_SENTINEL = object()
_UNSET = object()


def _settle(future: Future, result=_UNSET,
            exc: Optional[BaseException] = None) -> bool:
    """Resolve ``future`` exactly once; False if it was already settled.

    A timed-out :meth:`Server.stop` fails stranded batches from the
    caller's thread while a wedged worker may still complete them and
    route a late ``Done`` through the completer — both sides settle
    through here so whichever runs second is a recorded no-op instead of
    an ``InvalidStateError`` crash (and metrics only count the winner).
    """
    try:
        if exc is not None:
            future.set_exception(exc)
        else:
            future.set_result(result)
        return True
    except InvalidStateError:
        return False


class Server:
    """Long-lived multi-program serving runtime (see module docstring).

    Usage::

        server = serve.Server(serve.ServeConfig(max_batch=16, devices=4))
        server.register("edge", repro.Program.from_pipeline("edge_detect",
                                                            64, 64, 3),
                        repro.Options(backend="reference"))
        server.register("lenet", repro.Program.from_model("lenet"))
        server.start()                        # warms every device x bucket
        fut = server.submit("edge", frame)    # concurrent.futures.Future
        edges = fut.result()
        print(server.stats()["programs"]["edge"]["latency_ms"])
        server.stop()

    ``Server`` is also a context manager (``with serve.Server(...) as s:``
    starts on enter, drains and stops on exit). Futures resolve to numpy
    arrays; asyncio callers wrap them with ``asyncio.wrap_future``.

    ``clock``, ``hooks`` and ``placement`` are test/bench seams: an
    injectable time source (:class:`~repro.serve.clock.VirtualClock`),
    batch-close/execute hooks (:class:`Hooks`), and a placement policy
    object overriding ``config.placement``.
    """

    def __init__(self, config: Optional[ServeConfig] = None, *,
                 clock: Optional[Clock] = None,
                 hooks: Optional[Hooks] = None,
                 placement=None):
        self.config = config or ServeConfig()
        self._clock = clock or Clock()
        self._hooks = hooks or Hooks()
        self._ndev = self.config.devices or 1
        self._placement = (placement if placement is not None
                           else pool_mod.PLACEMENTS[self.config.placement]())
        self._programs: Dict[str, HostedProgram] = {}
        self._cond = threading.Condition()
        self._queued_total = 0                 # frames across all programs
        self._active_batches = 0               # dispatched, not yet completed
        self._stopping = False
        self._drain = True
        self._started = False
        self._warmed = False
        self._scheduler: Optional[threading.Thread] = None
        self._completer: Optional[threading.Thread] = None
        self._pool: Optional[pool_mod.Pool] = None
        self._done: queue_mod.Queue = queue_mod.Queue()
        self._req_seq = itertools.count()
        self.log = obs.StructuredLog(path=self.config.log_path)
        self.admin = None                      # serve.admin.AdminServer
        # automatic flight-dump state (SLO breach / worker failure /
        # stop-timeout): rate-limited, in-memory ring + optional files
        self._dump_lock = threading.Lock()
        self._flight_dumps: deque = deque(maxlen=self.config.flight_dump_keep)
        self._last_dump_t: Optional[float] = None
        self._dump_seq = 0
        self._dumps_suppressed = 0
        self._last_dump_reason: Optional[str] = None

    # -- lifecycle ---------------------------------------------------------

    def register(self, name: str, program: Program,
                 options: Optional[Options] = None,
                 buckets: Optional[Sequence[int]] = None,
                 slo: Optional[SLO] = None) -> HostedProgram:
        """Host ``program`` under ``name``: compiles it now (plan-cache
        priming happens at registration, jit warm-up at :meth:`start`).

        ``slo`` declares rolling-window objectives for this program
        (:class:`obs.SLO`); a breach increments ``slo.breach.<name>``,
        logs a structured event and triggers a rate-limited flight dump.
        """
        if self._started:
            raise RuntimeError("register() before start()")
        if name in self._programs:
            raise ValueError(f"program {name!r} already registered")
        exe = program.compile(options or Options())
        bks = tuple(sorted({int(b) for b in buckets})) if buckets else \
            (self.config.batch_buckets
             or batcher.power_of_two_buckets(self.config.max_batch))
        if min(bks) < 1:
            raise ValueError(f"buckets must be >= 1, got {bks}")
        hosted = HostedProgram(name, program, exe, bks,
                               metrics=ProgramMetrics(name=name),
                               slo=SLOMonitor(name, slo) if slo else None)
        self._programs[name] = hosted
        return hosted

    def start(self, warm: bool = True) -> "Server":
        """Launch the device pool + scheduler/completer threads.

        Binds every hosted executable to each pool device
        (``Executable.bind`` — shared compiled plan, per-device placement
        caches and donated/reused buffers where safe) and, with ``warm``,
        pre-traces every (device, bucket) pair so the first real requests
        don't pay jit latency — the plan-cache/trace priming a production
        rollout does before taking traffic.
        """
        if self._started:
            raise RuntimeError("server already started")
        if not self._programs:
            raise RuntimeError("no programs registered")
        if self._ndev > 1:
            import jax
            local = jax.local_devices()
            if self._ndev > len(local):
                raise ValueError(
                    f"devices={self._ndev} but only {len(local)} local "
                    f"device(s); on CPU set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={self._ndev}")
            for hosted in self._programs.values():
                # staging ring depth matches the per-device pipeline: a
                # worker may have max_inflight batches dispatched but
                # unawaited, each still reading its staging buffer
                hosted.bound = tuple(
                    hosted.executable.bind(
                        d, staging_slots=max(2, self.config.max_inflight))
                    for d in local[:self._ndev])
        else:
            # single device: keep the *unbound* executable, preserving
            # the exact PR-5 path (Options(shard_batch=True) included)
            for hosted in self._programs.values():
                hosted.bound = (hosted.executable,)
        if warm:
            for hosted in self._programs.values():
                for exe in hosted.bound:
                    exe.warm(hosted.buckets)
        self._warmed = warm
        self._pool = pool_mod.Pool(
            self._ndev, self._placement, self._done, clock=self._clock,
            execute_hook=self._hooks.execute,
            pipeline=self.config.max_inflight)
        self._started = True
        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="repro-serve-scheduler",
            daemon=True)
        self._completer = threading.Thread(
            target=self._completer_loop, name="repro-serve-completer",
            daemon=True)
        self._pool.start()
        self._completer.start()
        self._scheduler.start()
        if self.config.admin_port is not None:
            from repro.serve.admin import AdminServer
            self.admin = AdminServer(self, port=self.config.admin_port,
                                     host=self.config.admin_host).start()
        self.log.info("serve.start", devices=self._ndev,
                      programs=sorted(self._programs),
                      admin_port=self.admin.port if self.admin else None)
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop the server. ``drain=True`` serves everything already
        queued first; ``drain=False`` fails pending requests with
        :class:`ServerClosed`. A finite ``timeout`` bounds every join:
        batches a wedged device still holds when it expires are failed
        with :class:`ServerClosed` rather than left stranded (no caller
        blocks forever on ``result()``)."""
        with self._cond:
            self._stopping = True
            self._drain = drain
            self._cond.notify_all()
        if self._scheduler is not None:
            self._scheduler.join(timeout)
            if not self._scheduler.is_alive():
                # retire the pool only once the scheduler can no longer
                # dispatch; when every worker joins, Pool.stop guarantees
                # every dispatched batch's completion is on the done
                # queue before returning, so the sentinel cannot overtake
                # a live completion and strand its futures unresolved. A
                # finite timeout voids that guarantee — reclaim whatever
                # a still-running worker holds and fail it (idempotently:
                # the worker may yet complete an in-flight batch) before
                # the sentinel retires the completer.
                if self._pool is not None:
                    self._pool.stop(timeout)
                    if self._pool.alive():
                        self._fail_stranded()
                self._done.put(_SENTINEL)
                if self._completer is not None:
                    self._completer.join(timeout)
        if not drain:
            with self._cond:
                for hosted in self._programs.values():
                    while hosted.queue:
                        req = hosted.queue.popleft()
                        hosted.metrics.add_queued(-req.n)
                        self._queued_total -= req.n
                        if _settle(req.future,
                                   exc=ServerClosed("server stopped")):
                            hosted.metrics.record_failed()
                self._cond.notify_all()    # release backpressured submitters
        # the ops endpoint outlives the serving threads so a probe during
        # shutdown sees "unhealthy", then goes down last
        if self.admin is not None:
            self.admin.stop(timeout)
        self.log.info("serve.stop", drain=drain)

    def _fail_stranded(self) -> None:
        """Fail every batch a timed-out pool shutdown left behind.

        Queued batches were removed from the worker queues (they can
        never reach the done queue); in-flight batches may still finish
        on the wedged worker, so both sides settle each future through
        :func:`_settle` and only the winner is counted in metrics.
        """
        queued, inflight = self._pool.take_outstanding()
        for batch in queued + inflight:
            failed = sum(
                1 for req in batch.live
                if _settle(req.future, exc=ServerClosed(
                    f"server stopped before the pool drained (stop "
                    f"timeout expired with a batch of "
                    f"{batch.hosted.name!r} outstanding)")))
            if failed:
                batch.hosted.metrics.record_failed(failed)
        if queued or inflight:
            self.log.error("serve.stop.stranded",
                           queued=len(queued), inflight=len(inflight))
            self._flight_dump("stop_timeout")
        if queued:
            # queued batches produce no Done, so the completer will never
            # run its active-batch decrement for them
            with self._cond:
                self._active_batches -= len(queued)
                self._cond.notify_all()

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    # -- request path ------------------------------------------------------

    def submit(self, name: str, frames, deadline_ms: Optional[float] = None,
               block: bool = True, timeout: Optional[float] = None) -> Future:
        """Enqueue ``frames`` ([H, W, C] or [n, H, W, C]) for ``name``.

        Returns a ``concurrent.futures.Future`` resolving to the program's
        output for exactly those frames (numpy, batch-first) — bit-identical
        to a direct per-request ``Executable.run``. Raises
        :class:`AdmissionError` when the bounded queue is full
        (``block=False``, or the backpressure wait exceeds ``timeout``),
        :class:`ServerClosed` after :meth:`stop`, and ``ValueError`` for an
        unknown program or a frame-shape mismatch — all in the caller's
        thread, before anything is queued.
        """
        hosted = self._programs.get(name)
        if hosted is None:
            raise ValueError(f"unknown program {name!r}; hosted: "
                             f"{sorted(self._programs)}")
        frames = np.asarray(frames, np.float32)
        if frames.ndim == 3:
            frames = frames[None]
        hwc = tuple(hosted.program.input_hwc)
        if frames.ndim != 4 or tuple(frames.shape[1:]) != hwc:
            raise ValueError(
                f"frames {frames.shape} do not match {name!r}'s input "
                f"[n, {', '.join(map(str, hwc))}]")
        n = frames.shape[0]
        if n == 0:
            raise ValueError("request carries no frames")
        if n > self.config.max_queue:
            # larger than the whole admission bound: the blocking wait
            # below could never be satisfied — fail fast instead
            raise ValueError(
                f"request of {n} frames exceeds max_queue="
                f"{self.config.max_queue}; raise the bound or split the "
                f"request")
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        t_submit = self._clock.now()
        seq = next(self._req_seq)
        req = _Request(frames, n, Future(), t_submit,
                       t_submit + deadline_ms / 1e3
                       if deadline_ms is not None else None,
                       trace_id=f"{name}/req-{seq}", seq=seq)
        if obs.recording():
            obs.event("serve.submit", attrs={"program": name, "frames": n},
                      trace_id=req.trace_id)
        with self._cond:
            while (self._queued_total + n > self.config.max_queue
                   and not self._stopping):
                if not block:
                    hosted.metrics.record_reject()
                    raise AdmissionError(
                        f"queue full ({self._queued_total} frames >= "
                        f"{self.config.max_queue})")
                if not self._clock.wait(self._cond, timeout):
                    hosted.metrics.record_reject()
                    raise AdmissionError(
                        f"queue full after {timeout}s backpressure wait")
            if self._stopping:
                raise ServerClosed("server is stopping")
            hosted.queue.append(req)
            hosted.metrics.add_queued(n)
            self._queued_total += n
            hosted.metrics.record_admit()
            self._cond.notify_all()
        return req.future

    # -- scheduler ---------------------------------------------------------

    def _collect(self) -> Optional[Tuple[HostedProgram, list, str]]:
        """One scheduling decision: pick a program, hold the batch open,
        pop it. Returns (hosted, requests, close_reason), or None when
        stopping with nothing left to drain."""
        cfg = self.config
        with self._cond:
            while True:
                if self._stopping and not self._drain:
                    return None
                backlog = [h for h in self._programs.values() if h.queue]
                if backlog:
                    break
                if self._stopping:
                    return None
                self._cond.wait()
            # route: the program whose head request has waited longest
            hosted = min(backlog, key=lambda h: h.queue[0].t_submit)
            cap = min(cfg.max_batch, max(hosted.buckets))
            close_at = hosted.queue[0].t_submit + cfg.max_wait_ms / 1e3
            reason = None
            while (hosted.metrics.queued_frames < cap
                   and not self._stopping):
                # speculative close: with an idle device in the pool,
                # waiting for more frames is pure added latency —
                # dispatch what we have
                if batcher.should_close_early(hosted.metrics.queued_frames,
                                              cap, self._active_batches,
                                              cfg.speculative_close,
                                              devices=self._ndev):
                    reason = "speculative"
                    break
                remaining = close_at - self._clock.now()
                if remaining <= 0:
                    reason = "window"
                    break
                self._clock.wait(self._cond, remaining)
            if reason is None:
                reason = ("full" if hosted.metrics.queued_frames >= cap
                          else "stop")
            reqs, n = [], 0
            while hosted.queue and n + hosted.queue[0].n <= cap:
                req = hosted.queue.popleft()
                reqs.append(req)
                n += req.n
            if not reqs and hosted.queue:
                # head request alone exceeds the cap: dispatch it solo
                # (run_padded chunks it through the largest bucket)
                reqs = [hosted.queue.popleft()]
                n = reqs[0].n
            hosted.metrics.add_queued(-n)
            self._queued_total -= n
            self._cond.notify_all()        # wake backpressured submitters
        return hosted, reqs, reason

    def _scheduler_loop(self) -> None:
        while True:
            picked = self._collect()
            if picked is None:
                return
            hosted, reqs, reason = picked
            t_closed = self._clock.now()   # batch stopped collecting here
            if self._hooks.batch_close is not None:
                self._hooks.batch_close(hosted.name, reason,
                                        sum(r.n for r in reqs))
            # deadline shedding: drop what is already past due
            t = self._clock.now()
            live = []
            for req in reqs:
                if req.deadline is not None and t > req.deadline:
                    # both sides of every settle race go through _settle
                    # (a timed-out stop() or an external cancel may have
                    # resolved this future already); metrics count only
                    # the winner
                    if _settle(req.future, exc=DeadlineExceeded(
                            f"deadline missed by "
                            f"{(t - req.deadline) * 1e3:.1f}ms "
                            f"waiting for dispatch")):
                        hosted.metrics.record_shed()
                        self._observe_slo(hosted, "shed", t)
                else:
                    live.append(req)
            if not live:
                continue
            frames = (live[0].frames if len(live) == 1
                      else np.concatenate([r.frames for r in live], axis=0))
            bucket = batcher.pick_bucket(frames.shape[0], hosted.buckets)
            with self._cond:
                self._active_batches += 1      # a device busy until done
            # hand off to the pool without touching the device: placement
            # picks a worker, the worker dispatches + blocks, and the
            # completer resolves futures off the shared done queue
            self._pool.dispatch(pool_mod.Batch(
                hosted, live, frames, bucket, frames.shape[0], t_closed))

    def _completer_loop(self) -> None:
        while True:
            item = self._done.get()
            if item is _SENTINEL:
                return
            batch, live, hosted = item.batch, item.batch.live, item.batch.hosted
            try:
                if item.error is not None:
                    failed = sum(1 for req in live
                                 if _settle(req.future, exc=item.error))
                    if failed:
                        hosted.metrics.record_failed(failed)
                    t_fail = self._clock.now()
                    for _ in range(failed):
                        self._observe_slo(hosted, "failed", t_fail)
                    self.log.error(
                        "serve.worker.failure", program=hosted.name,
                        device=item.device, requests=failed,
                        error=str(item.error))
                    # a worker failure is exactly the incident the black
                    # box exists for: capture the moments before it
                    self._flight_dump(f"worker_error:{hosted.name}")
                    continue
                hosted.metrics.record_batch(
                    batcher.padded_slots(batch.n, batch.bucket),
                    batch.t_dispatch, frames=batch.n)
                for part, req in zip(
                        batcher.split_results(item.out, [r.n for r in live]),
                        live):
                    if not _settle(req.future, result=part):
                        # a timed-out stop() already failed this request;
                        # the late completion is a no-op, not a crash
                        continue
                    t_done = self._clock.now()
                    hosted.metrics.record_served(t_done - req.t_submit, req.n,
                                                 t_done)
                    self._observe_slo(hosted, "served", t_done,
                                      latency_ms=(t_done - req.t_submit) * 1e3)
                    if obs.recording():
                        self._emit_request_timeline(
                            hosted, req, batch.bucket, item.device,
                            batch.t_closed, batch.t_dispatch, item.t_ready,
                            t_done)
            finally:
                # a device is idle again: wake a scheduler holding a batch
                # open (speculative close) and any backpressured submitters
                with self._cond:
                    self._active_batches -= 1
                    self._cond.notify_all()

    @staticmethod
    def _emit_request_timeline(hosted: HostedProgram, req: _Request,
                               bucket: int, device: int, t_closed: float,
                               t_dispatch: float, t_ready: float,
                               t_done: float) -> None:
        """Stitch one request's end-to-end latency decomposition into the
        trace: queue-wait -> batch-assembly -> device -> split, all
        carrying the request's ``trace_id`` on its own synthetic lane, so
        the exported Chrome trace shows one contiguous row per request
        even though the spans were measured on three different threads.
        The device phase carries the pool device index that executed it.
        """
        lane = _REQ_LANE_BASE + req.seq
        attrs = {"program": hosted.name, "frames": req.n, "bucket": bucket,
                 "device": device}
        for name, t0, t1 in (
                ("serve.request.queue_wait", req.t_submit, t_closed),
                ("serve.request.batch_assembly", t_closed, t_dispatch),
                ("serve.request.device", t_dispatch, t_ready),
                ("serve.request.split", t_ready, t_done)):
            obs.span_at(name, t0, t1, attrs=attrs, trace_id=req.trace_id,
                        lane_tid=lane, lane=req.trace_id)

    # -- SLOs + incident capture -------------------------------------------

    def _observe_slo(self, hosted: HostedProgram, kind: str, t: float,
                     latency_ms: Optional[float] = None) -> None:
        """Feed one request outcome to the program's SLO monitor (if
        any); every breach report the evaluation returns is handled."""
        if hosted.slo is None:
            return
        for breach in hosted.slo.observe(kind, t, latency_ms=latency_ms):
            self._handle_breach(hosted, breach)

    def _handle_breach(self, hosted: HostedProgram, breach: Dict) -> None:
        """One SLO breach: counter + structured log + flight dump."""
        obs.counter(f"slo.breach.{hosted.name}").inc()
        obs.event("serve.slo.breach",
                  attrs={"program": hosted.name, **breach})
        self.log.warning("serve.slo.breach", program=hosted.name, **breach)
        self._flight_dump(
            f"slo:{hosted.name}:{breach['objective']}", detail=breach)

    def _flight_dump(self, reason: str,
                     detail: Optional[Dict] = None) -> Optional[Dict]:
        """Dump the flight recorder, rate-limited by
        ``config.flight_dump_interval_s``. Returns the dump dict, or
        None when no recorder is installed / the limiter suppressed it.

        The ``flight.trigger`` instant event is recorded *before* the
        dump so the dump itself proves where in the retained history the
        incident sits (``check_trace.py --flight`` requires spans from
        before the trigger).
        """
        fl = obs.get_flight()
        if fl is None:
            return None
        t = self._clock.now()
        with self._dump_lock:
            if (self._last_dump_t is not None
                    and t - self._last_dump_t
                    < self.config.flight_dump_interval_s):
                self._dumps_suppressed = self._dumps_suppressed + 1
                return None
            self._last_dump_t = t
            self._last_dump_reason = reason
            self._dump_seq = self._dump_seq + 1
            seq = self._dump_seq
        obs.event("flight.trigger", attrs={"reason": reason,
                                           **(detail or {})})
        dump = fl.dump(reason=reason)
        path = None
        if self.config.flight_dump_dir is not None:
            import json as json_mod
            import os
            slug = "".join(c if c.isalnum() else "-" for c in reason)[:48]
            path = os.path.join(self.config.flight_dump_dir,
                                f"flight-{seq:03d}-{slug}.json")
            os.makedirs(self.config.flight_dump_dir, exist_ok=True)
            with open(path, "w") as f:
                json_mod.dump(dump, f)
        with self._dump_lock:
            self._flight_dumps.append(
                {"seq": seq, "reason": reason, "t": t, "path": path,
                 "records": dump["otherData"]["records"], "dump": dump})
        self.log.info("serve.flight.dump", reason=reason, path=path,
                      records=dump["otherData"]["records"])
        return dump

    def flight_dumps(self) -> list:
        """The retained automatic dumps, oldest first (metadata + dump)."""
        with self._dump_lock:
            return list(self._flight_dumps)

    # -- health + ops surface ----------------------------------------------

    def health(self) -> Dict[str, object]:
        """The ``/healthz`` answer: is every serving thread running?

        Healthy means started, not stopping, and the pool has *all* its
        workers — a pool that lost one of four devices still serves, but
        a fleet scheduler must know it is degraded.
        """
        pool = self._pool
        with self._cond:
            stopping = self._stopping
        checks = {
            "started": self._started,
            "not_stopping": not stopping,
            "scheduler_alive": (self._scheduler is not None
                                and self._scheduler.is_alive()),
            "completer_alive": (self._completer is not None
                                and self._completer.is_alive()),
            "pool_workers": (pool.workers_alive() if pool is not None else 0),
            "pool_size": pool.size if pool is not None else 0,
        }
        healthy = bool(
            checks["started"] and checks["not_stopping"]
            and checks["scheduler_alive"] and checks["completer_alive"]
            and pool is not None and pool.healthy())
        return {"healthy": healthy, "checks": checks}

    def readiness(self) -> Dict[str, object]:
        """The ``/readyz`` answer: healthy *and* able to take traffic —
        buckets warmed (no jit latency on the next request) and the
        admission queue not already full."""
        h = self.health()
        with self._cond:
            depth = self._queued_total
        checks = {
            "warmed": self._warmed,
            "queue_depth": depth,
            "max_queue": self.config.max_queue,
            "queue_has_room": depth < self.config.max_queue,
        }
        ready = bool(h["healthy"] and checks["warmed"]
                     and checks["queue_has_room"])
        return {"ready": ready, "checks": {**h["checks"], **checks}}

    def prometheus_metrics(self) -> str:
        """Every registry this server touches, in one exposition blob:
        the process-wide ``obs.REGISTRY`` (plan cache, conv dispatch,
        SLO breach counters), each hosted program's private registry and
        the pool's per-device registry."""
        parts = [obs.prometheus_text()]
        for hosted in self._programs.values():
            parts.append(obs.prometheus_text(hosted.metrics.registry))
        if self._pool is not None:
            parts.append(obs.prometheus_text(self._pool.registry))
        return "".join(parts)

    # -- observability -----------------------------------------------------

    def stats(self, verbose: bool = False) -> Dict[str, object]:
        """JSON-able snapshot: per-program counters, latency percentiles,
        achieved frames/s, padding waste, queue depth — plus each program's
        modeled device FPS / power / kFPS-per-W from its compiled report,
        the measured-vs-modeled kFPS/W drift, the process-wide plan-cache
        hit rate, per-strategy conv dispatch counts (``repro.obs``) and
        the device pool's per-device occupancy/steal/failure breakdown
        (``"pool"`` — see ``serve.pool.Pool.stats``).

        ``verbose=True`` adds the batch-occupancy / padding-waste
        histograms per program and the full global ``obs`` registry dump
        — the breakdown ``serve.format_stats`` renders as a table.
        """
        from repro.core.plan import plan_cache_stats
        programs = {}
        totals = {"submitted": 0, "served": 0, "shed_deadline": 0,
                  "rejected": 0, "failed": 0}
        frames_served = 0
        for name, hosted in self._programs.items():
            snap = hosted.metrics.snapshot()
            r = hosted.executable.report
            # modeled energy per frame (J) from the power report: the
            # measured-vs-modeled efficiency axis. "Measured" kFPS/W
            # re-uses the modeled device power with the *achieved* rate —
            # the drift isolates host/scheduling losses from the model.
            e_frame = (r.avg_power_w / r.fps) if r.fps else 0.0
            fps = snap["achieved_fps"]
            measured_kfps_per_w = ((fps / 1e3) / r.avg_power_w
                                   if r.avg_power_w else 0.0)
            snap["model"] = {
                "fps": r.fps, "avg_power_w": r.avg_power_w,
                "kfps_per_w": r.kfps_per_w,
                "energy_per_frame_j": e_frame,
                "modeled_energy_j": e_frame * snap["frames_served"],
            }
            snap["measured_kfps_per_w"] = measured_kfps_per_w
            snap["kfps_per_w_drift"] = (measured_kfps_per_w / r.kfps_per_w
                                        if r.kfps_per_w else 0.0)
            snap["buckets"] = list(hosted.buckets)
            if hosted.slo is not None:
                snap["slo"] = hosted.slo.state(self._clock.now())
            if verbose:
                snap["histograms"] = hosted.metrics.histograms()
            programs[name] = snap
            for k in totals:
                totals[k] += snap["requests"][k]
            frames_served += snap["frames_served"]
        with self._cond:
            depth = self._queued_total
        cache = plan_cache_stats()
        lookups = cache["hits"] + cache["misses"]
        strategies = {
            kind: c.get() for kind in ("resident", "strip", "fused",
                                       "reference")
            if (c := obs.REGISTRY.get(f"dispatch.conv.{kind}")) is not None}
        out = {
            "config": dataclasses.asdict(self.config),
            "queue_depth": depth,
            "frames_served": frames_served,
            "requests": totals,
            "plan_cache": {**cache,
                           "hit_rate": (cache["hits"] / lookups
                                        if lookups else 0.0)},
            "conv_dispatch": strategies,
            "programs": programs,
        }
        if self._pool is not None:
            out["pool"] = self._pool.stats()
        with self._dump_lock:
            out["flight"] = {
                "dumps": self._dump_seq,
                "suppressed": self._dumps_suppressed,
                "last_reason": self._last_dump_reason,
                "retained": [{k: v for k, v in d.items() if k != "dump"}
                             for d in self._flight_dumps],
            }
        fl = obs.get_flight()
        if fl is not None:
            out["flight"]["recorder"] = fl.stats()
        if verbose:
            out["obs"] = obs.REGISTRY.snapshot()
        return out
