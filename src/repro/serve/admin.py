"""The ops endpoint: a stdlib HTTP surface over a running Server.

A fleet scheduler (or an operator with ``curl``) needs four answers
from a serving process without attaching a debugger:

    GET /healthz   -> 200/503  is every serving thread running?
    GET /readyz    -> 200/503  ...and can it take traffic right now?
    GET /metrics   -> Prometheus text exposition (every registry the
                      server touches: global + per-program + pool)
    GET /statusz   -> JSON: Server.stats(verbose=True) + per-program
                      fused-segment roster + plan-cache + SLO state +
                      the recent structured-log tail
                      (?format=text renders serve.format_stats instead)
    GET /tracez    -> an on-demand flight-recorder dump (the same
                      Chrome-trace JSON scripts/check_trace.py --flight
                      validates); 503 when no recorder is installed

Zero new dependencies: ``http.server.ThreadingHTTPServer`` with daemon
request threads. Bound to loopback by default (``ServeConfig(
admin_host=)``) — the endpoint exposes operational detail, not user
data, but there is no auth layer, so keep it off public interfaces.

Lifecycle: ``Server.start`` constructs + starts one ``AdminServer``
when ``ServeConfig(admin_port=)`` is set (``0`` = ephemeral, read
``server.admin.port``); ``Server.stop`` shuts it down *after* the
serving threads so a probe during drain observes "unhealthy" instead
of a connection refused that looks like a dead host. The acceptor
thread is joined in :meth:`AdminServer.stop` (the PR-9 concurrency
lint's unjoined-thread rule holds for this module).
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

from repro import obs


class AdminServer:
    """One HTTP acceptor thread serving the ops routes for ``server``."""

    def __init__(self, server, port: int = 0, host: str = "127.0.0.1"):
        self._server = server
        handler = _make_handler(server)
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "AdminServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-serve-admin",
            daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = None) -> None:
        self._httpd.shutdown()
        if self._thread is not None:
            self._thread.join(timeout)
        self._httpd.server_close()


def _make_handler(server):
    """A handler class closed over the Server (BaseHTTPRequestHandler is
    instantiated per request by the HTTP server, so state rides the
    closure, not the instance)."""

    class Handler(BaseHTTPRequestHandler):

        # ops probes arrive every few seconds; stderr access logging
        # would drown the structured log
        def log_message(self, fmt, *args):  # noqa: A003
            pass

        def _send(self, code: int, body: bytes, ctype: str) -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _send_json(self, code: int, payload) -> None:
            self._send(code, json.dumps(payload, default=str).encode(),
                       "application/json")

        def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
            parsed = urlparse(self.path)
            route = parsed.path.rstrip("/") or "/"
            try:
                if route == "/metrics":
                    self._send(200, server.prometheus_metrics().encode(),
                               "text/plain; version=0.0.4")
                elif route == "/healthz":
                    h = server.health()
                    self._send_json(200 if h["healthy"] else 503, h)
                elif route == "/readyz":
                    r = server.readiness()
                    self._send_json(200 if r["ready"] else 503, r)
                elif route == "/statusz":
                    self._statusz(parsed)
                elif route == "/tracez":
                    fl = obs.get_flight()
                    if fl is None:
                        self._send_json(503, {
                            "error": "no flight recorder installed "
                                     "(REPRO_FLIGHT=off?)"})
                    else:
                        self._send_json(200, fl.dump(reason="tracez"))
                else:
                    self._send_json(404, {
                        "error": f"unknown route {route!r}",
                        "routes": ["/metrics", "/healthz", "/readyz",
                                   "/statusz", "/tracez"]})
            except Exception as e:  # noqa: BLE001 — a probe must never hang
                self._send_json(500, {"error": f"{type(e).__name__}: {e}"})

        def _statusz(self, parsed) -> None:
            fmt = parse_qs(parsed.query).get("format", ["json"])[0]
            if fmt == "text":
                from repro.serve.metrics import format_stats
                self._send(200, format_stats(server.stats()).encode(),
                           "text/plain")
                return
            stats = server.stats(verbose=True)
            for name, hosted in server._programs.items():
                stats["programs"][name]["fused_segments"] = \
                    hosted.executable.report.fused_segments
            stats["log_tail"] = server.log.recent(32)
            stats["log_counts"] = server.log.counts()
            self._send_json(200, stats)

    return Handler
