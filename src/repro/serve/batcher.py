"""Micro-batching primitives: batch buckets, padding accounting, splitting.

Pure, thread-free helpers the scheduler (``serve.server``) composes:

* a server compiles each hosted program at a small set of **batch
  buckets** (powers of two up to ``max_batch`` by default) instead of
  jit-tracing every queue length it ever observes;
* a collected micro-batch of ``n`` frames is padded up to the smallest
  bucket that holds it (``Executable.run_padded`` does the zero-padding —
  per-frame calibration makes the pad frames provably inert);
* results come back as one array and are **split** per-request by each
  request's frame count;
* a collecting batch **closes speculatively** (``should_close_early``)
  when the device pipeline is idle — the hold-open window only pays off
  while a previous batch is still computing.

The pad -> bucket -> split round trip is bit-identical to running every
request directly (tests/test_serve.py pins it across odd sizes, mixed
programs and both kernel backends).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro import obs


def power_of_two_buckets(max_batch: int) -> Tuple[int, ...]:
    """The default bucket ladder: 1, 2, 4, ... capped by ``max_batch``.

    ``max_batch`` itself is always a bucket (so a full collection window
    never pays padding), even when it is not a power of two.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    buckets = {max_batch}
    b = 1
    while b < max_batch:
        buckets.add(b)
        b <<= 1
    return tuple(sorted(buckets))


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket holding ``n`` frames; the largest if none does
    (the caller then runs in largest-bucket chunks — ``run_padded``)."""
    if n < 1:
        raise ValueError(f"cannot bucket {n} frames")
    best = max(buckets)
    for b in sorted(buckets):
        if b >= n:
            best = b
            break
    if obs.recording():
        obs.event("batcher.pick_bucket",
                  attrs={"frames": n, "bucket": best,
                         "pad": padded_slots(n, best) - n})
    return best


def padded_slots(n: int, bucket: int) -> int:
    """Device batch slots consumed serving ``n`` real frames at ``bucket``
    (chunked when ``n > bucket``) — the padding-waste numerator's basis."""
    return -(-n // bucket) * bucket


def should_close_early(queued_frames: int, cap: int, inflight_batches: int,
                       speculative: bool = True, devices: int = 1) -> bool:
    """Close a collecting micro-batch now instead of waiting out the window?

    The hold-open window (``max_wait_ms``) exists to let a batch fill while
    the device is busy with the previous one — coalescing there is free.
    When the device pipeline is *idle*, holding the batch open buys nothing:
    every waited millisecond is pure added latency, because the device could
    already be computing. So the scheduler closes speculatively as soon as
    the queue is drained (everything currently queued is collected, i.e. the
    batch stopped growing) and some device is idle — with a pool of
    ``devices`` workers, that is whenever fewer batches are in flight than
    there are devices to run them.

    Pure predicate so the policy is testable without threads; the server
    supplies its live counters and the ``ServeConfig.speculative_close``
    switch.
    """
    return (speculative and inflight_batches < max(devices, 1)
            and 0 < queued_frames < cap)


def split_results(out: np.ndarray, counts: Sequence[int]) -> list:
    """Split a stacked result [sum(counts), ...] back per request."""
    total = int(sum(counts))
    if out.shape[0] != total:
        raise ValueError(
            f"result batch {out.shape[0]} != sum of request sizes {total}")
    if obs.recording():
        obs.event("batcher.split",
                  attrs={"requests": len(counts), "frames": total})
    parts, off = [], 0
    for n in counts:
        parts.append(out[off:off + n])
        off += n
    return parts
