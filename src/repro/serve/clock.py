"""Injectable time source for the serving runtime.

Every timestamp and timed condition-wait inside a :class:`~repro.serve.
Server` goes through one :class:`Clock` object, so tests can substitute a
:class:`VirtualClock` and assert scheduling *decisions* (which close
reason fired, how long the window was held) instead of racing the wall
clock — the deflaking contract for the speculative-close and
window-hold tests in ``tests/test_serve.py``, which used to sleep real
seconds and flake under CI load.

The default :class:`Clock` is ``time.perf_counter`` plus a plain
``Condition.wait`` — byte-for-byte the behaviour the server had before
the seam existed. ``serve.metrics.now()`` remains the module-level
shortcut for callers outside a server (the load generator).
"""

from __future__ import annotations

import threading
import time
from typing import Optional


class Clock:
    """Real time: ``perf_counter`` + real condition waits (the default)."""

    def now(self) -> float:
        """Monotonic seconds — the same clock ``serve.metrics.now`` uses."""
        return time.perf_counter()

    def wait(self, cond: threading.Condition, timeout: Optional[float] = None
             ) -> bool:
        """Wait on ``cond`` (which the caller holds) up to ``timeout``."""
        return cond.wait(timeout)


class VirtualClock(Clock):
    """Deterministic test clock: timed waits advance virtual time instantly.

    * ``now()`` returns the virtual time (starts at ``start`` seconds).
    * A **timed** ``wait`` advances the virtual clock by the full timeout
      and returns without sleeping — so "the scheduler held the batch
      window open for 400 ms" is observable as a 0.4 s virtual-time jump
      that costs the test microseconds of real time.
    * An **untimed** ``wait`` (waiting for work to arrive) blocks for
      real, because the thing it waits for — a submit from another
      thread — happens in real time.

    The jump-on-wait model means a virtual-clocked scheduler never
    coalesces two requests submitted "during" a hold window (the window
    elapses the moment it starts); the deflaked tests assert close
    *reasons* and virtual durations, not coalescing counts.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> float:
        """Manually advance virtual time (e.g. to expire a deadline)."""
        if dt < 0:
            raise ValueError(f"cannot advance time backwards ({dt})")
        with self._lock:
            self._t += dt
            return self._t

    def wait(self, cond: threading.Condition, timeout: Optional[float] = None
             ) -> bool:
        if timeout is None:
            return cond.wait()
        with self._lock:
            self._t += max(timeout, 0.0)
        # poll the condition without sleeping: racing notifies that are
        # already pending still land, but virtual time has moved on
        return cond.wait(0.0)
