"""Open-loop Poisson load generator for the serving runtime.

An *open-loop* generator submits on an arrival-time schedule drawn ahead
of the run (exponential inter-arrival gaps at the offered rate) and never
waits for responses — so, unlike a closed benchmark loop, a slow server
cannot throttle its own offered load. That is the property that makes
latency-vs-offered-load curves honest (coordinated-omission-free): when
the generator falls behind the schedule it submits immediately rather
than silently re-timing the arrivals.

``poisson_load`` drives one hosted program; ``saturate`` is the
closed-world companion (submit everything at once under backpressure)
used to measure a server's service capacity for the batching ablation.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import wait as futures_wait
from typing import Dict, Optional

import numpy as np

from repro.serve.metrics import latency_summary, now
from repro.serve.server import AdmissionError, Server


@dataclasses.dataclass
class LoadReport:
    """What one load run measured (JSON-able via ``dataclasses.asdict``)."""

    program: str
    offered_rps: float          # requests/s the schedule offered
    duration_s: float           # first submit -> last completion
    submitted: int
    served: int
    shed: int                   # deadline-exceeded
    rejected: int               # admission-refused
    achieved_rps: float         # served requests/s over the run
    achieved_fps: float         # served frames/s over the run
    behind_schedule: int        # arrivals the generator hit late (>1ms)
    latency_ms: Dict[str, float]   # submit -> result-ready, client-side


def poisson_load(server: Server, name: str, frames: np.ndarray,
                 rate_rps: float, n_requests: int,
                 frames_per_request: int = 1, seed: int = 0,
                 deadline_ms: Optional[float] = None,
                 block: bool = False,
                 result_timeout_s: float = 120.0) -> LoadReport:
    """Offer ``n_requests`` Poisson arrivals at ``rate_rps`` to ``name``.

    ``frames`` is a host pool [N, H, W, C]; each request takes the next
    ``frames_per_request`` frames (wrapping). ``block=False`` (default)
    keeps the loop open: a full queue counts a rejection instead of
    stalling the schedule. Latency is measured client-side, submit to
    future completion, via done-callbacks — no per-request polling.
    """
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    # materialize every request's payload before the clock starts — the
    # arrival loop must spend its time pacing, not slicing arrays
    payloads = [
        np.take(frames, range(i * frames_per_request,
                              (i + 1) * frames_per_request),
                axis=0, mode="wrap")
        for i in range(n_requests)]

    lock = threading.Lock()
    latencies, shed = [], [0]

    def _done(fut, t_submit):
        with lock:
            if fut.exception() is not None:
                shed[0] += 1
            else:
                latencies.append((now() - t_submit) * 1e3)

    futures, rejected, behind = [], 0, 0
    t_start = now()
    t_next = t_start
    for i in range(n_requests):
        t_next += gaps[i]
        delay = t_next - now()
        if delay > 0:
            time.sleep(delay)
        elif delay < -1e-3:
            behind += 1                     # late: submit now, keep schedule
        t_submit = now()
        try:
            fut = server.submit(name, payloads[i], deadline_ms=deadline_ms,
                                block=block)
        except AdmissionError:
            rejected += 1
            continue
        fut.add_done_callback(lambda f, t=t_submit: _done(f, t))
        futures.append(fut)

    futures_wait(futures, timeout=result_timeout_s)
    # futures_wait returns when results are SET, but done-callbacks run
    # after the waiter wake-up — settle until every done future's
    # callback has recorded, or the accounting can miss the tail request
    settle_deadline = now() + 5.0
    while now() < settle_deadline:
        n_done = sum(1 for f in futures if f.done())
        with lock:
            if len(latencies) + shed[0] >= n_done:
                break
        time.sleep(1e-3)
    t_end = now()
    with lock:
        lat = np.asarray(latencies, np.float64)
        n_shed = shed[0]
    served = int(lat.size)
    span = max(t_end - t_start, 1e-9)
    return LoadReport(
        program=name,
        offered_rps=rate_rps,
        duration_s=span,
        submitted=len(futures),
        served=served,
        shed=n_shed,
        rejected=rejected,
        achieved_rps=served / span,
        achieved_fps=served * frames_per_request / span,
        behind_schedule=behind,
        latency_ms=latency_summary(lat),
    )


def saturate(server: Server, name: str, frames: np.ndarray,
             n_requests: int, frames_per_request: int = 1,
             result_timeout_s: float = 300.0) -> LoadReport:
    """Closed-world saturation: submit everything under backpressure.

    Every submit blocks until the bounded queue has room, so the server
    is continuously backlogged and the achieved frames/s IS its service
    capacity — what the batch-bucket ablation compares across scheduler
    configurations.
    """
    pool = len(frames)
    futures = []
    t_start = now()
    submit_times = []
    for i in range(n_requests):
        idx = (i * frames_per_request) % pool
        req_frames = np.take(frames,
                             range(idx, idx + frames_per_request),
                             axis=0, mode="wrap")
        submit_times.append(now())
        futures.append(server.submit(name, req_frames, block=True))
    futures_wait(futures, timeout=result_timeout_s)
    t_end = now()
    lat = np.asarray(
        [(t_end - t) * 1e3 for f, t in zip(futures, submit_times)
         if f.done() and f.exception() is None], np.float64)
    # NB: completion timestamps are not tracked per-future here; saturation
    # latency is dominated by queueing and is not the number this mode is
    # for — use poisson_load for latency curves.
    served = sum(1 for f in futures if f.done() and f.exception() is None)
    span = max(t_end - t_start, 1e-9)
    return LoadReport(
        program=name,
        offered_rps=float("inf"),
        duration_s=span,
        submitted=len(futures),
        served=served,
        shed=sum(1 for f in futures
                 if f.done() and f.exception() is not None),
        rejected=0,
        achieved_rps=served / span,
        achieved_fps=served * frames_per_request / span,
        behind_schedule=0,
        latency_ms=latency_summary(lat),
    )
