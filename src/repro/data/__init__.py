from repro.data.synthetic import (SyntheticTextConfig, synthetic_lm_batches,
                                  synthetic_digits, synthetic_textures,
                                  modality_batch)
from repro.data.pipeline import DataPipeline
