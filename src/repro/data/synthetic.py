"""Deterministic synthetic datasets (no MNIST/CIFAR offline — DESIGN.md §2).

Three generators, all seeded and reproducible across restarts (a batch is a
pure function of (seed, step) — exactly what elastic restart needs):

  * ``synthetic_lm_batches`` — Zipf-ish token streams with planted n-gram
    structure so CE actually decreases during the example runs.
  * ``synthetic_digits`` — procedural 28x28 "digit" glyphs (7-segment style
    rendering + jitter/noise). Stand-in for MNIST: 10 classes that a LeNet
    can learn, letting the QAT accuracy *trend* across [W:A] configs be
    measured (the paper's Table 1 axis).
  * ``synthetic_textures`` — k-class oriented-texture RGB images (CIFAR
    stand-in for VGG9).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTextConfig:
    vocab: int
    seq: int
    batch: int
    seed: int = 0
    ngram: int = 3          # planted structure order


def _zipf_probs(vocab: int, alpha: float = 1.2) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = r ** -alpha
    return p / p.sum()


def synthetic_lm_batch(cfg: SyntheticTextConfig, step: int
                       ) -> Dict[str, np.ndarray]:
    """One batch as a pure function of (cfg.seed, step) — restart-safe."""
    probs = _zipf_probs(cfg.vocab)
    rng = np.random.default_rng((cfg.seed, step))
    toks = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq + 1), p=probs)
    # planted bigram: token t deterministically suggests (t*7+3) % vocab;
    # applied sequentially so chains stay coherent (stronger signal)
    follow = (toks * 7 + 3) % cfg.vocab
    use_follow = rng.random((cfg.batch, cfg.seq + 1)) < 0.7
    for j in range(1, cfg.seq + 1):
        nxt = (toks[:, j - 1] * 7 + 3) % cfg.vocab
        toks[:, j] = np.where(use_follow[:, j], nxt, toks[:, j])
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def synthetic_lm_batches(cfg: SyntheticTextConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {tokens, labels}. Deterministic per (seed, step)."""
    step = 0
    while True:
        yield synthetic_lm_batch(cfg, step)
        step += 1


# ---------------------------------------------------------------------------
# Vision
# ---------------------------------------------------------------------------

_SEGS = {  # 7-segment truth table
    0: "abcdef", 1: "bc", 2: "abged", 3: "abgcd", 4: "fgbc",
    5: "afgcd", 6: "afgedc", 7: "abc", 8: "abcdefg", 9: "abcfgd",
}


def _render_digit(d: int, rng: np.random.Generator, hw: int = 28) -> np.ndarray:
    img = np.zeros((hw, hw), np.float32)
    m = hw // 7
    x0, y0 = hw // 4 + rng.integers(-2, 3), hw // 6 + rng.integers(-2, 3)
    w, h = hw // 2, int(hw * 0.66)
    t = max(hw // 14, 2)
    seg = _SEGS[d]
    def bar(x, y, dx, dy):
        img[max(y, 0):min(y + dy, hw), max(x, 0):min(x + dx, hw)] = 1.0
    if "a" in seg: bar(x0, y0, w, t)
    if "b" in seg: bar(x0 + w - t, y0, t, h // 2)
    if "c" in seg: bar(x0 + w - t, y0 + h // 2, t, h // 2)
    if "d" in seg: bar(x0, y0 + h - t, w, t)
    if "e" in seg: bar(x0, y0 + h // 2, t, h // 2)
    if "f" in seg: bar(x0, y0, t, h // 2)
    if "g" in seg: bar(x0, y0 + h // 2 - t // 2, w, t)
    img += 0.12 * rng.standard_normal((hw, hw)).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def synthetic_digits(n: int, seed: int = 0, hw: int = 28
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """-> (images [n,hw,hw,1] in [0,1], labels [n])."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n)
    imgs = np.stack([_render_digit(int(d), rng, hw) for d in labels])
    return imgs[..., None].astype(np.float32), labels.astype(np.int32)


def synthetic_textures(n: int, n_classes: int = 10, seed: int = 0,
                       hw: int = 32) -> Tuple[np.ndarray, np.ndarray]:
    """k-class oriented sinusoid textures in RGB (CIFAR stand-in)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    imgs = np.zeros((n, hw, hw, 3), np.float32)
    for i, c in enumerate(labels):
        theta = np.pi * c / n_classes
        freq = 3.0 + (c % 3) * 2.0
        phase = rng.uniform(0, 2 * np.pi)
        base = 0.5 + 0.5 * np.sin(
            2 * np.pi * freq * (xx * np.cos(theta) + yy * np.sin(theta))
            + phase)
        color = 0.3 + 0.7 * rng.random(3).astype(np.float32)
        imgs[i] = base[..., None] * color[None, None, :]
    imgs += 0.08 * rng.standard_normal(imgs.shape).astype(np.float32)
    return np.clip(imgs, 0, 1), labels.astype(np.int32)


def modality_batch(cfg, batch: int, seq: int, seed: int = 0
                   ) -> Dict[str, np.ndarray]:
    """A host batch for any ModelConfig (used by examples + smoke tests)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    if cfg.frontend == "audio":
        out["frames"] = rng.standard_normal(
            (batch, seq, cfg.frontend_dim)).astype(np.float32)
        out["labels"] = rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)
    elif cfg.frontend == "vision":
        t_text = seq - cfg.n_patches
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.frontend_dim)).astype(np.float32)
        out["tokens"] = rng.integers(0, cfg.vocab, (batch, t_text)).astype(np.int32)
        out["labels"] = rng.integers(0, cfg.vocab, (batch, t_text)).astype(np.int32)
    else:
        out["tokens"] = rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)
        out["labels"] = rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)
    return out
