"""Sharding-aware host data pipeline with background prefetch.

Responsibilities at scale:
  * deterministic batch(step) — restart/elastic-safe (no hidden iterator
    state; the checkpoint stores only the step counter)
  * per-process sharding: each host materializes only its addressable slice
    of the global batch (single-process here, but the slicing math is the
    multi-host one)
  * double-buffered prefetch: the next batch is generated on a worker thread
    and device_put while the current step runs (compute/IO overlap)
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Optional

import jax
import numpy as np


class DataPipeline:
    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 shardings: Optional[Dict] = None, prefetch: int = 2,
                 start_step: int = 0):
        self.batch_fn = batch_fn
        self.shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _device_put(self, host_batch: Dict[str, np.ndarray]):
        if self.shardings is None:
            return host_batch
        return {k: jax.device_put(v, self.shardings.get(k))
                for k, v in host_batch.items()}

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            try:
                self._q.put((step, self._device_put(batch)), timeout=1.0)
                step += 1
            except queue.Full:
                if self._stop.is_set():
                    return
                # retry same step
                while not self._stop.is_set():
                    try:
                        self._q.put((step, self._device_put(batch)),
                                    timeout=1.0)
                        step += 1
                        break
                    except queue.Full:
                        continue

    def next(self):
        step, batch = self._q.get()
        return step, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    @staticmethod
    def process_slice(global_batch: int, process_index: int | None = None,
                      process_count: int | None = None) -> slice:
        """The rows of the global batch this host materializes."""
        pi = jax.process_index() if process_index is None else process_index
        pc = jax.process_count() if process_count is None else process_count
        per = global_batch // pc
        return slice(pi * per, (pi + 1) * per)
