"""Fixed-function filter banks for the optical imaging pipelines.

Every filter is expressed as conv weights in the device's HWIO layout
([k, k, c_in, c_out]) so it drops straight into a ``ConvSpec`` and runs on
the OC banks under the same MR weight quantization as any CNN layer. The
coefficients are the classical image-processing kernels; what the paper
adds is that they execute on the *acquisition* fabric, per [W:A] scheme.
"""

from __future__ import annotations

import numpy as np

SOBEL_X = np.array([[-1, 0, 1],
                    [-2, 0, 2],
                    [-1, 0, 1]], np.float32)
SOBEL_Y = SOBEL_X.T.copy()

PREWITT_X = np.array([[-1, 0, 1],
                      [-1, 0, 1],
                      [-1, 0, 1]], np.float32)
PREWITT_Y = PREWITT_X.T.copy()

# 4-neighbour Laplacian; sharpen = identity - laplacian
LAPLACIAN = np.array([[0, 1, 0],
                      [1, -4, 1],
                      [0, 1, 0]], np.float32)

SHARPEN = np.array([[0, -1, 0],
                    [-1, 5, -1],
                    [0, -1, 0]], np.float32)


def gaussian_kernel(size: int = 5, sigma: float = 1.0) -> np.ndarray:
    """Normalized 2-D Gaussian, [size, size], sum == 1."""
    r = np.arange(size, dtype=np.float32) - (size - 1) / 2.0
    g = np.exp(-(r ** 2) / (2.0 * sigma ** 2))
    k = np.outer(g, g)
    return (k / k.sum()).astype(np.float32)


def box_kernel(size: int = 3) -> np.ndarray:
    """Uniform mean filter, [size, size], sum == 1."""
    return np.full((size, size), 1.0 / (size * size), np.float32)


def unsharp_kernel(amount: float = 0.7, size: int = 5,
                   sigma: float = 1.0) -> np.ndarray:
    """Unsharp mask as ONE conv: (1 + a) * delta - a * gaussian."""
    k = -amount * gaussian_kernel(size, sigma)
    k[size // 2, size // 2] += 1.0 + amount
    return k.astype(np.float32)


def edge_pair_weights(kx: np.ndarray, ky: np.ndarray) -> np.ndarray:
    """Two gradient kernels as a 1-in 2-out conv weight [k, k, 1, 2]."""
    return np.stack([kx, ky], axis=-1)[:, :, None, :].astype(np.float32)


def single_filter_weights(k: np.ndarray) -> np.ndarray:
    """One kernel as a 1-in 1-out conv weight [k, k, 1, 1]."""
    return k[:, :, None, None].astype(np.float32)


def depthwise_weights(k: np.ndarray, channels: int) -> np.ndarray:
    """The same kernel on every channel: depthwise weight [k, k, 1, C]."""
    return np.repeat(k[:, :, None, None], channels, axis=-1).astype(np.float32)
