"""Float reference execution of imaging pipelines (the quality oracle).

Runs the same layer IR as ``core.plan`` but in plain float32 — no CRC
activation codes, no MR weight levels. The difference between this path and
``plan.execute`` is the device's acquisition physics: the 4-bit CRC/MR
quantization AND the CRC's non-negativity clamp (light intensity — every
inter-stage requant is max(x, 0), which this oracle deliberately does not
apply). For signed-output filters (sharpen/unsharp overshoot) the clamp
dominates the PSNR gap reported by ``benchmarks.bench_imaging``; for
non-negative outputs the gap is pure quantization. Differentiable
end-to-end (the learned reconstruction head trains through it).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.accelerator import (CASpec, ConvSpec, DenseSpec, FlattenSpec,
                                    UpsampleSpec, _activation)
from repro.core.compressive import compressive_acquire, upsample_reconstruct


def apply_float(layers, params: Dict[str, Dict],
                frames: jnp.ndarray) -> jnp.ndarray:
    """Run an imaging/vision layer-IR program in full float32 math.

    The quality oracle for ``core.plan.execute``: same IR, no quantization
    and no CRC clamps (see module docstring for exactly what differs).

    Args:
        layers: the layer IR sequence (e.g. from ``PIPELINES[n].build``).
        params: per-layer weight pytrees keyed by layer name (fixed filter
            weights for the imaging pipelines).
        frames: ``[B, H, W, C]`` float frames in [0, 1].

    Returns:
        The pipeline output — ``[B, H', W', C']`` for spatial programs,
        ``[B, n]`` after a dense head. Differentiable end-to-end.
    """
    x = frames.astype(jnp.float32)
    for layer in layers:
        if isinstance(layer, CASpec):
            x = compressive_acquire(x, layer.pool, layer.rgb_to_gray)
            if x.ndim == 3:
                x = x[..., None]
        elif isinstance(layer, ConvSpec):
            p = params[layer.name]
            groups = layer.c_in if layer.depthwise else 1
            y = jax.lax.conv_general_dilated(
                x, p["w"].astype(jnp.float32),
                (layer.stride, layer.stride), layer.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=groups)
            if p.get("b") is not None:
                y = y + p["b"]
            y = _activation(y, layer.act)
            if layer.pool is not None:
                kind, size = layer.pool
                b_, h_, w_, c_ = y.shape
                yr = y.reshape(b_, h_ // size, size, w_ // size, size, c_)
                y = yr.max(axis=(2, 4)) if kind == "max" else yr.mean(axis=(2, 4))
            x = y
        elif isinstance(layer, UpsampleSpec):
            x = upsample_reconstruct(x, layer.factor, layer.method)
        elif isinstance(layer, FlattenSpec):
            x = x.reshape(x.shape[0], -1)
        elif isinstance(layer, DenseSpec):
            p = params[layer.name]
            y = x @ p["w"]
            if p.get("b") is not None:
                y = y + p["b"]
            x = _activation(y, layer.act)
        else:
            raise TypeError(f"unknown layer IR {layer!r}")
    return x
