"""repro.imaging — compiled versatile image-processing pipelines.

Fixed-function optical filter / compression / reconstruction programs over
the LightatorDevice layer IR, compiled and executed on the plan runtime
(``core.plan``) with per-scheme quantization, plus the float reference path
and PSNR/SSIM quality metrics.
"""

from repro.imaging.metrics import psnr, ssim
from repro.imaging.pipelines import (PIPELINES, ImagingPipeline,
                                     fit_recon_head, gray_target,
                                     recon_head_identity_params)
from repro.imaging.reference import apply_float

__all__ = ["PIPELINES", "ImagingPipeline", "apply_float", "fit_recon_head",
           "gray_target", "psnr", "ssim", "recon_head_identity_params"]
