"""Fixed-function optical image-processing pipelines (the paper's
"versatile image processing" claim, as executable programs).

Each pipeline is a small program in the LightatorDevice layer IR — the same
``CASpec``/``ConvSpec``/``UpsampleSpec`` vocabulary the CNN models use — so
it compiles through the plan runtime into a cached plan, executes
batch-first through the kernel dispatch under any [W:A] scheme, and gets a
power/latency report from the same architecture model. The filter weights
are fixed classical kernels (``imaging.filters``); the CA provides fused
RGB->gray acquisition and compressive downsampling; ``UpsampleSpec`` plus an
optional learned head provides reconstruction.

    prog = PIPELINES["edge_detect"].program(64, 64, 3)
    exe = prog.compile(repro.Options(scheme=W4A4))
    edges = exe.run(frames)                               # device path
    ref = apply_float(prog.layers, prog.params, frames)   # float oracle
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import CASpec, ConvSpec, UpsampleSpec
from repro.imaging import filters as F
from repro.imaging.reference import apply_float


@dataclasses.dataclass(frozen=True)
class ImagingPipeline:
    """A named fixed-function program over the device layer IR.

    ``kind`` tags what the output is: "filter" pipelines keep the input
    resolution (edges / sharpened / denoised frames); "recon" pipelines
    compressively downsample then reconstruct, so quality is also measured
    against the original frame, not just the float path.
    """

    name: str
    description: str
    kind: str                     # "filter" | "recon"
    builder: Callable[[int, int, int], Tuple[tuple, Dict]]

    def build(self, h: int, w: int, c: int) -> Tuple[tuple, Dict]:
        """-> (layer IR tuple, fixed params) for [h, w, c] input frames."""
        if c not in (1, 3):
            raise ValueError(f"{self.name}: input channels must be 1 (gray) "
                             f"or 3 (RGB), got {c}")
        layers, params = self.builder(h, w, c)
        return tuple(layers), params

    def program(self, h: int, w: int, c: int = 3):
        """The pipeline as a ``repro.Program`` — the unified front door.

        ``PIPELINES[name].program(h, w, c).compile(Options(...))`` replaces
        the build -> compile_model -> execute triple; ``Program.then``
        chains pipelines into one compiled plan.
        """
        from repro.core.program import Program
        layers, params = self.build(h, w, c)
        return Program(layers, params, (h, w, c), name=self.name)


def _gray_front(c: int):
    """Fused RGB->gray acquisition (pool=1: conversion without downsample)."""
    return [CASpec(pool=1, rgb_to_gray=True)] if c == 3 else []


def _w(arr: np.ndarray) -> Dict[str, jnp.ndarray]:
    return {"w": jnp.asarray(arr)}


# -- filter pipelines -------------------------------------------------------

def _edge_builder(kx: np.ndarray, ky: np.ndarray):
    def build(h, w, c):
        layers = _gray_front(c) + [
            # two gradient kernels on the OC banks, magnitude readout
            ConvSpec("grad", 1, 2, kernel=3, act="abs"),
            # |Gx| + |Gy| as a 1x1 combine conv (L1 gradient magnitude)
            ConvSpec("edge_mag", 2, 1, kernel=1, act="none"),
        ]
        params = {"grad": _w(F.edge_pair_weights(kx, ky)),
                  "edge_mag": _w(np.ones((1, 1, 2, 1), np.float32))}
        return layers, params
    return build


def _single_filter_builder(name: str, kernel_fn):
    def build(h, w, c):
        k = kernel_fn()
        layers = _gray_front(c) + [
            ConvSpec(name, 1, 1, kernel=k.shape[0], act="none"),
        ]
        return layers, {name: _w(F.single_filter_weights(k))}
    return build


def _depthwise_filter_builder(name: str, kernel_fn):
    def build(h, w, c):
        k = kernel_fn()
        layers = [ConvSpec(name, c, c, kernel=k.shape[0], act="none",
                           depthwise=True)]
        return layers, {name: _w(F.depthwise_weights(k, c))}
    return build


# -- compression / reconstruction pipelines ---------------------------------

def _check_compress_dims(h: int, w: int, pool: int):
    if h % pool or w % pool:
        raise ValueError(f"compressive pool={pool} does not divide "
                         f"frame {h}x{w}")


def _compress_recon_builder(pool: int = 2):
    def build(h, w, c):
        _check_compress_dims(h, w, pool)
        layers = [CASpec(pool=pool, rgb_to_gray=(c == 3)),
                  UpsampleSpec(factor=pool, method="bilinear")]
        return layers, {}
    return build


def recon_head_identity_params() -> Dict[str, Dict[str, jnp.ndarray]]:
    """Identity-initialized learned head: rec2(relu(rec1(x))) == x.

    rec1 lifts to 4 channels with a centre-tap delta in channel 0; rec2
    projects channel 0 back. Upsampled intensities are non-negative, so the
    relu is transparent at init — the head starts as a no-op on top of the
    bilinear reconstruction and only helps after ``fit_recon_head``.
    """
    w1 = np.zeros((3, 3, 1, 4), np.float32)
    w1[1, 1, 0, 0] = 1.0
    w2 = np.zeros((3, 3, 4, 1), np.float32)
    w2[1, 1, 0, 0] = 1.0
    return {"rec1": _w(w1), "rec2": _w(w2)}


def _compress_recon_deconv_builder(pool: int = 2):
    def build(h, w, c):
        _check_compress_dims(h, w, pool)
        layers = [CASpec(pool=pool, rgb_to_gray=(c == 3)),
                  UpsampleSpec(factor=pool, method="bilinear"),
                  ConvSpec("rec1", 1, 4, kernel=3, act="relu"),
                  ConvSpec("rec2", 4, 1, kernel=3, act="none")]
        return layers, recon_head_identity_params()
    return build


def gray_target(frames: jnp.ndarray) -> jnp.ndarray:
    """The reconstruction target: the full-resolution grayscale frame."""
    from repro.core.compressive import compressive_acquire
    if frames.shape[-1] == 3:
        return compressive_acquire(frames, 1, True)[..., None]
    return frames


def fit_recon_head(layers, params, frames: jnp.ndarray, steps: int = 150,
                   lr: float = 0.3, momentum: float = 0.9) -> Dict:
    """Train the deconv head (rec1/rec2) to reconstruct ``frames``.

    Optimizes MSE against the grayscale original through the *float*
    reference path (differentiable end-to-end: CA -> bilinear -> head) with
    plain SGD + momentum — no optimizer deps.

    Args:
        layers: the ``compress_recon_deconv`` layer IR (must contain convs
            named ``rec1``/``rec2``).
        params: the pipeline params; only the head entries are updated.
        frames: ``[B, H, W, C]`` training frames in [0, 1].
        steps / lr / momentum: SGD schedule.

    Returns:
        A new params dict with the fitted head (inputs are not mutated).
        The frozen CA/upsample stages have no parameters and the head stays
        small (4 x 3x3 + 4 x 3x3 taps), so this converges in seconds on CPU.
    """
    target = gray_target(frames)
    head = {k: params[k] for k in ("rec1", "rec2")}
    frozen = {k: v for k, v in params.items() if k not in head}

    def loss_fn(hd):
        out = apply_float(layers, {**frozen, **hd}, frames)
        return jnp.mean((out - target) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    vel = jax.tree_util.tree_map(jnp.zeros_like, head)
    for _ in range(steps):
        _, g = grad_fn(head)
        vel = jax.tree_util.tree_map(lambda v, gi: momentum * v - lr * gi,
                                     vel, g)
        head = jax.tree_util.tree_map(lambda p, v: p + v, head, vel)
    return {**frozen, **head}


# -- registry ---------------------------------------------------------------

#: The pipeline registry — every fixed-function imaging program the device
#: serves, keyed by name. Each value is an :class:`ImagingPipeline`; call
#: ``PIPELINES[name].build(h, w, c)`` for the (layer IR, params) pair, then
#: compile/execute it through ``core.plan`` like any model. The full table
#: (filter math, measured PSNR per scheme, serving walkthrough) lives in
#: docs/imaging.md.
PIPELINES: Dict[str, ImagingPipeline] = {
    p.name: p for p in [
        ImagingPipeline(
            "edge_detect", "Sobel gradient magnitude (|Gx| + |Gy|)",
            "filter", _edge_builder(F.SOBEL_X, F.SOBEL_Y)),
        ImagingPipeline(
            "prewitt_edge", "Prewitt gradient magnitude",
            "filter", _edge_builder(F.PREWITT_X, F.PREWITT_Y)),
        ImagingPipeline(
            "sharpen", "Laplacian sharpen (identity - laplacian)",
            "filter", _single_filter_builder(
                "sharpen", lambda: F.SHARPEN)),
        ImagingPipeline(
            "unsharp_mask", "5x5 unsharp mask (amount=0.7, sigma=1.0)",
            "filter", _single_filter_builder(
                "unsharp", lambda: F.unsharp_kernel(0.7, 5, 1.0))),
        ImagingPipeline(
            "denoise_gauss", "depthwise 5x5 Gaussian denoise (sigma=1.0)",
            "filter", _depthwise_filter_builder(
                "gauss", lambda: F.gaussian_kernel(5, 1.0))),
        ImagingPipeline(
            "denoise_box", "depthwise 3x3 box denoise",
            "filter", _depthwise_filter_builder(
                "box", lambda: F.box_kernel(3))),
        ImagingPipeline(
            "compress_recon", "2x2 CA compressive downsample + bilinear "
            "reconstruction", "recon", _compress_recon_builder(2)),
        ImagingPipeline(
            "compress_recon_deconv", "2x2 CA compression + bilinear + "
            "learned deconv head", "recon", _compress_recon_deconv_builder(2)),
    ]
}
