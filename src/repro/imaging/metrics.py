"""Image quality metrics: PSNR + SSIM (quantized-vs-float evaluation).

Used to report how much the device's 4-bit CRC activations and [W:A] MR
weight quantization cost against the float reference pipeline, and how much
reconstruction quality the compressive acquisition gives back up.
"""

from __future__ import annotations

import jax.numpy as jnp


def _data_range(ref: jnp.ndarray, data_range) -> jnp.ndarray:
    if data_range is not None:
        return jnp.asarray(data_range, jnp.float32)
    rng = jnp.max(ref) - jnp.min(ref)
    return jnp.maximum(rng, 1e-8)


def psnr(ref: jnp.ndarray, x: jnp.ndarray, data_range=None) -> jnp.ndarray:
    """Peak signal-to-noise ratio in dB. ``ref`` is the ground truth;
    ``data_range`` defaults to ref's dynamic range (use 1.0 for [0,1] frames).
    """
    mse = jnp.mean((ref.astype(jnp.float32) - x.astype(jnp.float32)) ** 2)
    dr = _data_range(ref, data_range)
    return 20.0 * jnp.log10(dr) - 10.0 * jnp.log10(jnp.maximum(mse, 1e-12))


def ssim(ref: jnp.ndarray, x: jnp.ndarray, data_range=None,
         window: int = 7) -> jnp.ndarray:
    """Mean structural similarity over [B, H, W, C] (or [B, H, W]) images.

    Uniform ``window`` x ``window`` local statistics (the box-filter SSIM
    variant); standard C1/C2 stabilizers at k1=0.01, k2=0.03.
    """
    if ref.ndim == 3:
        ref, x = ref[..., None], x[..., None]
    ref = ref.astype(jnp.float32)
    x = x.astype(jnp.float32)
    dr = _data_range(ref, data_range)
    c1 = (0.01 * dr) ** 2
    c2 = (0.03 * dr) ** 2

    def box(img):
        # depthwise box filter, VALID so every window is fully supported
        import jax
        c = img.shape[-1]
        k = jnp.ones((window, window, 1, c), jnp.float32) / (window * window)
        return jax.lax.conv_general_dilated(
            img, k, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c)

    mu_r, mu_x = box(ref), box(x)
    var_r = box(ref * ref) - mu_r * mu_r
    var_x = box(x * x) - mu_x * mu_x
    cov = box(ref * x) - mu_r * mu_x
    num = (2 * mu_r * mu_x + c1) * (2 * cov + c2)
    den = (mu_r ** 2 + mu_x ** 2 + c1) * (var_r + var_x + c2)
    return jnp.mean(num / den)
