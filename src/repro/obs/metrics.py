"""Named counters / gauges / histograms behind a registry.

The generalized machinery under ``serve.metrics.ProgramMetrics`` (which
is now a thin facade over a private :class:`Registry` per hosted
program) plus one process-wide :data:`REGISTRY` for runtime-global
signals: plan-cache hits/misses, per-strategy conv dispatch counts,
fused-segment trace-time fallbacks.

All metrics in one registry share a single lock, so a registry
``snapshot()`` is internally consistent (every value from the same
instant) — the property ``Server.stats()`` has always promised. Metrics
are always-on (an increment is one lock + one add; the hooks sit at
per-batch / per-compile granularity, never per-element), unlike tracing
which is off by default.

Naming convention (dotted, lowercase — the registry of names lives in
docs/observability.md): ``<subsystem>.<object>.<signal>``, e.g.
``plan.cache.hit``, ``dispatch.conv.strip``, ``serve.lenet.submitted``.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def get(self) -> int:
        with self._lock:
            return self.value


class Gauge:
    """A value that goes up and down (queue depths, in-flight counts)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v

    def add(self, delta: float) -> None:
        with self._lock:
            self.value += delta

    def get(self) -> float:
        with self._lock:
            return self.value


# Default histogram buckets: ratios in [0, 1] (padding waste, batch
# occupancy). Callers with other domains pass their own boundaries.
RATIO_BUCKETS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


class Histogram:
    """Fixed-bucket histogram with sum/count/min/max.

    ``buckets`` are upper bounds (``le`` semantics, Prometheus-style); an
    implicit +Inf bucket catches the rest.
    """

    __slots__ = ("name", "buckets", "counts", "sum", "count",
                 "min", "max", "_lock")

    def __init__(self, name: str, lock: threading.RLock,
                 buckets: Sequence[float] = RATIO_BUCKETS):
        self.name = name
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self.counts: List[int] = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._lock = lock

    def observe(self, v: float) -> None:
        with self._lock:
            i = 0
            for i, le in enumerate(self.buckets):      # noqa: B007
                if v <= le:
                    break
            else:
                i = len(self.buckets)
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def summary(self) -> Dict[str, object]:
        with self._lock:
            return {
                "count": self.count,
                "sum": self.sum,
                "mean": (self.sum / self.count) if self.count else 0.0,
                "min": self.min,
                "max": self.max,
                "buckets": {
                    **{f"le_{le:g}": c
                       for le, c in zip(self.buckets, self.counts)},
                    "le_inf": self.counts[-1]},
            }


class Registry:
    """A namespace of metrics sharing one lock (consistent snapshots)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, object] = {}

    def _get_or_make(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, self._lock, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_make(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_make(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = RATIO_BUCKETS) -> Histogram:
        return self._get_or_make(name, Histogram, buckets)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, object]:
        """Every metric's value, read under one lock acquisition."""
        with self._lock:
            out: Dict[str, object] = {}
            for name in sorted(self._metrics):
                m = self._metrics[name]
                out[name] = (m.summary() if isinstance(m, Histogram)
                             else m.value)
            return out

    def reset(self) -> None:
        """Drop every metric (tests; never called by the runtime)."""
        with self._lock:
            self._metrics.clear()


# The process-wide registry: runtime-global signals (plan cache, kernel
# dispatch). Per-program serving metrics live in per-ProgramMetrics
# registries so two Servers hosting the same program name never alias.
REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, buckets: Sequence[float] = RATIO_BUCKETS
              ) -> Histogram:
    return REGISTRY.histogram(name, buckets)
