"""Exporters: JSON-lines sink + Prometheus-style text exposition.

Chrome-trace export lives on :meth:`obs.Trace.export`; this module
covers the two other shapes operators consume:

* :func:`write_jsonl` — append records (span dicts, stats snapshots,
  load reports) to a JSON-lines file, one object per line — the format
  log shippers and ``jq`` pipelines eat directly.
* :func:`prometheus_text` — dump a :class:`obs.Registry` in the
  Prometheus text exposition format (``# TYPE`` headers, ``_bucket``/
  ``_sum``/``_count`` histogram series), so a scrape endpoint or a
  node-exporter textfile collector can pick the metrics up without any
  new dependency.
"""

from __future__ import annotations

import json
import re
import threading
from typing import Iterable, Optional

from repro.obs.metrics import Counter, Gauge, Histogram, Registry, REGISTRY

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

# One process-wide sink lock: concurrent write_jsonl callers (the serving
# threads' structured log, periodic stats exporters) interleave whole
# *records*, never partial lines. Appends under a single lock are cheap
# relative to json.dumps; a per-path lock table would only matter with
# many distinct high-rate sinks, which the runtime does not have.
_jsonl_lock = threading.Lock()


def _prom_name(name: str) -> str:
    """Dotted registry name -> a fully legal Prometheus metric name.

    Every character outside ``[a-zA-Z0-9_:]`` becomes ``_`` (dots,
    dashes, slashes, spaces — e.g. ``slo.breach.edge-detect`` ->
    ``slo_breach_edge_detect``), and a name starting with a digit gets a
    leading ``_`` because the exposition grammar forbids a digit first.
    """
    pname = _NAME_RE.sub("_", name)
    if pname and pname[0].isdigit():
        pname = "_" + pname
    return pname


def write_jsonl(path, records: Iterable[dict], append: bool = True) -> str:
    """Write ``records`` to ``path`` as JSON lines; returns the path.

    Safe for concurrent writers: each call serializes its records first,
    then appends them under a process-wide lock, so readers never see a
    torn line even when several serving threads log at once.
    """
    lines = [json.dumps(rec) + "\n" for rec in records]
    with _jsonl_lock:
        with open(path, "a" if append else "w") as f:
            f.writelines(lines)
    return str(path)


def prometheus_text(registry: Optional[Registry] = None) -> str:
    """The registry in Prometheus text exposition format.

    Each metric gets ``# HELP`` (carrying the original dotted registry
    name, since escaping is lossy) and ``# TYPE`` headers; histograms
    expose cumulative ``_bucket{le=}`` series plus ``_sum``/``_count``.
    """
    registry = registry if registry is not None else REGISTRY
    lines = []
    with registry._lock:
        metrics = dict(registry._metrics)
    for name in sorted(metrics):
        m = metrics[name]
        pname = _prom_name(name)
        if isinstance(m, Counter):
            lines.append(f"# HELP {pname} repro metric '{name}'")
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {m.get()}")
        elif isinstance(m, Gauge):
            lines.append(f"# HELP {pname} repro metric '{name}'")
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {m.get()}")
        elif isinstance(m, Histogram):
            lines.append(f"# HELP {pname} repro metric '{name}'")
            lines.append(f"# TYPE {pname} histogram")
            with m._lock:
                acc = 0
                for le, c in zip(m.buckets, m.counts):
                    acc += c
                    lines.append(f'{pname}_bucket{{le="{le:g}"}} {acc}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {m.sum}")
                lines.append(f"{pname}_count {m.count}")
    return "\n".join(lines) + "\n"


def export_metrics(path, registry: Optional[Registry] = None) -> str:
    """Write :func:`prometheus_text` to ``path``; returns the path."""
    with open(path, "w") as f:
        f.write(prometheus_text(registry))
    return str(path)
