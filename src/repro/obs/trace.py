"""Thread-safe tracing: spans, instant events, Chrome-trace export.

The repo's latency claims are *rates* (kFPS/W, frames/s at saturation),
but a rate tells you nothing about *where* a frame's time goes —
plan-cache miss vs. jit trace priming vs. batcher hold-open vs. device
vs. result split. This module records that decomposition:

    with obs.span("plan.compile", attrs={"model": "lenet"}):
        ...                                  # nested spans parent here
    obs.event("plan.cache.miss")             # zero-duration instant

* **Off by default, near-zero overhead when off** — ``span()``/``event()``
  first check a module-level collector reference; with no collector
  installed they return a shared no-op immediately (no allocation, no
  lock). The disabled path is gated at <2% end-to-end overhead on the
  3-stage imaging chain by ``benchmarks/bench_obs.py`` →
  ``scripts/check_bench.py``.
* **Monotonic clock** — every timestamp is ``time.perf_counter_ns()``
  (the same clock ``serve.metrics.now()`` uses, in seconds), so spans
  recorded from serving timestamps line up exactly.
* **Nested parenting** — spans opened on one thread stack up in a
  thread-local; a child records its parent's id. Spans on one ``tid``
  therefore always nest and never interleave (pinned by
  tests/test_obs.py across the scheduler/completer boundary).
* **Cross-thread request timelines** — a request's life crosses three
  threads (submitter → scheduler → completer). The serving runtime
  stitches it back together with :meth:`Trace.add_span` (explicit begin/
  end timestamps, explicit ``trace_id``, a synthetic per-request lane
  ``tid``), so one request's queue-wait → batch-assembly → device →
  split spans reassemble into one timeline in the exported trace.
* **Chrome-trace export** — :meth:`Trace.export` writes the Trace Event
  Format JSON that ``chrome://tracing`` and Perfetto open directly.

Tracing must never perturb results: nothing in this module touches
arrays, and every hook site in the runtime is read-only observation.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Iterator, List, Optional

TRACE_MODES = ("auto", "on", "off")

_TID_META_PID = 1          # chrome-trace process id (single-process runtime)


def now_ns() -> int:
    """The one trace clock: monotonic nanoseconds (``perf_counter_ns``)."""
    return time.perf_counter_ns()


class Trace:
    """A thread-safe collection of finished spans and instant events.

    Spans/events are plain dicts (JSON-able as recorded):

        {"name", "ph": "X"|"i", "t0_ns", "t1_ns", "tid", "id",
         "parent", "trace_id", "attrs"}
    """

    def __init__(self, name: str = "repro"):
        self.name = name
        self._lock = threading.Lock()
        self._records: List[Dict] = []
        self._next_id = 1
        self._lanes: Dict[object, int] = {}      # synthetic tid -> lane name
        self.t0_ns = now_ns()

    # -- recording ---------------------------------------------------------

    def add_span(self, name: str, t0_ns: int, t1_ns: int,
                 attrs: Optional[Dict] = None, trace_id: Optional[str] = None,
                 tid: Optional[int] = None, parent: Optional[int] = None,
                 lane: Optional[str] = None) -> int:
        """Record a finished span with explicit timestamps.

        ``tid`` defaults to the calling thread; pass a synthetic lane id
        (+ a human ``lane`` name) to place retrospective spans — e.g. a
        request's queue-wait reconstructed after the fact — on their own
        timeline row instead of overlapping the recording thread's live
        spans.
        """
        if tid is None:
            tid = threading.get_ident()
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            if lane is not None:
                self._lanes[tid] = lane
            self._records.append({
                "name": name, "ph": "X", "t0_ns": int(t0_ns),
                "t1_ns": int(t1_ns), "tid": tid, "id": sid,
                "parent": parent, "trace_id": trace_id,
                "attrs": dict(attrs) if attrs else {}})
        return sid

    def add_event(self, name: str, t_ns: Optional[int] = None,
                  attrs: Optional[Dict] = None,
                  trace_id: Optional[str] = None,
                  tid: Optional[int] = None) -> None:
        """Record an instant event."""
        if t_ns is None:
            t_ns = now_ns()
        if tid is None:
            tid = threading.get_ident()
        with self._lock:
            self._records.append({
                "name": name, "ph": "i", "t0_ns": int(t_ns),
                "t1_ns": int(t_ns), "tid": tid, "id": self._next_id,
                "parent": None, "trace_id": trace_id,
                "attrs": dict(attrs) if attrs else {}})
            self._next_id += 1

    # -- reading -----------------------------------------------------------

    def records(self) -> List[Dict]:
        with self._lock:
            return list(self._records)

    def spans(self, name: Optional[str] = None) -> List[Dict]:
        return [r for r in self.records()
                if r["ph"] == "X" and (name is None or r["name"] == name)]

    def events(self, name: Optional[str] = None) -> List[Dict]:
        return [r for r in self.records()
                if r["ph"] == "i" and (name is None or r["name"] == name)]

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name {count, total_ms} rollup (the stats table rows)."""
        out: Dict[str, Dict[str, float]] = {}
        for r in self.records():
            if r["ph"] != "X":
                continue
            e = out.setdefault(r["name"], {"count": 0, "total_ms": 0.0})
            e["count"] += 1
            e["total_ms"] += (r["t1_ns"] - r["t0_ns"]) / 1e6
        return out

    # -- export ------------------------------------------------------------

    def to_chrome(self) -> Dict:
        """Chrome Trace Event Format (``chrome://tracing`` / Perfetto).

        Durations use complete ("X") events with microsecond timestamps
        relative to the trace epoch; instants are "i" events; synthetic
        request lanes get ``thread_name`` metadata so the viewer labels
        each request's row with its ``trace_id``.
        """
        events = []
        with self._lock:
            records = list(self._records)
            lanes = dict(self._lanes)
        for tid, lane in sorted(lanes.items(), key=lambda kv: kv[0]):
            events.append({"name": "thread_name", "ph": "M",
                           "pid": _TID_META_PID, "tid": tid,
                           "args": {"name": lane}})
        for r in records:
            args = dict(r["attrs"])
            if r["trace_id"] is not None:
                args["trace_id"] = r["trace_id"]
            ev = {"name": r["name"], "ph": r["ph"],
                  "cat": r["name"].split(".", 1)[0],
                  "pid": _TID_META_PID, "tid": r["tid"],
                  "ts": (r["t0_ns"] - self.t0_ns) / 1e3, "args": args}
            if r["ph"] == "X":
                ev["dur"] = (r["t1_ns"] - r["t0_ns"]) / 1e3
            else:
                ev["s"] = "t"                      # instant scope: thread
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"trace": self.name}}

    def export(self, path) -> str:
        """Write the Chrome-trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return str(path)


# ---------------------------------------------------------------------------
# Module-level collector + the no-op fast path
# ---------------------------------------------------------------------------

_active: Optional[Trace] = None
_active_lock = threading.Lock()
_tls = threading.local()           # .stack (open spans), .mode, .trace_id

# The process flight recorder (obs.flight.FlightRecorder), if installed.
# Managed by obs.flight.install/uninstall; read here on the hot path so
# span()/event()/span_at() feed the always-on black box even when the
# Options(trace=) tri-state has recording off.
_flight = None


def enable(trace: Optional[Trace] = None) -> Trace:
    """Install ``trace`` (or a fresh one) as the process collector."""
    global _active
    with _active_lock:
        _active = trace if trace is not None else Trace()
        return _active


def disable() -> Optional[Trace]:
    """Remove the collector; returns it (for export) or None."""
    global _active
    with _active_lock:
        trace, _active = _active, None
        return trace


def get_trace() -> Optional[Trace]:
    """The active collector, if any."""
    return _active


def trace_mode() -> str:
    """The ambient trace mode: ``REPRO_TRACE`` env or ``auto``."""
    env = os.environ.get("REPRO_TRACE", "").strip().lower()
    if not env:
        return "auto"
    if env not in TRACE_MODES:
        raise ValueError(f"REPRO_TRACE={env!r}; expected one of {TRACE_MODES}")
    return env


# enabled() resolves the env mode once and caches it: with the always-on
# flight recorder installed, every serving span/event reaches the "no
# collector, no thread pin" branch, and an os.environ lookup per record
# is measurable against the 5% flight budget. The env is process config,
# not a runtime switch (use use_mode()/enable() for that).
_env_mode: Optional[str] = None


def _ambient_mode() -> str:
    global _env_mode
    if _env_mode is None:
        _env_mode = trace_mode()
    return _env_mode


class _UseMode:
    """Per-thread trace-mode pin (what ``Options(trace=...)`` maps to).

    ``off`` suppresses recording on this thread even while a collector is
    installed; ``on`` forces recording (installing a collector if none);
    ``auto`` follows the collector. Re-entrant; restores on exit.
    """

    __slots__ = ("mode", "_prev")

    def __init__(self, mode: str):
        if mode not in TRACE_MODES:
            raise ValueError(f"unknown trace mode {mode!r}; expected one of "
                             f"{TRACE_MODES}")
        self.mode = mode

    def __enter__(self):
        self._prev = getattr(_tls, "mode", None)
        _tls.mode = self.mode
        return self

    def __exit__(self, *exc):
        _tls.mode = self._prev


def use_mode(mode: str) -> _UseMode:
    """Context manager pinning the trace mode for the current thread."""
    return _UseMode(mode)


def enabled() -> bool:
    """Is recording active for this thread? (The one hot-path check.)

    Resolution: thread-local ``use_mode`` pin, else the ``REPRO_TRACE``
    env mode, else ``auto`` = record iff a collector is installed.
    ``on`` lazily installs a collector so forced spans are never lost.
    """
    mode = getattr(_tls, "mode", None)
    if mode is None:
        if _active is not None:
            return True                      # the common fast path
        mode = _ambient_mode()
    if mode == "off":
        return False
    if mode == "on":
        if _active is None:
            enable()
        return True
    return _active is not None


def recording() -> bool:
    """Is *any* sink live — the trace collector or the flight recorder?

    Guard call sites that build attrs dicts / timestamps with this (not
    :func:`enabled`) so the always-on flight recorder still captures
    serving history while ``Options(trace=)`` is off. :func:`enabled`
    keeps governing the export-on-demand :class:`Trace` collector only.
    """
    return _flight is not None or enabled()


def current_trace_id() -> Optional[str]:
    """The thread's inherited trace id (set by an enclosing span)."""
    return getattr(_tls, "trace_id", None)


class _NullSpan:
    """The shared disabled-path span: enter/exit do nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records into the collector and/or flight on exit.

    ``to_trace`` is resolved at ``span()`` time (the :func:`enabled`
    tri-state); the flight recorder is consulted again on exit so a
    recorder installed mid-span still sees the record.
    """

    __slots__ = ("name", "attrs", "trace_id", "to_trace", "_t0",
                 "_prev_trace_id", "_parent")

    def __init__(self, name: str, attrs: Optional[Dict],
                 trace_id: Optional[str], to_trace: bool = True):
        self.name = name
        self.attrs = attrs
        self.trace_id = trace_id
        self.to_trace = to_trace

    def __enter__(self):
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self._parent = stack[-1][1] if stack else None
        self._prev_trace_id = getattr(_tls, "trace_id", None)
        if self.trace_id is None:
            self.trace_id = self._prev_trace_id
        else:
            _tls.trace_id = self.trace_id
        # reserve the span id up front so children opened inside can
        # point at it; the record itself lands on exit
        trace = _active if self.to_trace else None
        sid = None
        if trace is not None:
            with trace._lock:
                sid = trace._next_id
                trace._next_id += 1
        stack.append((self, sid))
        self._t0 = now_ns()
        return self

    def __exit__(self, *exc):
        t1 = now_ns()
        stack = _tls.stack
        _, sid = stack.pop()
        _tls.trace_id = self._prev_trace_id
        trace = _active if self.to_trace else None
        if trace is not None and sid is not None:
            with trace._lock:
                trace._records.append({
                    "name": self.name, "ph": "X", "t0_ns": self._t0,
                    "t1_ns": t1, "tid": threading.get_ident(), "id": sid,
                    "parent": self._parent, "trace_id": self.trace_id,
                    "attrs": dict(self.attrs) if self.attrs else {}})
        flight = _flight
        if flight is not None:
            flight.record_span(self.name, self._t0, t1,
                               trace_id=self.trace_id, attrs=self.attrs)
        return False


def span(name: str, attrs: Optional[Dict] = None,
         trace_id: Optional[str] = None):
    """Open a span context manager; a shared no-op when nothing records.

    The span feeds the :class:`Trace` collector when :func:`enabled`
    says so, and *always* feeds the flight recorder when one is
    installed — black-box capture ignores the trace tri-state.
    """
    to_trace = enabled()
    if not to_trace and _flight is None:
        return _NULL_SPAN
    return _Span(name, attrs, trace_id, to_trace=to_trace)


def event(name: str, attrs: Optional[Dict] = None,
          trace_id: Optional[str] = None) -> None:
    """Record an instant event; no-op when nothing records."""
    to_trace = enabled()
    flight = _flight
    if not to_trace and flight is None:
        return
    if trace_id is None:
        trace_id = getattr(_tls, "trace_id", None)
    trace = _active if to_trace else None
    if trace is not None:
        trace.add_event(name, attrs=attrs, trace_id=trace_id)
    if flight is not None:
        flight.record_event(name, trace_id=trace_id, attrs=attrs)


def span_at(name: str, t0_s: float, t1_s: float,
            attrs: Optional[Dict] = None, trace_id: Optional[str] = None,
            lane_tid: Optional[int] = None,
            lane: Optional[str] = None) -> None:
    """Record a retrospective span from ``perf_counter()`` *seconds*.

    The serving runtime's request timelines use this: timestamps were
    taken with ``serve.metrics.now()`` (the same monotonic clock, in
    seconds) on whatever thread held the request at the time, and the
    span is stitched in afterwards on a synthetic per-request lane.
    """
    to_trace = enabled()
    flight = _flight
    if not to_trace and flight is None:
        return
    t0_ns, t1_ns = int(t0_s * 1e9), int(t1_s * 1e9)
    trace = _active if to_trace else None
    if trace is not None:
        trace.add_span(name, t0_ns, t1_ns, attrs=attrs,
                       trace_id=trace_id, tid=lane_tid, lane=lane)
    if flight is not None:
        flight.record_span(name, t0_ns, t1_ns, trace_id=trace_id,
                           attrs=attrs, lane_tid=lane_tid, lane=lane)
