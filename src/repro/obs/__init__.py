"""repro.obs — unified tracing, metrics & profiling across the runtime.

One zero-dependency observability layer threaded through every
subsystem: the compile pass (``core.plan``), kernel dispatch
(``kernels.dispatch``), the program front door (``Options(trace=)``)
and the serving runtime (``repro.serve``). See docs/observability.md
for the span taxonomy and metric name registry.

    from repro import obs

    trace = obs.enable()                  # install a collector
    ...                                   # compile / run / serve
    trace.export("out.json")              # open in chrome://tracing
    print(obs.prometheus_text())          # metrics exposition dump

Everything is **off by default**: with no collector installed,
``obs.span``/``obs.event`` return a shared no-op immediately
(<2% end-to-end overhead on the 3-stage imaging chain, gated by
``benchmarks/bench_obs.py`` through ``scripts/check_bench.py``), and
recording never perturbs numerics — hooks observe, they do not touch
arrays.
"""

from repro.obs.export import (export_metrics, prometheus_text, write_jsonl)
from repro.obs.metrics import (Counter, Gauge, Histogram, RATIO_BUCKETS,
                               REGISTRY, Registry, counter, gauge, histogram)
from repro.obs.trace import (TRACE_MODES, Trace, current_trace_id, disable,
                             enable, enabled, event, get_trace, now_ns, span,
                             span_at, trace_mode, use_mode)

__all__ = [
    "Counter", "Gauge", "Histogram", "RATIO_BUCKETS", "REGISTRY",
    "Registry", "TRACE_MODES", "Trace", "counter", "current_trace_id",
    "disable", "enable", "enabled", "event", "export_metrics", "gauge",
    "get_trace", "histogram", "now_ns", "prometheus_text", "span",
    "span_at", "trace_mode", "use_mode", "write_jsonl",
]
