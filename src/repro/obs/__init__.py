"""repro.obs — unified tracing, metrics & profiling across the runtime.

One zero-dependency observability layer threaded through every
subsystem: the compile pass (``core.plan``), kernel dispatch
(``kernels.dispatch``), the program front door (``Options(trace=)``)
and the serving runtime (``repro.serve``). See docs/observability.md
for the span taxonomy and metric name registry.

    from repro import obs

    trace = obs.enable()                  # install a collector
    ...                                   # compile / run / serve
    trace.export("out.json")              # open in chrome://tracing
    print(obs.prometheus_text())          # metrics exposition dump

The on-demand :class:`Trace` collector is **off by default**: with no
collector installed and no flight recorder, ``obs.span``/``obs.event``
return a shared no-op immediately (<2% end-to-end overhead on the
3-stage imaging chain, gated by ``benchmarks/bench_obs.py`` through
``scripts/check_bench.py``), and recording never perturbs numerics —
hooks observe, they do not touch arrays.

The **flight recorder** (``obs.flight``) is the exception: it installs
at import time (disable with ``REPRO_FLIGHT=off``) and keeps the last
N spans/events per thread in preallocated ring buffers regardless of
the trace tri-state, so ``FlightRecorder.dump()`` can reconstruct the
moments before an incident (<5% overhead under serving load, same
bench gate). Per-program :class:`SLO` objectives (``obs.slo``) and the
structured JSON-lines log (``obs.log``) build on it: a breach or a
worker failure auto-triggers a dump inside ``repro.serve``.
"""

from repro.obs.export import (export_metrics, prometheus_text, write_jsonl)
from repro.obs.metrics import (Counter, Gauge, Histogram, RATIO_BUCKETS,
                               REGISTRY, Registry, counter, gauge, histogram)
from repro.obs.trace import (TRACE_MODES, Trace, current_trace_id, disable,
                             enable, enabled, event, get_trace, now_ns,
                             recording, span, span_at, trace_mode, use_mode)
from repro.obs.flight import (FlightRecorder, get_flight, install,
                              install_default, uninstall)
from repro.obs.log import StructuredLog
from repro.obs.slo import SLO, SLOMonitor

__all__ = [
    "Counter", "FlightRecorder", "Gauge", "Histogram", "RATIO_BUCKETS",
    "REGISTRY", "Registry", "SLO", "SLOMonitor", "StructuredLog",
    "TRACE_MODES", "Trace", "counter", "current_trace_id", "disable",
    "enable", "enabled", "event", "export_metrics", "gauge", "get_flight",
    "get_trace", "histogram", "install", "install_default", "now_ns",
    "prometheus_text", "recording", "span", "span_at", "trace_mode",
    "uninstall", "use_mode", "write_jsonl",
]

# the always-on black box: installed unless REPRO_FLIGHT=off
install_default()
