"""Structured JSON-lines logging, correlated by per-request trace_id.

A print statement cannot be grepped by request, shipped to a collector,
or joined against a flight dump. This module's :class:`StructuredLog`
emits one JSON object per event with a fixed envelope:

    {"ts": <unix wall seconds>, "mono_s": <perf_counter seconds>,
     "level": "info"|"warning"|"error", "event": "serve.slo.breach",
     "trace_id": "lenet/req-42" | null, ...caller fields}

* ``trace_id`` defaults to :func:`obs.current_trace_id` — a log call
  made inside a span inherits the request's id automatically, so a
  breach log, the flight dump that follows it, and the Chrome-trace
  lane for that request all join on one key.
* ``mono_s`` is the same monotonic clock spans use (seconds), so log
  lines can be placed *inside* a dumped timeline.
* Records go to an in-memory bounded deque (``recent()``, served by
  ``/statusz`` debugging) and, when a path is configured, to a
  JSON-lines file via the concurrency-safe :func:`obs.write_jsonl`.

Thread-safe: one lock guards the deque + counters; file appends are
serialized by ``write_jsonl``'s own sink lock.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

from repro.obs.export import write_jsonl
from repro.obs.trace import current_trace_id

LEVELS = ("debug", "info", "warning", "error")


class StructuredLog:
    """A JSON-lines event log with an in-memory tail."""

    def __init__(self, path: Optional[str] = None, keep: int = 256):
        self.path = str(path) if path is not None else None
        self._lock = threading.Lock()
        self._recent: deque = deque(maxlen=keep)
        self._counts: Dict[str, int] = {lvl: 0 for lvl in LEVELS}

    def log(self, event: str, level: str = "info",
            trace_id: Optional[str] = None, **fields) -> Dict:
        """Record one event; returns the record dict."""
        if level not in LEVELS:
            raise ValueError(f"unknown log level {level!r}; expected one of "
                             f"{LEVELS}")
        if trace_id is None:
            trace_id = current_trace_id()
        rec = {"ts": time.time(), "mono_s": time.perf_counter(),
               "level": level, "event": event, "trace_id": trace_id}
        rec.update(fields)
        with self._lock:
            self._recent.append(rec)
            self._counts[level] = self._counts[level] + 1
        if self.path is not None:
            write_jsonl(self.path, [rec], append=True)
        return rec

    def info(self, event: str, **fields) -> Dict:
        return self.log(event, level="info", **fields)

    def warning(self, event: str, **fields) -> Dict:
        return self.log(event, level="warning", **fields)

    def error(self, event: str, **fields) -> Dict:
        return self.log(event, level="error", **fields)

    def recent(self, n: Optional[int] = None,
               level: Optional[str] = None) -> List[Dict]:
        """The newest ``n`` records (all retained when ``n`` is None)."""
        with self._lock:
            records = list(self._recent)
        if level is not None:
            records = [r for r in records if r["level"] == level]
        if n is not None:
            records = records[-n:]
        return records

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)
