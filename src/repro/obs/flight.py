"""Always-on flight recorder: fixed-size per-thread span/event rings.

The PR-7 tracer is export-on-demand: a timeline exists only if the
operator installed a collector *before* the anomaly. Production
incidents do not announce themselves, so this module keeps the last N
records per thread in a preallocated ring buffer that records **even
when tracing is off** — then :meth:`FlightRecorder.dump` reconstructs
the final seconds before any trigger (SLO breach, ``WorkerError``,
stop-timeout stranding) as the same Chrome-trace JSON
``scripts/check_trace.py`` already validates.

Design constraints, in order:

* **No allocation on the hot path.** Every ring slot is a fixed-shape
  list preallocated at ring creation; ``put`` mutates the slot fields in
  place under a per-ring lock. Recording a span touches one lock, nine
  list stores and two integer adds — measured well under the 5%
  serving-load budget gated by ``BENCH_obs.json`` (``flight`` section).
* **Overwrite-oldest.** The ring wraps; a monotonically increasing
  per-ring ``seq`` stamps every record so a dump can prove the retained
  history is gap-free (``check_trace.py --flight`` checks seq
  contiguity per ring).
* **Per-thread rings.** One ring per recording OS thread — no
  cross-thread contention on the hot path. Rings are registered by
  thread id; a thread-local caches the calling thread's ring so the
  registry lock is only taken on first use per thread.

Installation is process-global (``install()`` / ``uninstall()``), and
``repro.obs`` installs a default recorder at import time unless
``REPRO_FLIGHT=off`` (capacity via ``REPRO_FLIGHT_SLOTS``, default
2048 slots/thread). ``obs.span``/``obs.event``/``obs.span_at`` feed the
recorder from ``trace.py`` whenever one is installed, independent of
the ``Options(trace=)`` tri-state.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional

from repro.obs import trace as _trace_mod
from repro.obs.trace import _TID_META_PID, now_ns

DEFAULT_CAPACITY = 2048

# slot field indices (a slot is a fixed 9-element list, mutated in place)
_SEQ, _PH, _NAME, _T0, _T1, _TRACE_ID, _ATTRS, _LANE_TID, _LANE = range(9)


class _Ring:
    """One thread's preallocated record ring.

    ``slots`` is a list of ``capacity`` fixed-shape lists; ``head`` is
    the next slot to (over)write and ``seq`` the total records ever
    written — so ``seq - capacity`` is the oldest retained sequence
    number once the ring has wrapped.
    """

    __slots__ = ("tid", "lane", "slots", "head", "seq", "lock")

    def __init__(self, tid: int, lane: str, capacity: int):
        self.tid = tid
        self.lane = lane
        self.slots: List[list] = [
            [0, "", "", 0, 0, None, None, None, None]
            for _ in range(capacity)]
        self.head = 0
        self.seq = 0
        self.lock = threading.Lock()

    def put(self, ph: str, name: str, t0_ns: int, t1_ns: int,
            trace_id: Optional[str], attrs: Optional[Dict],
            lane_tid: Optional[int], lane: Optional[str]) -> None:
        """Overwrite the oldest slot with one record. No allocation."""
        with self.lock:
            slot = self.slots[self.head]
            slot[_SEQ] = self.seq
            slot[_PH] = ph
            slot[_NAME] = name
            slot[_T0] = t0_ns
            slot[_T1] = t1_ns
            slot[_TRACE_ID] = trace_id
            slot[_ATTRS] = attrs
            slot[_LANE_TID] = lane_tid
            slot[_LANE] = lane
            self.head = (self.head + 1) % len(self.slots)
            self.seq = self.seq + 1

    def snapshot(self) -> List[list]:
        """Retained records oldest -> newest (copies; safe post-return)."""
        with self.lock:
            n = len(self.slots)
            count = min(self.seq, n)
            start = (self.head - count) % n
            out = []
            for i in range(count):
                out.append(list(self.slots[(start + i) % n]))
            return out


class FlightRecorder:
    """Process-wide black box: per-thread rings + Chrome-trace dumps."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 name: str = "flight"):
        if capacity < 1:
            raise ValueError(f"flight capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.name = name
        self._lock = threading.Lock()
        self._rings: Dict[int, _Ring] = {}
        self._tls = threading.local()
        self._dumps = 0

    # -- recording (hot path) ----------------------------------------------

    def _ring(self) -> _Ring:
        """The calling thread's ring (registered on first use)."""
        ring = getattr(self._tls, "ring", None)
        if ring is not None:
            return ring
        tid = threading.get_ident()
        lane = threading.current_thread().name
        ring = _Ring(tid, lane, self.capacity)
        with self._lock:
            # a reused OS tid replaces the dead thread's ring: one ring
            # per live tid keeps per-ring seq contiguity meaningful
            self._rings[tid] = ring
        self._tls.ring = ring
        return ring

    def record_span(self, name: str, t0_ns: int, t1_ns: int,
                    trace_id: Optional[str] = None,
                    attrs: Optional[Dict] = None,
                    lane_tid: Optional[int] = None,
                    lane: Optional[str] = None) -> None:
        self._ring().put("X", name, t0_ns, t1_ns, trace_id, attrs,
                         lane_tid, lane)

    def record_event(self, name: str, t_ns: Optional[int] = None,
                     trace_id: Optional[str] = None,
                     attrs: Optional[Dict] = None) -> None:
        if t_ns is None:
            t_ns = now_ns()
        self._ring().put("i", name, t_ns, t_ns, trace_id, attrs, None, None)

    # -- dumping -----------------------------------------------------------

    def dump(self, reason: Optional[str] = None) -> Dict:
        """All retained history as Chrome-trace JSON (a plain dict).

        Same shape as :meth:`obs.Trace.to_chrome`: complete ("X") and
        instant ("i") events with microsecond ``ts`` relative to the
        dump epoch (the earliest retained timestamp), ``thread_name``
        metadata per lane, and per-record ``args`` carrying the ring's
        ``seq``/``ring`` so ``check_trace.py --flight`` can prove the
        retained history is gap-free.
        """
        with self._lock:
            rings = list(self._rings.values())
            self._dumps = self._dumps + 1
        ring_snaps = [(r, r.snapshot()) for r in rings]

        epoch = None
        for _, snap in ring_snaps:
            for rec in snap:
                if epoch is None or rec[_T0] < epoch:
                    epoch = rec[_T0]
        if epoch is None:
            epoch = now_ns()

        events = []
        lanes: Dict[int, str] = {}
        total = 0
        dropped = 0
        for ring, snap in ring_snaps:
            total += len(snap)
            dropped += max(0, ring.seq - len(snap))
            lanes.setdefault(ring.tid, f"flight:{ring.lane}")
            for rec in snap:
                tid = ring.tid
                if rec[_LANE_TID] is not None:
                    tid = rec[_LANE_TID]
                    if rec[_LANE] is not None:
                        lanes.setdefault(tid, rec[_LANE])
                args = dict(rec[_ATTRS]) if rec[_ATTRS] else {}
                args["seq"] = rec[_SEQ]
                args["ring"] = ring.tid
                if rec[_TRACE_ID] is not None:
                    args["trace_id"] = rec[_TRACE_ID]
                ev = {"name": rec[_NAME], "ph": rec[_PH],
                      "cat": rec[_NAME].split(".", 1)[0],
                      "pid": _TID_META_PID, "tid": tid,
                      "ts": (rec[_T0] - epoch) / 1e3, "args": args}
                if rec[_PH] == "X":
                    ev["dur"] = (rec[_T1] - rec[_T0]) / 1e3
                else:
                    ev["s"] = "t"
                events.append(ev)

        meta = [{"name": "thread_name", "ph": "M", "pid": _TID_META_PID,
                 "tid": tid, "args": {"name": lane}}
                for tid, lane in sorted(lanes.items(), key=lambda kv: kv[0])]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"flight": self.name,
                              "reason": reason,
                              "capacity": self.capacity,
                              "rings": len(ring_snaps),
                              "records": total,
                              "dropped_total": dropped}}

    def stats(self) -> Dict:
        with self._lock:
            rings = list(self._rings.values())
            dumps = self._dumps
        retained = sum(min(r.seq, self.capacity) for r in rings)
        total = sum(r.seq for r in rings)
        return {"rings": len(rings), "capacity": self.capacity,
                "retained": retained, "recorded_total": total,
                "dropped_total": total - retained, "dumps": dumps}


# ---------------------------------------------------------------------------
# Process-global installation (mirrors trace.enable/disable)
# ---------------------------------------------------------------------------

_install_lock = threading.Lock()


def install(recorder: Optional[FlightRecorder] = None) -> FlightRecorder:
    """Install ``recorder`` (or a fresh one) as the process flight box."""
    with _install_lock:
        if recorder is None:
            recorder = FlightRecorder()
        _trace_mod._flight = recorder
        return recorder


def uninstall() -> Optional[FlightRecorder]:
    """Remove the flight recorder; returns it (for a final dump) or None."""
    with _install_lock:
        recorder = _trace_mod._flight
        _trace_mod._flight = None
        return recorder


def get_flight() -> Optional[FlightRecorder]:
    """The installed flight recorder, if any."""
    return _trace_mod._flight


def install_default() -> Optional[FlightRecorder]:
    """The import-time default: on unless ``REPRO_FLIGHT=off``.

    ``REPRO_FLIGHT_SLOTS`` overrides the per-thread capacity. Called
    once from ``repro.obs.__init__``; explicit ``install()``/
    ``uninstall()`` calls afterwards win.
    """
    mode = os.environ.get("REPRO_FLIGHT", "").strip().lower()
    if mode in ("off", "0", "false", "no"):
        return None
    capacity = int(os.environ.get("REPRO_FLIGHT_SLOTS", DEFAULT_CAPACITY))
    return install(FlightRecorder(capacity=capacity))
