"""Declarative SLOs with rolling-window evaluation.

The deadline-shedding scheduler (PR 5) exists to protect a latency
objective, but nothing *watched* that objective: an operator learned
about a p99 blowout or a shed spike from an angry dashboard, not from
the server. This module closes the loop:

    server.register("lenet", program,
                    slo=obs.SLO(p99_ms=50.0, max_shed_rate=0.05))

:class:`SLO` declares the objectives; :class:`SLOMonitor` keeps a
rolling window of request outcomes (served / shed / failed, with
latencies) and evaluates the objectives on every observation — but at
most once per ``eval_every_s`` (default ``window_s / 8``) so a
saturated server is not computing percentiles per request. A breach
report names the objective, the measured value and the limit; the
``Server`` turns reports into ``slo.breach.<program>`` counter
increments, a structured log event and a rate-limited flight dump (see
``docs/observability.md`` for breach semantics).

Timestamps are caller-supplied seconds (the serving runtime passes its
injectable ``Clock``), so the whole engine is deterministic under
``VirtualClock`` in tests.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

KINDS = ("served", "shed", "failed")


@dataclass(frozen=True)
class SLO:
    """Per-program service-level objectives over a rolling window.

    Any subset of objectives may be set (at least one must be):

    * ``p99_ms`` — 99th-percentile served latency must stay below this.
    * ``max_shed_rate`` — fraction of window requests deadline-shed.
    * ``max_error_rate`` — fraction of window requests failed
      (``WorkerError``).
    * ``window_s`` — rolling window length in seconds.
    * ``min_count`` — objectives are not evaluated until the window
      holds at least this many outcomes (a 1-request window has a
      meaningless p99).
    * ``eval_every_s`` — minimum spacing between evaluations; ``None``
      means ``max(window_s / 8, 0.25)``. Pass ``0`` to evaluate on
      every observation (tests).
    """

    p99_ms: Optional[float] = None
    max_shed_rate: Optional[float] = None
    max_error_rate: Optional[float] = None
    window_s: float = 60.0
    min_count: int = 1
    eval_every_s: Optional[float] = None

    def __post_init__(self):
        if (self.p99_ms is None and self.max_shed_rate is None
                and self.max_error_rate is None):
            raise ValueError("SLO needs at least one objective "
                             "(p99_ms / max_shed_rate / max_error_rate)")
        if self.p99_ms is not None and self.p99_ms <= 0:
            raise ValueError(f"p99_ms must be > 0, got {self.p99_ms}")
        for fname in ("max_shed_rate", "max_error_rate"):
            v = getattr(self, fname)
            if v is not None and not (0.0 <= v <= 1.0):
                raise ValueError(f"{fname} must be in [0, 1], got {v}")
        if self.window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {self.window_s}")
        if self.min_count < 1:
            raise ValueError(f"min_count must be >= 1, got {self.min_count}")
        if self.eval_every_s is not None and self.eval_every_s < 0:
            raise ValueError(f"eval_every_s must be >= 0, "
                             f"got {self.eval_every_s}")

    @property
    def eval_spacing_s(self) -> float:
        if self.eval_every_s is not None:
            return self.eval_every_s
        return max(self.window_s / 8.0, 0.25)


class SLOMonitor:
    """Rolling-window evaluator for one hosted program's :class:`SLO`."""

    def __init__(self, name: str, slo: SLO):
        self.name = name
        self.slo = slo
        self._lock = threading.Lock()
        self._window: deque = deque()     # (t_s, kind, latency_ms | None)
        self._breach_counts: Dict[str, int] = {}
        self._last_eval_t: Optional[float] = None
        self._last_breach_t: Optional[float] = None

    # -- feeding -----------------------------------------------------------

    def observe(self, kind: str, t: float,
                latency_ms: Optional[float] = None) -> List[Dict]:
        """Record one request outcome at time ``t`` (seconds).

        Returns the list of *new* breach reports from this evaluation
        tick (usually empty; also empty between throttled ticks).
        """
        if kind not in KINDS:
            raise ValueError(f"unknown outcome {kind!r}; expected one of "
                             f"{KINDS}")
        with self._lock:
            self._window.append((t, kind, latency_ms))
            self._prune(t)
            if (self._last_eval_t is not None
                    and t - self._last_eval_t < self.slo.eval_spacing_s):
                return []
            self._last_eval_t = t
            breaches = self._evaluate(t)
            if breaches:
                self._last_breach_t = t
                for b in breaches:
                    obj = b["objective"]
                    self._breach_counts[obj] = \
                        self._breach_counts.get(obj, 0) + 1
            return breaches

    def _prune(self, t: float) -> None:
        # caller holds self._lock
        horizon = t - self.slo.window_s
        window = self._window
        while window and window[0][0] < horizon:
            window.popleft()

    # -- evaluating --------------------------------------------------------

    def _values(self) -> Dict[str, Optional[float]]:
        # caller holds self._lock
        n = len(self._window)
        out: Dict[str, Optional[float]] = {"n": n, "p99_ms": None,
                                           "shed_rate": None,
                                           "error_rate": None}
        if n == 0:
            return out
        shed = sum(1 for _, kind, _ in self._window if kind == "shed")
        failed = sum(1 for _, kind, _ in self._window if kind == "failed")
        out["shed_rate"] = shed / n
        out["error_rate"] = failed / n
        lats = [lat for _, kind, lat in self._window
                if kind == "served" and lat is not None]
        if lats:
            out["p99_ms"] = float(np.percentile(lats, 99))
        return out

    def _evaluate(self, t: float) -> List[Dict]:
        # caller holds self._lock
        slo = self.slo
        vals = self._values()
        if vals["n"] < slo.min_count:
            return []
        breaches = []

        def breach(objective, value, limit):
            breaches.append({"objective": objective, "value": value,
                             "limit": limit, "window_s": slo.window_s,
                             "n": vals["n"]})

        if (slo.p99_ms is not None and vals["p99_ms"] is not None
                and vals["p99_ms"] > slo.p99_ms):
            breach("p99_ms", vals["p99_ms"], slo.p99_ms)
        if (slo.max_shed_rate is not None
                and vals["shed_rate"] > slo.max_shed_rate):
            breach("shed_rate", vals["shed_rate"], slo.max_shed_rate)
        if (slo.max_error_rate is not None
                and vals["error_rate"] > slo.max_error_rate):
            breach("error_rate", vals["error_rate"], slo.max_error_rate)
        return breaches

    # -- reading -----------------------------------------------------------

    def state(self, t: Optional[float] = None) -> Dict:
        """Current window values vs limits (the ``/statusz`` SLO block)."""
        slo = self.slo
        with self._lock:
            if t is not None:
                self._prune(t)
            vals = self._values()
            return {
                "window_s": slo.window_s,
                "n": vals["n"],
                "objectives": {
                    "p99_ms": {"value": vals["p99_ms"], "limit": slo.p99_ms},
                    "shed_rate": {"value": vals["shed_rate"],
                                  "limit": slo.max_shed_rate},
                    "error_rate": {"value": vals["error_rate"],
                                   "limit": slo.max_error_rate},
                },
                "breaches": dict(self._breach_counts),
                "last_breach_t": self._last_breach_t,
            }
