"""Structured diagnostics for the static-analysis passes.

Every finding the plan verifier (:mod:`repro.analysis.verifier`) or the
concurrency lint (:mod:`repro.analysis.lint`) emits is a
:class:`Diagnostic`: a stable code (``LTR…`` for plan/runtime invariants,
``LTC…`` for concurrency rules — the glossary lives in
``docs/analysis.md``), a severity, the step/site it anchors to, a message
stating the violated invariant, and a fix hint. Codes are API: tests and
CI match on them, so a code is never renamed or reused once shipped.

Severities:

``error``    a proven invariant violation — the compile pass raises
             :class:`PlanVerificationError` (under ``Options(verify=)``
             "auto"/"on") and the CI gates fail.
``warning``  suspicious but not provably wrong (e.g. a *forced* resident
             conv exceeding the VMEM budget); surfaced in
             ``ModelReport.verification`` and the CLI, never raised.
``info``     per-step facts worth reporting (accumulator headroom in
             bits); returned by :func:`repro.analysis.verify_plan` and
             printed by ``scripts/verify_plan.py``, but kept out of
             ``ModelReport`` so clean eager/compiled reports stay
             field-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Sequence, Tuple

SEVERITIES = ("info", "warning", "error")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding from a static-analysis pass.

    ``step`` is the plan step / layer name for verifier findings, or
    ``path:line`` for lint findings. ``hint`` is the suggested fix —
    always actionable, never a restatement of the message.
    """

    code: str                      # stable, e.g. "LTR001"
    severity: str                  # "info" | "warning" | "error"
    step: str
    message: str
    hint: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; expected "
                             f"one of {SEVERITIES}")

    def asdict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        hint = f" (hint: {self.hint})" if self.hint else ""
        return f"{self.code} [{self.severity}] {self.step}: " \
               f"{self.message}{hint}"


def errors(diags: Iterable[Diagnostic]) -> Tuple[Diagnostic, ...]:
    """The error-severity subset, in order."""
    return tuple(d for d in diags if d.severity == "error")


def worst_severity(diags: Iterable[Diagnostic]) -> str:
    """The highest severity present ("info" for an empty sequence)."""
    rank = {s: i for i, s in enumerate(SEVERITIES)}
    worst = "info"
    for d in diags:
        if rank[d.severity] > rank[worst]:
            worst = d.severity
    return worst


def format_diagnostics(diags: Sequence[Diagnostic],
                       min_severity: str = "info") -> str:
    """One line per diagnostic at or above ``min_severity``."""
    floor = SEVERITIES.index(min_severity)
    return "\n".join(str(d) for d in diags
                     if SEVERITIES.index(d.severity) >= floor)


class PlanVerificationError(ValueError):
    """A compiled plan failed verification at error severity.

    Raised by ``Program.compile`` under ``Options(verify=)`` "auto"/"on"
    (and by :func:`repro.analysis.verify_plan` callers that choose to).
    Carries the full diagnostic list — error *and* lower severities — so
    callers can render the complete report, not just the fatal line.
    """

    def __init__(self, diags: Sequence[Diagnostic]):
        self.diagnostics: Tuple[Diagnostic, ...] = tuple(diags)
        errs = errors(self.diagnostics)
        lines = "\n".join(f"  {d}" for d in errs)
        super().__init__(
            f"plan verification failed with {len(errs)} error(s):\n{lines}\n"
            f"(compile with Options(verify=\"off\") to bypass — the kernels "
            f"only assert these invariants, they do not enforce them)")
