"""Compile-time plan verifier: prove the invariants the kernels assert.

The conv/dense kernels (``kernels/conv_bank``, ``kernels/photonic_mvm``)
accumulate quantized codes in f32 and *declare* integer-exactness —
``|sum| < 2^24`` — in a comment; the VMEM-budget strip heuristic and the
megakernel fusion pass (``kernels.dispatch``) are trusted rather than
audited. This pass turns those declarations into checks over a
:class:`~repro.core.plan.CompiledPlan`:

**Accumulator range analysis** (``LTR001``–``LTR003``). Activations are
unsigned CRC codes in ``[0, a_qmax]`` (``a_qmax = 2^ACT_BITS - 1``: the
compile pass feeds ONE global divisor to the executor regardless of
per-layer ``a_bits``); weights are symmetric signed codes in
``[-w_qmax, w_qmax]``. A dot product over ``K`` taps therefore satisfies

    |acc| <= a_qmax * w_qmax * K

*exactly* (the bound is attained by all-max codes under all-(-max)
weights), with ``K = kernel^2 * (c_in / groups)`` for convs and
``K = fan_in`` for FC layers. f32 represents every integer with
``|x| <= 2^24`` exactly, so ``bound < 2^24`` *proves* the accumulate is
integer-exact for every possible input — no test vector needed. The
verifier reports per-step headroom, ``log2(2^24 / bound)`` bits: how many
doublings of fan-in (or of ``w_qmax``) the layer could absorb.

**Shape legality** (``LTR010``–``LTR015``). An independent re-walk of the
layer IR from the frame shape: CA/pool divisibility, declared ``c_in`` /
``fan_in`` against the incoming tensor (the compile pass schedules from
the *declared* dims and would only fail at run time, inside the jitted
executor), depthwise channel equality, and act/pool/upsample vocabulary.

**VMEM / fusion audit** (``LTR020``–``LTR025``). An N-version check: the
strip geometry and fused-segment footprints are re-derived here from
first principles — the halo recurrence ``rows_in = (rows_out - 1) *
stride + kernel`` (pool expands first), padded-input/output/weight byte
counts — and compared against what ``select_conv_strategy`` /
``select_fused_segments`` recorded in the plan. The heuristic deciding a
*policy* differently is fine; the heuristic recording geometry that does
not cover the output, or selecting a segment that is not legally fusable,
is an error.

Severities follow :mod:`repro.analysis.diagnostics`: errors raise at
compile time under ``Options(verify=)`` "auto"/"on"; warnings surface in
``ModelReport.verification``; info (the headroom report) stays out of the
report so clean eager/compiled reports remain field-identical.
"""

from __future__ import annotations

import math
import os
from typing import List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import (Diagnostic, PlanVerificationError,
                                        errors)

# f32 exact-integer window: every |int| <= 2^24 is representable exactly.
ACC_EXACT_LIMIT = 1 << 24

# Headroom (in bits) under which a layer gets a warning: one more doubling
# of fan-in or weight range would push it out of the exact window.
LOW_HEADROOM_BITS = 1.0

VERIFY_MODES = ("auto", "on", "off")

# Independent copies of the fusion pass's legality vocabulary — deliberately
# NOT imported from kernels.dispatch, so a dispatch-side edit that widens
# the heuristic without teaching the fused kernel shows up as an audit
# failure here instead of a silent numerics bug.
_FUSABLE_ACTS = ("relu", "abs", "sign", "none")
_KNOWN_ACTS = ("relu", "sign", "tanh", "abs", "none")
_POOL_KINDS = ("max", "avg")
_UPSAMPLE_METHODS = ("bilinear", "nearest")


def verify_mode() -> str:
    """The ambient verify mode: ``REPRO_VERIFY`` or ``auto``."""
    env = os.environ.get("REPRO_VERIFY", "").strip().lower()
    if not env:
        return "auto"
    if env not in VERIFY_MODES:
        raise ValueError(
            f"REPRO_VERIFY={env!r}; expected one of {VERIFY_MODES}")
    return env


# ---------------------------------------------------------------------------
# Accumulator range analysis
# ---------------------------------------------------------------------------

def acc_bound(a_qmax: int, w_qmax: int, fan_in: int) -> int:
    """Worst-case |accumulator| of a ``fan_in``-tap quantized dot product."""
    return int(a_qmax) * int(w_qmax) * int(fan_in)


def headroom_bits(bound: int) -> float:
    """log2(2^24 / bound): doublings of fan-in left inside the window."""
    return math.log2(ACC_EXACT_LIMIT / max(bound, 1))


def _check_accumulators(plan, out: List[Diagnostic],
                        include_info: bool = True) -> None:
    from repro.core import plan as plan_mod

    ConvStep, DenseStep = plan_mod.ConvStep, plan_mod.DenseStep
    a_qmax = int(plan.consts.get("a_qmax", 15))
    # the warning threshold, as a pure-integer comparison (the clean path
    # must not pay a log2 per step): headroom < LOW_HEADROOM_BITS bits
    # <=> bound * 2^LOW_HEADROOM_BITS > ACC_EXACT_LIMIT
    warn_above = int(ACC_EXACT_LIMIT / 2 ** LOW_HEADROOM_BITS)
    for step in plan.steps:
        if isinstance(step, ConvStep):
            g = step.geom
            fan_in = step.kernel * step.kernel * (g.c_in // g.groups)
        elif isinstance(step, DenseStep):
            fan_in = _dense_fan_in(plan, step)
        else:
            continue
        bound = a_qmax * step.wa.w_qmax * fan_in
        if bound < ACC_EXACT_LIMIT and bound <= warn_above \
                and not include_info:
            continue                       # proven clean: nothing to say
        kind = (f"conv k={step.kernel} c_in={step.geom.c_in}"
                + (f" groups={step.geom.groups}"
                   if step.geom.groups > 1 else "")
                if isinstance(step, ConvStep) else f"fc fan_in={fan_in}")
        if bound >= ACC_EXACT_LIMIT:
            out.append(Diagnostic(
                "LTR001", "error", step.name,
                f"worst-case |accumulator| = {a_qmax} * {step.wa.w_qmax} * "
                f"{fan_in} = {bound} >= 2^24 = {ACC_EXACT_LIMIT}: the f32 "
                f"accumulate is not integer-exact for all inputs ({kind}, "
                f"scheme {step.wa.name})",
                hint="lower w_bits for this layer (MixedPrecisionScheme), "
                     "reduce its fan-in, or split it into grouped partial "
                     "sums under 2^24 each"))
            continue
        hb = headroom_bits(bound)
        if hb < LOW_HEADROOM_BITS:
            out.append(Diagnostic(
                "LTR002", "warning", step.name,
                f"accumulator headroom is only {hb:.2f} bits "
                f"(worst-case |acc| = {bound} of {ACC_EXACT_LIMIT}): "
                f"one fan-in doubling away from losing integer "
                f"exactness",
                hint="treat this layer as frozen geometry, or lower "
                     "w_bits to buy headroom"))
        if include_info:
            out.append(Diagnostic(
                "LTR003", "info", step.name,
                f"|acc| <= {bound} < 2^24, headroom {hb:.2f} bits ({kind}, "
                f"scheme {step.wa.name})"))


def _dense_fan_in(plan, step) -> int:
    """The declared fan_in of a DenseStep, from its paired IR layer
    (steps and layers are built 1:1 by the compile pass)."""
    from repro.core.accelerator import DenseSpec
    for layer, s in zip(plan.layers, plan.steps):
        if s is step and isinstance(layer, DenseSpec):
            return layer.fan_in
    raise AssertionError(f"dense step {step.name!r} has no paired DenseSpec")


# ---------------------------------------------------------------------------
# Shape legality (independent IR re-walk)
# ---------------------------------------------------------------------------

def _conv_out(hw: int, kernel: int, stride: int, padding: str) -> int:
    # independent of plan.conv_out_hw: XLA semantics re-stated from the doc
    if padding == "VALID":
        return (hw - kernel) // stride + 1
    return (hw + stride - 1) // stride            # SAME: ceil


def _check_shapes(layers: Sequence, frame_shape: Tuple[int, int, int],
                  out: List[Diagnostic]) -> None:
    from repro.core.accelerator import (CASpec, ConvSpec, DenseSpec,
                                        FlattenSpec, UpsampleSpec)
    h, w, c = frame_shape
    for i, layer in enumerate(layers):
        name = getattr(layer, "name", None) \
            or f"{type(layer).__name__.lower()}.{i}"
        if isinstance(layer, CASpec):
            if h % layer.pool or w % layer.pool:
                out.append(Diagnostic(
                    "LTR010", "error", name,
                    f"CA pool={layer.pool} does not divide the incoming "
                    f"{h}x{w} frame",
                    hint="pick a frame size divisible by the CA pool, or "
                         "a pool that divides the frame"))
                return
            h, w = h // layer.pool, w // layer.pool
            rgb = (layer.rgb_to_gray if layer.rgb_to_gray is not None
                   else c == 3)
            c = 1 if (rgb or c == 1) else c
        elif isinstance(layer, ConvSpec):
            if layer.c_in != c:
                out.append(Diagnostic(
                    "LTR013", "error", name,
                    f"declares c_in={layer.c_in} but receives {c} "
                    f"channel(s): the jitted executor would fail at run "
                    f"time with a shape error",
                    hint=f"set c_in={c} (the upstream layer's output "
                         f"channels), or fix the upstream c_out"))
                return
            if layer.depthwise and layer.c_out != layer.c_in:
                out.append(Diagnostic(
                    "LTR012", "error", name,
                    f"depthwise conv needs c_out == c_in (got "
                    f"{layer.c_in} -> {layer.c_out})",
                    hint="set c_out = c_in, or drop depthwise"))
                return
            if layer.act not in _KNOWN_ACTS:
                out.append(Diagnostic(
                    "LTR015", "error", name,
                    f"unknown activation {layer.act!r}; supported: "
                    f"{_KNOWN_ACTS}",
                    hint="pick a supported activation"))
            h = _conv_out(h, layer.kernel, layer.stride, layer.padding)
            w = _conv_out(w, layer.kernel, layer.stride, layer.padding)
            c = layer.c_out
            if layer.pool is not None:
                kind, size = layer.pool
                if kind not in _POOL_KINDS:
                    out.append(Diagnostic(
                        "LTR015", "error", name,
                        f"unknown pool kind {kind!r}; supported: "
                        f"{_POOL_KINDS} (the executor would silently "
                        f"average an unknown kind)",
                        hint="use ('max', n) or ('avg', n)"))
                if h % size or w % size:
                    out.append(Diagnostic(
                        "LTR011", "error", name,
                        f"{kind}-pool size={size} does not divide the "
                        f"{h}x{w} conv output",
                        hint="adjust the frame size, conv padding, or "
                             "pool size so the output tiles evenly"))
                    return
                h, w = h // size, w // size
        elif isinstance(layer, UpsampleSpec):
            if layer.method not in _UPSAMPLE_METHODS:
                out.append(Diagnostic(
                    "LTR015", "error", name,
                    f"unknown upsample method {layer.method!r}; "
                    f"supported: {_UPSAMPLE_METHODS}",
                    hint="use 'bilinear' or 'nearest'"))
            h, w = h * layer.factor, w * layer.factor
        elif isinstance(layer, FlattenSpec):
            h, w, c = 1, 1, h * w * c
        elif isinstance(layer, DenseSpec):
            if layer.fan_in != h * w * c:
                out.append(Diagnostic(
                    "LTR014", "error", name,
                    f"declares fan_in={layer.fan_in} but receives "
                    f"{h * w * c} feature(s) "
                    f"({h}x{w}x{c}): the jitted executor would fail at "
                    f"run time with a shape error",
                    hint=f"set fan_in={h * w * c}, or insert/fix the "
                         f"Flatten/upstream layer"))
                return
            if layer.act not in _KNOWN_ACTS:
                out.append(Diagnostic(
                    "LTR015", "error", name,
                    f"unknown activation {layer.act!r}; supported: "
                    f"{_KNOWN_ACTS}",
                    hint="pick a supported activation"))
            h, w, c = 1, 1, layer.fan_out


# ---------------------------------------------------------------------------
# VMEM / strategy audit (N-version re-derivation)
# ---------------------------------------------------------------------------

def _geom_out_hw(g) -> Tuple[int, int]:
    """Pre-pool conv output dims from a ChainGeom, re-derived."""
    (plo, phi), (qlo, qhi) = g.pads
    h = (g.h_in + plo + phi - g.kernel) // g.stride + 1
    w = (g.w_in + qlo + qhi - g.kernel) // g.stride + 1
    return h, w


def _geom_stage_bytes(g) -> int:
    """f32 working set of one fused stage: padded input + output + weights
    (independent restatement of ``ChainGeom.stage_bytes``)."""
    (plo, phi), (qlo, qhi) = g.pads
    h_out, w_out = _geom_out_hw(g)
    in_b = (g.h_in + plo + phi) * (g.w_in + qlo + qhi) * g.c_in * 4
    out_b = h_out * w_out * g.c_out * 4
    w_b = g.kernel * g.kernel * (g.c_in // g.groups) * g.c_out * 4
    return in_b + out_b + w_b


def _chain_halo(geoms: Sequence) -> int:
    """Extra input rows one output row needs through the chain: the
    back-substituted recurrence ``rows_in = (rows_out - 1) * stride +
    kernel``, pool expanding ``rows_out`` first."""
    rows = 1
    for g in reversed(tuple(geoms)):
        if g.pool is not None:
            rows *= g.pool[1]
        rows = (rows - 1) * g.stride + g.kernel
    return rows - 1


def _check_strategies(plan, budget: int, out: List[Diagnostic]) -> None:
    from repro.core import plan as plan_mod

    for step in plan.steps:
        if not isinstance(step, plan_mod.ConvStep) or step.strategy is None:
            continue
        g = step.geom
        h_out, w_out = _geom_out_hw(g)
        strat = step.strategy
        if strat.kind == "resident":
            patch = h_out * w_out * step.kernel * step.kernel * g.c_in * 4
            if patch > budget:
                out.append(Diagnostic(
                    "LTR021", "warning", step.name,
                    f"resident conv's im2col patch matrix is "
                    f"{patch / 2**20:.1f} MB, over the "
                    f"{budget / 2**20:.1f} MB VMEM budget (forced "
                    f"resident, or a heuristic/budget mismatch)",
                    hint="let conv_strategy='auto' strip-mine this "
                         "layer, or raise REPRO_CONV_VMEM_BUDGET"))
        elif strat.kind == "strip":
            if strat.strip_rows < 1 or strat.n_strips < 1:
                out.append(Diagnostic(
                    "LTR020", "error", step.name,
                    f"strip strategy carries degenerate geometry "
                    f"(strip_rows={strat.strip_rows}, "
                    f"n_strips={strat.n_strips})",
                    hint="this is a dispatch-heuristic bug: "
                         "_strip_geometry must return >= 1 rows/strips"))
                continue
            if strat.strip_rows * strat.n_strips < h_out:
                out.append(Diagnostic(
                    "LTR020", "error", step.name,
                    f"strip tiling does not cover the output: "
                    f"{strat.n_strips} strips x {strat.strip_rows} rows "
                    f"= {strat.n_strips * strat.strip_rows} < "
                    f"h_out={h_out} — the kernel would drop output rows",
                    hint="this is a dispatch-heuristic bug in "
                         "_strip_geometry's ceil-division"))
            (plo, phi), (qlo, qhi) = g.pads
            in_rows = (strat.strip_rows - 1) * g.stride + g.kernel
            strip_bytes = in_rows * (g.w_in + qlo + qhi) * g.c_in * 4
            if strat.strip_rows > 1 and strip_bytes > budget:
                out.append(Diagnostic(
                    "LTR022", "warning", step.name,
                    f"one input strip (+halo) is "
                    f"{strip_bytes / 2**20:.1f} MB, over the full "
                    f"{budget / 2**20:.1f} MB VMEM budget",
                    hint="shrink REPRO_CONV_VMEM_BUDGET-derived strips "
                         "or check _strip_geometry's row bound"))
        else:
            out.append(Diagnostic(
                "LTR020", "error", step.name,
                f"unknown conv strategy kind {strat.kind!r}",
                hint="expected 'resident' or 'strip'"))


def audit_fused_segments(geoms: Sequence, segments: Sequence,
                         budget: int) -> List[Diagnostic]:
    """Audit ``select_fused_segments`` output against an independent
    legality re-derivation.

    ``geoms`` is the step-aligned geometry list the selector consumed
    (``ChainGeom`` per conv step, ``None`` elsewhere); ``segments`` its
    output. Errors mean the heuristic selected a segment the fused
    kernel cannot legally execute, or recorded halo/VMEM numbers that
    disagree with the recurrence — exactly the N-version property
    ``tests/test_analysis.py`` fuzzes.
    """
    out: List[Diagnostic] = []
    covered: set = set()
    for seg in segments:
        name = "+".join(seg.names) or f"segment@{seg.start}"
        span = range(seg.start, seg.start + seg.length)
        if seg.start < 0 or seg.start + seg.length > len(geoms):
            out.append(Diagnostic(
                "LTR023", "error", name,
                f"fused segment [{seg.start}, {seg.start + seg.length}) "
                f"falls outside the {len(geoms)}-step plan",
                hint="select_fused_segments emitted a bad start/length"))
            continue
        if any(i in covered for i in span):
            out.append(Diagnostic(
                "LTR023", "error", name,
                "fused segments overlap: one step is claimed by two "
                "launches",
                hint="select_fused_segments must emit disjoint runs"))
        covered.update(span)
        run = [geoms[i] for i in span]
        bad = None
        for g in run:
            if g is None:
                bad = "covers a non-conv step"
            elif g.groups != 1 and not g.depthwise:
                bad = f"stage {g.name!r} is grouped but not depthwise"
            elif g.act not in _FUSABLE_ACTS:
                bad = (f"stage {g.name!r} activation {g.act!r} has no "
                       f"fused epilogue (supported: {_FUSABLE_ACTS})")
            elif g.pool is not None and g.pool[0] not in _POOL_KINDS:
                bad = f"stage {g.name!r} pool kind {g.pool[0]!r} unknown"
            if bad:
                break
        if bad:
            out.append(Diagnostic(
                "LTR023", "error", name,
                f"illegal fused segment: {bad} — the megakernel would "
                f"compute the wrong epilogue or crash",
                hint="this is a _fusable/select_fused_segments bug; the "
                     "segment must be split at the illegal stage"))
            continue
        halo = _chain_halo(run)
        if halo != seg.halo_rows:
            out.append(Diagnostic(
                "LTR024", "error", name,
                f"halo audit mismatch: plan records {seg.halo_rows} "
                f"rows, the back-substituted recurrence derives {halo}",
                hint="_chain_halo_rows and the audit disagree — one of "
                     "them mis-handles a stride/pool/kernel case"))
        vmem = max(_geom_stage_bytes(g) for g in run)
        if vmem != seg.vmem_bytes:
            out.append(Diagnostic(
                "LTR024", "error", name,
                f"VMEM audit mismatch: plan records {seg.vmem_bytes} "
                f"bytes, the independent footprint sum derives {vmem}",
                hint="ChainGeom.stage_bytes and the audit disagree on "
                     "padded-input/output/weight accounting"))
        elif vmem > budget:
            out.append(Diagnostic(
                "LTR025", "warning", name,
                f"fused segment peak stage working set "
                f"{vmem / 2**20:.1f} MB exceeds the "
                f"{budget / 2**20:.1f} MB VMEM budget (fuse='on' skips "
                f"the budget check)",
                hint="let fuse='auto' split the run, or raise "
                     "REPRO_CONV_VMEM_BUDGET"))
    return out


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def verify_plan(plan, budget: Optional[int] = None,
                include_info: bool = True) -> Tuple[Diagnostic, ...]:
    """Run every verifier check over a :class:`CompiledPlan`.

    Returns ALL diagnostics (info included), ordered check-by-check; use
    :func:`repro.analysis.diagnostics.errors` for the fatal subset, or
    :func:`raise_on_errors` to throw. ``budget`` is the VMEM budget the
    plan was compiled under; ``None`` reads the ambient
    ``conv_vmem_budget()`` (what an uncustomized compile used).
    ``include_info=False`` skips constructing info-severity diagnostics
    (the per-step headroom report) — the compile path uses it because
    ``ModelReport.verification`` only stores warnings/errors, and the
    proof itself is pure integer comparisons.
    """
    from repro.core import plan as plan_mod
    from repro.kernels import dispatch

    if budget is None:
        budget = dispatch.conv_vmem_budget()
    out: List[Diagnostic] = []
    _check_shapes(plan.layers, plan.frame_shape, out)
    _check_accumulators(plan, out, include_info=include_info)
    _check_strategies(plan, budget, out)
    geoms = [s.geom if isinstance(s, plan_mod.ConvStep) else None
             for s in plan.steps]
    out.extend(audit_fused_segments(geoms, plan.fused_segments, budget))
    return tuple(out)


def raise_on_errors(diags: Sequence[Diagnostic]) -> None:
    """Raise :class:`PlanVerificationError` if any error-severity
    diagnostic is present; no-op otherwise."""
    if errors(diags):
        raise PlanVerificationError(diags)
