"""Concurrency lint for the serving/observability runtime (AST-based).

The PR-7/8 review rounds each caught one instance of the same bug class:
shared mutable state touched without its lock (the queued-frames gauge's
bare ``+=``), and a future settled directly instead of through the
idempotent ``_settle`` helper (an ``InvalidStateError`` crash when the
other settler wins the race). Those are *mechanical* properties — this
module checks them over the source tree instead of waiting for review:

``LTC101`` (error) — an augmented assignment whose target reaches through
    an attribute (``self._total += n``, ``worker.inflight -= k``) outside
    any enclosing ``with <lock>:`` block. Attribute state is shared state
    in this codebase (every runtime object is touched from >= 2 threads);
    a read-modify-write outside the lock is a lost-update race.
    Lock-holding blocks are recognized syntactically: a ``with`` whose
    context expression mentions a name matching ``lock``/``cond``/
    ``mutex`` (``self._lock``, ``self._cond``, ``trace._lock``, a bare
    ``lock``). ``__init__``/``__post_init__``/``__new__`` are exempt (the
    object is not yet published), as are plain-name targets (locals).
    A nested function resets the lock context: its body runs when
    *called*, not where it is defined.

``LTC102`` (error) — a ``threading.Thread`` that is started but never
    joined. Matching is by dotted handle: ``x.thread =
    threading.Thread(...)`` + ``x.thread.start()`` with no
    ``x.thread.join(...)`` anywhere in the file, or an anonymous
    ``threading.Thread(...).start()`` chain. A daemon flag does not
    exempt: the stop path must bound shutdown, not abandon it.

``LTC103`` (error) — ``fut.set_result(...)`` / ``fut.set_exception(...)``
    called anywhere except inside a function named ``_settle``. Both
    sides of every settle race (completer vs timed-out stop vs deadline
    shed) must go through the idempotent helper so whichever runs second
    is a recorded no-op.

Suppression: append ``# lint: ok`` (optionally ``# lint: ok[LTC101]``)
to the flagged line. Use it for a documented single-threaded invariant,
not to mute a race.

Run as a CLI (what ``scripts/ci.sh`` gates)::

    python -m repro.analysis.lint src/repro/serve src/repro/obs

or programmatically via :func:`lint_paths` / :func:`lint_source`.
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.diagnostics import Diagnostic, errors

_LOCKISH = re.compile(r"lock|cond|mutex", re.IGNORECASE)
_SUPPRESS = re.compile(r"#\s*lint:\s*ok(?:\[(?P<codes>[A-Z0-9, ]+)\])?")

_EXEMPT_FUNCS = ("__init__", "__post_init__", "__new__")


def _dotted(node: ast.AST) -> Optional[str]:
    """`a.b.c` -> "a.b.c"; None for anything not a pure name/attr chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_lock(expr: ast.AST) -> bool:
    """Does any name/attribute inside ``expr`` look like a lock?"""
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and _LOCKISH.search(node.attr):
            return True
        if isinstance(node, ast.Name) and _LOCKISH.search(node.id):
            return True
    return False


def _is_thread_ctor(call: ast.AST) -> bool:
    """``threading.Thread(...)`` / ``Thread(...)`` (any module alias)."""
    if not isinstance(call, ast.Call):
        return False
    fn = call.func
    return (isinstance(fn, ast.Name) and fn.id == "Thread") or \
        (isinstance(fn, ast.Attribute) and fn.attr == "Thread")


class _FileLint:
    """One file's lint pass: a recursive walk carrying lock context."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.findings: List[Diagnostic] = []
        # LTC102 bookkeeping, file-global: start() in one method is
        # legitimately joined from another (start/stop pairs)
        self._thread_handles: dict = {}      # dotted name -> assign lineno
        self._started: dict = {}             # dotted name -> start lineno
        self._joined: set = set()

    # -- reporting ---------------------------------------------------------

    def _suppressed(self, lineno: int, code: str) -> bool:
        if not 1 <= lineno <= len(self.lines):
            return False
        m = _SUPPRESS.search(self.lines[lineno - 1])
        if not m:
            return False
        codes = m.group("codes")
        return codes is None or code in [c.strip()
                                         for c in codes.split(",")]

    def _flag(self, code: str, node: ast.AST, message: str,
              hint: str) -> None:
        lineno = getattr(node, "lineno", 0)
        if self._suppressed(lineno, code):
            return
        self.findings.append(Diagnostic(
            code, "error", f"{self.path}:{lineno}", message, hint))

    # -- the walk ----------------------------------------------------------

    def run(self) -> List[Diagnostic]:
        for stmt in self.tree.body:
            self._walk(stmt, locked=False, func_stack=())
        for handle, lineno in sorted(self._started.items(),
                                     key=lambda kv: kv[1]):
            if handle in self._joined:
                continue
            node = ast.Module(body=[], type_ignores=[])
            node.lineno = lineno
            self._flag(
                "LTC102", node,
                f"thread {handle!r} is start()ed but never join()ed in "
                f"this file: the stop path cannot bound its shutdown",
                "join it (with the stop timeout) wherever the owner "
                "stops, or suppress with a documented '# lint: ok' if "
                "its lifetime is provably process-long")
        return self.findings

    def _walk(self, node: ast.AST, locked: bool,
              func_stack: tuple) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def's body runs at call time — outside the lock
            inner = func_stack + (node.name,)
            for child in node.body:
                self._walk(child, locked=False, func_stack=inner)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            holds = any(_mentions_lock(item.context_expr)
                        for item in node.items)
            for item in node.items:
                self._visit_expr(item.context_expr, locked, func_stack)
            for child in node.body:
                self._walk(child, locked or holds, func_stack)
            return
        if isinstance(node, ast.AugAssign):
            self._check_augassign(node, locked, func_stack)
            self._visit_expr(node.value, locked, func_stack)
            return
        if isinstance(node, ast.Assign):
            self._check_thread_assign(node)
        # generic recursion: statements walk statements, expressions are
        # scanned for calls (start/join/settle)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._walk(child, locked, func_stack)
            elif isinstance(child, ast.expr):
                self._visit_expr(child, locked, func_stack)

    def _visit_expr(self, expr: ast.AST, locked: bool,
                    func_stack: tuple) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._check_call(node, func_stack)
            elif isinstance(node, (ast.Lambda,)):
                pass

    # -- LTC101 ------------------------------------------------------------

    def _check_augassign(self, node: ast.AugAssign, locked: bool,
                         func_stack: tuple) -> None:
        if locked or (func_stack and func_stack[-1] in _EXEMPT_FUNCS):
            return
        target = node.target
        # reach through subscripts: self.counts[i] += 1 mutates shared
        # attribute state just like self.count += 1
        while isinstance(target, ast.Subscript):
            target = target.value
        if not isinstance(target, ast.Attribute):
            return                          # plain local: not shared
        name = _dotted(node.target) or _dotted(target) or "<attr>"
        self._flag(
            "LTC101", node,
            f"augmented assignment to shared attribute {name!r} outside "
            f"a 'with <lock>:' block — a read-modify-write race",
            "hold the owning lock around the mutation (or route it "
            "through a locked helper like obs.Counter.inc)")

    # -- LTC102 ------------------------------------------------------------

    def _check_thread_assign(self, node: ast.Assign) -> None:
        if not _is_thread_ctor(node.value):
            return
        for tgt in node.targets:
            name = _dotted(tgt)
            if name:
                self._thread_handles[name] = node.lineno

    def _check_call(self, call: ast.Call, func_stack: tuple) -> None:
        fn = call.func
        if not isinstance(fn, ast.Attribute):
            return
        if fn.attr == "start":
            if _is_thread_ctor(fn.value):
                self._flag(
                    "LTC102", call,
                    "anonymous threading.Thread(...).start(): no handle "
                    "survives, so nothing can ever join it",
                    "keep the handle and join it on the stop path")
                return
            name = _dotted(fn.value)
            if name and name in self._thread_handles:
                self._started.setdefault(name, call.lineno)
        elif fn.attr == "join":
            name = _dotted(fn.value)
            if name:
                self._joined.add(name)
        elif fn.attr in ("set_result", "set_exception"):
            if "_settle" in func_stack:
                return
            self._flag(
                "LTC103", call,
                f"future.{fn.attr}() outside the idempotent _settle "
                f"helper: if the other settler (completer / timed-out "
                f"stop / shed) wins the race this raises "
                f"InvalidStateError on a runtime thread",
                "settle via _settle(future, ...) and count metrics only "
                "when it returns True")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>") -> List[Diagnostic]:
    """Lint one file's source text."""
    return _FileLint(path, source).run()


def lint_paths(paths: Sequence) -> List[Diagnostic]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: List[Diagnostic] = []
    for f in files:
        findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Concurrency lint: unlocked shared mutation, "
                    "unjoined threads, futures settled outside _settle.")
    ap.add_argument("paths", nargs="+", help=".py files or directories")
    args = ap.parse_args(argv)
    findings = lint_paths(args.paths)
    for d in findings:
        print(d)
    errs = errors(findings)
    n_files = sum(1 for p in args.paths)
    if errs:
        print(f"lint: FAIL — {len(errs)} error(s)", file=sys.stderr)
        return 1
    print(f"lint: OK ({n_files} path(s) clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
