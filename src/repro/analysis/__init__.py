"""repro.analysis — static verification of the invariants the kernels assert.

Two legs (see docs/analysis.md for the diagnostic-code glossary):

* :mod:`repro.analysis.verifier` — a compile-time pass over a
  ``CompiledPlan`` proving the ``|acc| < 2^24`` integer-exactness window,
  shape legality across ``Program.then`` chains, and auditing the
  strip/fusion VMEM heuristics with an independent re-derivation.
  Wired into ``Program.compile`` via ``Options(verify=)`` ("auto" | "on"
  | "off"; ambient default ``REPRO_VERIFY``).
* :mod:`repro.analysis.lint` — an AST concurrency lint over
  ``src/repro/serve`` + ``src/repro/obs`` (unlocked shared mutation,
  unjoined threads, futures settled outside ``_settle``), run by
  ``scripts/ci.sh`` as a gate.

The package imports no jax: it is safe to run the lint (and the
diagnostics types) in environments without the accelerator stack;
``verify_plan`` imports the core lazily.
"""

from repro.analysis.diagnostics import (Diagnostic, PlanVerificationError,
                                        SEVERITIES, errors,
                                        format_diagnostics, worst_severity)
from repro.analysis.verifier import (ACC_EXACT_LIMIT, VERIFY_MODES,
                                     acc_bound, audit_fused_segments,
                                     headroom_bits, raise_on_errors,
                                     verify_mode, verify_plan)


def __getattr__(name):
    # lazy: `python -m repro.analysis.lint` must not find the module
    # pre-imported by its own package (runpy's double-import warning)
    if name in ("lint_paths", "lint_source"):
        from repro.analysis import lint
        return getattr(lint, name)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")

__all__ = [
    "ACC_EXACT_LIMIT", "Diagnostic", "PlanVerificationError", "SEVERITIES",
    "VERIFY_MODES", "acc_bound", "audit_fused_segments", "errors",
    "format_diagnostics", "headroom_bits", "lint_paths", "lint_source",
    "raise_on_errors", "verify_mode", "verify_plan", "worst_severity",
]
