"""nn — pure-functional neural-net substrate (no flax; params are pytrees).

Conventions:
  * every layer is a pair of functions ``init_*(key, cfg...) -> params`` and
    ``apply_*(params, x, ...) -> y``; params are nested dicts of jnp arrays.
  * models stack layer params with a leading layer axis and run
    ``jax.lax.scan`` over layers — compile time is O(1) in depth, which is
    what makes the 512-device dry-runs tractable.
  * projections route through ``layers.dense`` which supports the Lightator
    photonic quantization (PQ) modes [W{2,3,4}:A4] via ``core.quant`` and the
    ``photonic_mvm`` Pallas kernel.
"""
