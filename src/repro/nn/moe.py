"""Mixture-of-Experts: top-k token-choice routing with sort-based dispatch.

Works for both assigned MoE archs — grok-1 (8 experts, top-2) and kimi-k2
(384 experts, top-8). GShard-style one-hot dispatch tensors are O(tokens *
E * capacity) and blow up at 384 experts, so dispatch is sort-based instead:

  1. router -> top-k (renormalized) per token
  2. flatten (token, k) slots, stable-sort by expert id
  3. rank-within-expert via exclusive cumsum of expert counts
  4. scatter tokens into a capacity-bounded buffer [E, C, d]   (drop overflow)
  5. batched expert FFN  [E, C, d] @ [E, d, ff] @ [E, ff, d]
  6. gather back per slot, weighted-combine over k

All steps are O(tokens*k) or O(E*C*d*ff); the buffer is sharded over the
"model" axis (expert parallelism) by the distribution layer. Aux losses:
load-balance (Switch) + router z-loss.

Expert FFN is SwiGLU, projections photonic-quantizable — the paper's "FC
layers segmented into 9-MAC chunks" case maps to expert matmuls directly.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import WASpec, fake_quant_weight
from repro.nn.module import KeyGen, scaled_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                     # per-expert hidden
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    balance_coef: float = 1e-2


class MoEOutput(NamedTuple):
    y: jnp.ndarray
    balance_loss: jnp.ndarray
    z_loss: jnp.ndarray
    dropped_fraction: jnp.ndarray


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    kg = KeyGen(key)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": scaled_init(d)(kg(), (d, e), jnp.float32),
        "w_gate": scaled_init(d)(kg(), (e, d, f), dtype),
        "w_up": scaled_init(d)(kg(), (e, d, f), dtype),
        "w_down": scaled_init(f)(kg(), (e, f, d), dtype),
    }


def _w(p, dtype):
    """Expert weight, possibly in photonic serving storage ({wq, ws})."""
    if isinstance(p, dict):
        return p["wq"].astype(dtype) * p["ws"].astype(dtype)
    return p


def _expert_ffn(params, xb: jnp.ndarray, quant: Optional[WASpec]) -> jnp.ndarray:
    """xb: [E, C, d] -> [E, C, d] (SwiGLU per expert)."""
    wg = _w(params["w_gate"], xb.dtype)
    wu = _w(params["w_up"], xb.dtype)
    wd = _w(params["w_down"], xb.dtype)
    if quant is not None:
        wg = fake_quant_weight(wg.astype(jnp.float32), quant).astype(xb.dtype)
        wu = fake_quant_weight(wu.astype(jnp.float32), quant).astype(xb.dtype)
        wd = fake_quant_weight(wd.astype(jnp.float32), quant).astype(xb.dtype)
    gate = jnp.einsum("ecd,edf->ecf", xb, wg)
    up = jnp.einsum("ecd,edf->ecf", xb, wu)
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_ffn(params, x: jnp.ndarray, cfg: MoEConfig,
            quant: Optional[WASpec] = None,
            capacity: Optional[int] = None) -> MoEOutput:
    """x: [B, S, d] -> MoEOutput with y: [B, S, d]."""
    bsz, seq, d = x.shape
    n_tok = bsz * seq
    e, k = cfg.n_experts, cfg.top_k
    n_slot = n_tok * k
    if capacity is None:
        capacity = max(int(n_tok * k / e * cfg.capacity_factor), 1)

    flat = x.reshape(n_tok, d)
    logits = (flat.astype(jnp.float32) @ params["router"])        # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                        # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ------------------------------------------------------
    me = probs.mean(axis=0)                                       # [E]
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / n_slot)
    balance = cfg.balance_coef * e * jnp.sum(me * ce)
    z = cfg.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- sort-based dispatch --------------------------------------------
    flat_e = top_i.reshape(-1)                                    # [N*k]
    flat_w = top_w.reshape(-1)
    src_tok = jnp.arange(n_slot, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)                      # [N*k]
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    seg_start = jnp.cumsum(counts) - counts                       # exclusive
    rank = jnp.arange(n_slot, dtype=jnp.int32) - seg_start[sorted_e]
    slot_sorted = jnp.where(rank < capacity,
                            sorted_e * capacity + rank,
                            e * capacity)                         # drop sentinel
    # slot id per original (token, k) position
    slot = jnp.zeros((n_slot,), jnp.int32).at[order].set(slot_sorted)

    buffer = jnp.zeros((e * capacity, d), x.dtype)
    buffer = buffer.at[slot].set(flat[src_tok], mode="drop")
    yb = _expert_ffn(params, buffer.reshape(e, capacity, d), quant)
    yb = yb.reshape(e * capacity, d)

    gathered = jnp.take(yb, slot, axis=0, fill_value=0.0,
                        mode="fill")                              # [N*k, d]
    combined = (gathered.astype(jnp.float32)
                * flat_w[:, None]).reshape(n_tok, k, d).sum(axis=1)
    dropped = jnp.mean((slot == e * capacity).astype(jnp.float32))
    return MoEOutput(combined.reshape(bsz, seq, d).astype(x.dtype),
                     balance, z, dropped)


def moe_ffn_grouped(params, x: jnp.ndarray, cfg: MoEConfig,
                    quant: Optional[WASpec] = None,
                    capacity: Optional[int] = None,
                    combine_dtype=None) -> MoEOutput:
    """Group-local dispatch: no cross-shard scatter (the §Perf rewrite).

    The sorted dispatch (``moe_ffn``) builds one global [E*C, d] buffer; under
    GSPMD the scatter from data-sharded tokens lowers to a full-buffer
    all-reduce over the data axis (~32 GB/layer for grok/kimi — measured in
    EXPERIMENTS.md §Perf). Here every batch row dispatches *locally*:

      tokens   [G(data), S, d]     (replicated over model)
      buffer   [G(data), E(model), C_g, d]   scatter is group-local
      experts  einsum over the model-sharded E axis — zero-comm matmuls
      combine  gather from yb; SPMD all-gathers yb over model — the ONLY
               collective, ~E*C_g*d per group, optionally quantized to
               ``combine_dtype`` (f8: the CRC trick applied to MoE traffic)

    Per-group capacity C_g = S*k/E * cf keeps expected drop rates identical
    to the global formulation (balance is per-row instead of per-batch).
    """
    from repro.distributed.sharding import shard
    bsz, seq, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if capacity is None:
        capacity = max(int(seq * k / e * cfg.capacity_factor), 1)
    n_slot = seq * k

    x = shard(x, "batch", None, None)
    logits = (x.astype(jnp.float32) @ params["router"])           # [G,S,E]
    # pin router outputs replicated over model: left free, SPMD shards the
    # E dim on "model" and then all-gathers [G,S,E] f32 back for top_k
    # (~92 GiB/step measured on kimi — §Perf iter 5)
    logits = shard(logits, "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                        # [G,S,k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (bsz * n_slot))
    balance = cfg.balance_coef * e * jnp.sum(me * ce)
    z = cfg.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    def slots_one(eg):
        """eg [S,k] -> slot ids [S*k] in [0, E*C] (E*C == dropped)."""
        flat_e = eg.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
        seg_start = jnp.cumsum(counts) - counts
        rank = jnp.arange(n_slot, dtype=jnp.int32) - seg_start[sorted_e]
        slot_sorted = jnp.where(rank < capacity,
                                sorted_e * capacity + rank, e * capacity)
        return jnp.zeros((n_slot,), jnp.int32).at[order].set(slot_sorted)

    slots = jax.vmap(slots_one)(top_i)                            # [G, S*k]

    y = _moe_block(params, x, slots, top_w, e, k, capacity,
                   quant, combine_dtype)
    dropped = jnp.mean((slots == e * capacity).astype(jnp.float32))
    return MoEOutput(y.astype(x.dtype), balance, z, dropped)


def _moe_block(params, x, slots, top_w, e, k, capacity, quant,
               combine_dtype):
    """Dispatch -> expert FFN -> combine.

    When experts shard over "model", the whole block runs inside ONE
    shard_map region: the dispatch scatter, expert matmuls, combine gather
    AND all their transposes (backward) are local by construction. The only
    mesh traffic is (i) the explicit FSDP all-gather of the local experts'
    weights over "data" (ZeRO-3 semantics; its transpose is the wgrad
    reduce-scatter) and (ii) one token-sized psum over "model". A naive
    GSPMD lowering of the same math moves the full [S*k, d] slot tensor
    through select+all-reduce in BOTH directions — measured 3.4 TB/step on
    kimi-k2 (EXPERIMENTS.md §Perf).
    """
    from repro.distributed.sharding import _current
    from jax.sharding import PartitionSpec as P

    bsz, seq, d = x.shape
    n_slot = slots.shape[-1]
    cur = _current()
    model_axes = cur[1].get("experts") if cur else None
    if cur is None or not model_axes or isinstance(params["w_gate"], dict):
        # unsharded / small-E / quantized-storage fallback (GSPMD)
        def dispatch_one(xg, slot):
            src_tok = jnp.arange(n_slot, dtype=jnp.int32) // k
            buf = jnp.zeros((e * capacity, d), xg.dtype)
            return buf.at[slot].set(xg[src_tok], mode="drop")

        from repro.distributed.sharding import shard as shard_fn
        buffers = jax.vmap(dispatch_one)(x, slots)
        buffers = buffers.reshape(bsz, e, capacity, d)
        buffers = shard_fn(buffers, "batch", "experts", None, None)
        yb = _expert_ffn_grouped(params, buffers, quant)
        if combine_dtype is not None:
            yb = yb.astype(combine_dtype)
        return _combine_fallback(yb, slots, top_w, seq, k)

    mesh, rules = cur
    from jax.experimental.shard_map import shard_map
    b_ax = rules.get("batch")
    b0 = (tuple(b_ax) if isinstance(b_ax, tuple) and len(b_ax) > 1
          else (b_ax[0] if isinstance(b_ax, tuple) else b_ax))
    m_ax = model_axes if isinstance(model_axes, str) else model_axes[0]
    d_ax = rules.get("expert_embed")
    d_ax = d_ax[0] if isinstance(d_ax, tuple) else d_ax
    w_flat = top_w.reshape(bsz, n_slot)
    wg_p, wu_p, wd_p = params["w_gate"], params["w_up"], params["w_down"]

    def body(x_l, slot_l, w_l, wg_l, wu_l, wd_l):
        # x_l [G_l,S,d]; slot_l/w_l [G_l,n_slot]; wg_l [E_l, d/dx, f]
        if d_ax is not None:     # explicit ZeRO-3 gather of local experts
            wg = jax.lax.all_gather(wg_l, d_ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu_l, d_ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd_l, d_ax, axis=2, tiled=True)
        else:
            wg, wu, wd = wg_l, wu_l, wd_l
        e_l = wg.shape[0]
        local_size = e_l * capacity
        base = jax.lax.axis_index(m_ax) * local_size
        loc = slot_l - base
        valid = (loc >= 0) & (loc < local_size)
        loc_in = jnp.where(valid, loc, local_size)          # drop sentinel
        src_tok = jnp.arange(n_slot, dtype=jnp.int32) // k

        def scatter_one(xg, lg):
            buf = jnp.zeros((local_size, d), xg.dtype)
            return buf.at[lg].set(xg[src_tok], mode="drop")

        buf = jax.vmap(scatter_one)(x_l, loc_in)            # [G_l, E_l*C, d]
        xb = buf.reshape(-1, e_l, capacity, d)
        gate = jnp.einsum("gecd,edf->gecf", xb, wg.astype(xb.dtype))
        up = jnp.einsum("gecd,edf->gecf", xb, wu.astype(xb.dtype))
        h = jax.nn.silu(gate) * up
        yb = jnp.einsum("gecf,efd->gecd", h, wd.astype(xb.dtype))
        if combine_dtype is not None:
            yb = yb.astype(combine_dtype)
        ybf = yb.reshape(-1, local_size, d)
        g = jax.vmap(lambda f, i: jnp.take(f, jnp.clip(i, 0, local_size - 1),
                                           axis=0))(ybf, loc)
        g = jnp.where(valid[..., None], g, 0).astype(jnp.float32)
        part = (g * w_l[..., None]).reshape(-1, seq, k, d).sum(axis=2)
        return jax.lax.psum(part.astype(x_l.dtype), m_ax)

    w_spec = P(m_ax, d_ax, None)
    wd_spec = P(m_ax, None, d_ax)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(b0, None, None), P(b0, None), P(b0, None),
                  w_spec, w_spec, wd_spec),
        out_specs=P(b0, None, None),
        check_rep=False)(x, slots, w_flat, wg_p, wu_p, wd_p)
    return out.astype(jnp.float32)


def _expert_ffn_grouped(params, xb: jnp.ndarray,
                        quant: Optional[WASpec]) -> jnp.ndarray:
    """xb: [G, E, C, d] -> [G, E, C, d]; compute pinned to bf16 carriers."""
    wg = _w(params["w_gate"], xb.dtype)
    wu = _w(params["w_up"], xb.dtype)
    wd = _w(params["w_down"], xb.dtype)
    if quant is not None:
        wg = fake_quant_weight(wg.astype(jnp.float32), quant).astype(xb.dtype)
        wu = fake_quant_weight(wu.astype(jnp.float32), quant).astype(xb.dtype)
        wd = fake_quant_weight(wd.astype(jnp.float32), quant).astype(xb.dtype)
    gate = jnp.einsum("gecd,edf->gecf", xb, wg.astype(xb.dtype))
    up = jnp.einsum("gecd,edf->gecf", xb, wu.astype(xb.dtype))
    h = jax.nn.silu(gate) * up
    return jnp.einsum("gecf,efd->gecd", h, wd.astype(xb.dtype))


def _combine_fallback(yb, slots, top_w, seq: int, k: int):
    bsz, e, capacity_, d = yb.shape
    yb_flat = yb.reshape(bsz, e * capacity_, d)

    def combine_one(ybg, slot, wg):
        g = jnp.take(ybg, slot, axis=0, fill_value=0.0, mode="fill")
        return (g.astype(jnp.float32)
                * wg.reshape(-1)[:, None]).reshape(seq, k, d).sum(axis=1)

    return jax.vmap(combine_one)(yb_flat, slots, top_w)


def moe_ffn_dense_oracle(params, x: jnp.ndarray, cfg: MoEConfig) -> jnp.ndarray:
    """All-experts reference (tests only): y = sum_e gate_e * FFN_e(x)."""
    bsz, seq, d = x.shape
    flat = x.reshape(-1, d)
    logits = flat.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(flat.shape[0])[:, None], top_i].set(top_w)
    per_expert = _expert_ffn(
        params, jnp.broadcast_to(flat[None], (cfg.n_experts,) + flat.shape),
        None)                                                    # [E, N, d]
    y = jnp.einsum("ne,end->nd", gates, per_expert.astype(jnp.float32))
    return y.reshape(bsz, seq, d).astype(x.dtype)
