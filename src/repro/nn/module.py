"""Param-tree utilities and initializers for the pure-functional substrate."""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(stddev: float = 0.02):
    def init(key, shape, dtype=jnp.float32):
        return stddev * jax.random.normal(key, shape, dtype)
    return init


def scaled_init(fan_in: int):
    """1/sqrt(fan_in) — the default for projection matrices."""
    return normal_init(1.0 / math.sqrt(max(fan_in, 1)))


def zeros_init():
    def init(key, shape, dtype=jnp.float32):
        return jnp.zeros(shape, dtype)
    return init


def ones_init():
    def init(key, shape, dtype=jnp.float32):
        return jnp.ones(shape, dtype)
    return init


# ---------------------------------------------------------------------------
# Key management
# ---------------------------------------------------------------------------

class KeyGen:
    """Deterministic key splitter: kg = KeyGen(key); k = kg()."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------

def tree_size(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))


def tree_flatten_with_paths(tree: PyTree) -> Iterable[Tuple[str, jnp.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        yield name, leaf


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def stack_layer_params(layer_params: list[PyTree]) -> PyTree:
    """Stack per-layer param trees along a new leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
