"""Attention: GQA + RoPE, flash-style blockwise softmax, KV cache, SWA.

Shapes follow the [B, T, H, D] convention (batch, time, heads, head_dim);
KV uses [B, S, K, D] with K (kv heads) <= H and H % K == 0.

Three execution paths:
  * ``attention``        — blockwise (flash-style) online-softmax over KV
                           blocks via ``lax.scan``: O(T*S) compute, O(block)
                           memory. Default for training/prefill.
  * ``attention_naive``  — materialized scores; reference/oracle + tiny tests.
  * ``decode_attention`` — single-token query against a cache; O(S) per step.

Sliding-window attention (``window``) masks keys older than the window; for
decode the cache itself is a ring buffer of window size, which is what makes
hymba's long_500k cell sub-quadratic in memory and compute.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                       # [D/2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [B, T, H, D]; positions: [B, T] (or [T]) absolute positions."""
    freqs = rope_frequencies(x.shape[-1], theta)            # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, D/2]
    cos = jnp.cos(angles)[:, :, None, :]                    # [B, T, 1, D/2]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Reference (naive) attention
# ---------------------------------------------------------------------------

def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B,S,K,D] -> [B,S,H,D] by repeating each kv head H//K times."""
    b, s, kv, d = k.shape
    reps = n_heads // kv
    return jnp.repeat(k, reps, axis=2) if reps > 1 else k


def attention_naive(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0) -> jnp.ndarray:
    """Oracle. q: [B,T,H,D], k/v: [B,S,K,D] -> [B,T,H,D]."""
    b, t, h, d = q.shape
    s = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = d ** -0.5
    scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(t) + q_offset
    kpos = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Flash-style blockwise attention
# ---------------------------------------------------------------------------

def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: Optional[int] = None,
              kv_block: int = 512, q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention, scanning KV blocks. Memory O(T * kv_block).

    GQA-aware: computes in grouped layout [B, T, K, G, D] so kv heads are
    never materialized H/K times.
    """
    b, t, h, d = q.shape
    s = k.shape[1]
    kv_heads = k.shape[2]
    g = h // kv_heads
    scale = d ** -0.5

    if s % kv_block:
        pad = kv_block - s % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_pad = s + pad
    else:
        s_pad = s
    n_blocks = s_pad // kv_block

    qg = q.reshape(b, t, kv_heads, g, d)
    kb = k.reshape(b, n_blocks, kv_block, kv_heads, d)
    vb = v.reshape(b, n_blocks, kv_block, kv_heads, d)
    qpos = (jnp.arange(t) + q_offset)[:, None]              # [T, 1]

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = blk                         # [B,kvb,K,D]
        kpos = blk_idx * kv_block + jnp.arange(kv_block)[None, :]   # [1,kvb]
        sc = jnp.einsum("btkgd,bskd->btkgs", qg, k_blk).astype(jnp.float32)
        sc = sc * scale
        mask = kpos < s                                     # padding
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
        m_blk = jnp.max(sc, axis=-1)                        # [B,T,K,G]
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("btkgs,bskd->btkgd", p.astype(v_blk.dtype), v_blk)
        acc = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, t, kv_heads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, t, kv_heads, g), jnp.float32)
    acc0 = jnp.zeros((b, t, kv_heads, g, d), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)                           # [n,B,kvb,K,D]
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb_t, vb_t, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, t, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray          # [B, S_cache, K, D]
    v: jnp.ndarray
    pos: jnp.ndarray        # [] int32 — number of tokens already written

    @classmethod
    def init(cls, batch: int, max_len: int, kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16):
        z = jnp.zeros((batch, max_len, kv_heads, head_dim), dtype)
        return cls(z, jnp.zeros_like(z), jnp.zeros((), jnp.int32))


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 ring: bool = False) -> KVCache:
    """Insert [B, 1, K, D] at cache.pos (ring buffer if ``ring``)."""
    s_cache = cache.k.shape[1]
    idx = jnp.where(ring, cache.pos % s_cache,
                    jnp.minimum(cache.pos, s_cache - 1)) if ring else cache.pos
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, idx, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, idx, 0, 0))
    return KVCache(k, v, cache.pos + 1)


def decode_attention(q: jnp.ndarray, cache: KVCache,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-step decode: q [B,1,H,D] vs the cache. O(S_cache) per token.

    With a ring-buffer cache (sliding window) every resident entry is valid
    once pos >= S_cache; before that, entries >= pos are masked.
    """
    b, one, h, d = q.shape
    s_cache = cache.k.shape[1]
    kv_heads = cache.k.shape[2]
    g = h // kv_heads
    scale = d ** -0.5
    qg = q.reshape(b, kv_heads, g, d)
    # caches may live in a narrower dtype (f8/int8 — the CRC trick applied
    # to KV storage); upcast at use, XLA fuses the cast into the einsum
    k_cache = cache.k.astype(q.dtype)
    v_cache = cache.v.astype(q.dtype)
    sc = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    kpos = jnp.arange(s_cache)
    # cache.pos counts tokens already written (cache_update increments it),
    # so entries 0..pos-1 are valid; the query sits at position pos-1.
    valid = kpos < cache.pos
    if window is not None and window < s_cache:
        valid &= kpos >= cache.pos - window
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache)
    return out.reshape(b, 1, h, d).astype(q.dtype)
