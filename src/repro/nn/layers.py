"""Core layers: dense (photonic-quantizable), embedding, norms, conv.

``dense`` is the single projection primitive every model routes through; its
``quant`` argument turns on the Lightator PQ path:

  quant=None          plain matmul (bf16/f32) — the non-photonic baseline
  quant=WASpec, mode="fake"    QAT fake-quant (STE) — training the paper's way
  quant=WASpec, mode="qweights"  weight-only quantized storage (int carriers
                      dequantized on the fly) — photonic serving; weights live
                      at w_bits the way they live on the MRs
  quant=WASpec, mode="kernel"  the photonic_mvm Pallas kernel (integer MAC)

Params are dicts: dense -> {"w": [in,out](, "b": [out])}; quantized storage
adds {"wq": int8 [in,out], "ws": [1,out] or [out]}.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import WASpec, fake_quant_act, fake_quant_weight
from repro.nn.module import KeyGen, normal_init, scaled_init, zeros_init, ones_init


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.float32, stddev: float | None = None):
    kg = KeyGen(key)
    init = normal_init(stddev) if stddev is not None else scaled_init(d_in)
    p = {"w": init(kg(), (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x: jnp.ndarray, quant: Optional[WASpec] = None,
          mode: str = "fake", act_scale: float = 1.0 / 15.0) -> jnp.ndarray:
    """x: [..., d_in] -> [..., d_out]."""
    if "wq" in params:
        # photonic serving storage: int-carrier weights + per-channel scales
        # (weights live at w_bits the way they live on the MRs)
        w = params["wq"].astype(x.dtype) * params["ws"].astype(x.dtype)
        y = x @ w
        if "b" in params:
            y = y + params["b"].astype(y.dtype)
        return y
    if quant is None:
        y = x @ params["w"]
    elif mode == "fake":
        # QAT: activations clipped to [0, qmax*scale] happens inside; weights
        # symmetric per-out-channel. Photonic activations are unsigned, but
        # interior LM activations are signed — we model the paper's BPD trick
        # (two VCSEL rails) by quantizing |x| and reapplying sign.
        w = fake_quant_weight(params["w"].astype(jnp.float32), quant)
        sgn = jnp.sign(x)
        mag = fake_quant_act(jnp.abs(x.astype(jnp.float32)),
                             scale=act_scale, a_bits=quant.a_bits)
        y = ((sgn * mag) @ w).astype(x.dtype)
    elif mode == "qweights":
        # weight-only: int-carrier weights dequantized on the fly (serving)
        w = params["wq"].astype(x.dtype) * params["ws"].astype(x.dtype)
        y = x @ w
    elif mode == "kernel":
        from repro.kernels.photonic_mvm import ops as pk_ops
        y = pk_ops.photonic_mvm(x, params["w"], quant, act_scale=act_scale)
    else:
        raise ValueError(f"unknown quant mode {mode}")
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def quantize_dense_params(params, spec: WASpec):
    """Convert fp dense params to photonic serving storage (wq int8 + ws)."""
    from repro.core.quant import quantize_weight
    wq, ws = quantize_weight(params["w"].astype(jnp.float32), spec, axis=-1)
    out = {"wq": wq, "ws": ws.astype(jnp.float32)}
    if "b" in params:
        out["b"] = params["b"]
    return out


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": normal_init(1.0)(key, (vocab, d_model), dtype)}


def embedding_lookup(params, ids: jnp.ndarray) -> jnp.ndarray:
    # one_hot matmul is pathological for big vocab; take() is the right op
    return jnp.take(params["table"], ids, axis=0)


def embedding_logits(params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied output head: x [..., d] @ table.T -> [..., vocab]."""
    return x @ params["table"].T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(key, dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(key, dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Conv2D (vision models; NHWC)
# ---------------------------------------------------------------------------

def init_conv2d(key, k: int, c_in: int, c_out: int, bias: bool = True,
                dtype=jnp.float32):
    kg = KeyGen(key)
    p = {"w": scaled_init(k * k * c_in)(kg(), (k, k, c_in, c_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d(params, x: jnp.ndarray, stride: int = 1, padding: str = "SAME",
           quant: Optional[WASpec] = None) -> jnp.ndarray:
    w = params["w"]
    if quant is not None:
        w = fake_quant_weight(w.astype(jnp.float32), quant).astype(x.dtype)
        sgn = jnp.sign(x)
        x = sgn * fake_quant_act(jnp.abs(x.astype(jnp.float32)),
                                 scale=1.0 / 15.0,
                                 a_bits=quant.a_bits).astype(x.dtype)
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def max_pool2d(x: jnp.ndarray, size: int = 2) -> jnp.ndarray:
    b, h, w, c = x.shape
    return x.reshape(b, h // size, size, w // size, size, c).max(axis=(2, 4))


def avg_pool2d(x: jnp.ndarray, size: int = 2) -> jnp.ndarray:
    b, h, w, c = x.shape
    return x.reshape(b, h // size, size, w // size, size, c).mean(axis=(2, 4))
