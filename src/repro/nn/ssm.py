"""Mamba2 — state-space duality (SSD), chunked parallel form + decode step.

Implements the SSD algorithm of "Transformers are SSMs" (arXiv:2405.21060):
the selective SSM
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t        h: [H, P, N]
    y_t = C_t . h_t + D x_t
is evaluated in O(T) by splitting time into chunks: a quadratic
(attention-like) intra-chunk term with the 1-semiseparable decay mask L, and
an inter-chunk recurrence over per-chunk states carried by ``lax.scan``.

Shapes: x [B, T, H, P]; A [H]; B, C [B, T, G, N] (G groups, GQA-style);
dt [B, T, H]. chunk = Q.

This is attention-free and O(T) — mamba2/hymba are the archs that run the
long_500k cell. Decode carries state [B, H, P, N]: O(1) per token.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] (j < i).

    Returns -inf above the diagonal; exp(segsum) is the lower-triangular
    decay mask L of the SSD dual form.
    """
    t = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]     # sum over (j, i]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, chunk: int = 128,
                initial_state: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. Returns (y [B,T,H,P], final_state [B,H,P,N])."""
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert t % chunk == 0, f"T({t}) must divide chunk({chunk})"
    nc = t // chunk
    hg = h // g                                           # heads per group

    # chunked views --------------------------------------------------------
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)

    da = dtc * a[None, None, None, :]                     # [B,nc,Q,H] (<0)
    da = jnp.moveaxis(da, -1, -2)                         # [B,nc,H,Q]
    da_cs = jnp.cumsum(da, axis=-1)                       # [B,nc,H,Q]

    # 1) intra-chunk (diagonal blocks): attention-like with decay mask -----
    l_mask = jnp.exp(segsum(da))                          # [B,nc,H,Q,Q]
    # scores: C_i . B_j  -> [B,nc,H,Q,Q] with GQA group broadcast
    cb = jnp.einsum("bcqgn,bcsgn->bcgqs", cc, bc)         # [B,nc,G,Q,Q]
    cb = jnp.repeat(cb, hg, axis=2)                       # [B,nc,H,Q,Q]
    dtx = xc * jnp.moveaxis(dtc, -1, -1)[..., None]       # x * dt [B,nc,Q,H,P]
    scores = cb * l_mask
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp",
                        scores.astype(x.dtype), dtx.astype(x.dtype))

    # 2) per-chunk states: what each chunk contributes to the carried state
    # expand the GQA-style groups to heads (head h uses group h // (H/G))
    bh = jnp.repeat(bc, hg, axis=3)                       # [B,nc,Q,H,N]
    ch = jnp.repeat(cc, hg, axis=3)
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)       # [B,nc,H,Q]
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn",
                        bh.astype(jnp.float32),
                        decay_states.astype(jnp.float32) *
                        jnp.moveaxis(dtc, -1, -2).astype(jnp.float32),
                        xc.astype(jnp.float32))           # [B,nc,H,P,N]

    # 3) inter-chunk recurrence over carried states -------------------------
    chunk_decay = jnp.exp(da_cs[..., -1])                 # [B,nc,H]
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bsz, h, p, n), jnp.float32))

    def scan_fn(carry, inp):
        st, dec = inp                                     # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                 # emit PREVIOUS state

    states_t = jnp.moveaxis(states, 1, 0)                 # [nc,B,H,P,N]
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)             # [nc,B,H]
    final_state, prev_states = jax.lax.scan(scan_fn, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # [B,nc,H,P,N]

    # 4) inter-chunk output: C_t . (decay-to-t applied to incoming state) ---
    state_decay = jnp.exp(da_cs)                          # [B,nc,H,Q]
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       ch.astype(jnp.float32), prev_states,
                       state_decay.astype(jnp.float32))

    y = (y_diag.astype(jnp.float32) + y_off).reshape(bsz, t, h, p)
    return y.astype(x.dtype), final_state


def ssd_reference(x, dt, a, b, c, initial_state=None):
    """O(T) sequential oracle (slow; tests only)."""
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    state = (initial_state if initial_state is not None
             else jnp.zeros((bsz, h, p, n), jnp.float32))
    ys = []
    for i in range(t):
        da = jnp.exp(dt[:, i] * a[None, :])               # [B,H]
        bi = jnp.repeat(b[:, i], hg, axis=1)              # [B,H,N]
        ci = jnp.repeat(c[:, i], hg, axis=1)
        upd = (dt[:, i][..., None, None] * x[:, i][..., None]
               * bi[:, :, None, :])                       # [B,H,P,N]
        state = state * da[..., None, None] + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, ci))
    y = jnp.stack(ys, axis=1)                             # [B,T,H,P]
    return y.astype(x.dtype), state


class SSMState(NamedTuple):
    """Decode-time cache: conv window + SSM state."""
    conv: jnp.ndarray        # [B, K-1, conv_dim]
    ssm: jnp.ndarray         # [B, H, P, N] float32
    pos: jnp.ndarray         # [] int32

    @classmethod
    def init(cls, batch: int, conv_k: int, conv_dim: int, heads: int,
             head_dim: int, state: int, dtype=jnp.bfloat16):
        return cls(jnp.zeros((batch, conv_k - 1, conv_dim), dtype),
                   jnp.zeros((batch, heads, head_dim, state), jnp.float32),
                   jnp.zeros((), jnp.int32))


def ssd_decode_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray):
    """One recurrent step. x [B,H,P]; dt [B,H]; b,c [B,G,N]; state [B,H,P,N].

    Returns (y [B,H,P], new_state). O(H*P*N) — independent of context length.
    """
    h = x.shape[1]
    g = b.shape[1]
    hg = h // g
    da = jnp.exp(dt * a[None, :])                         # [B,H]
    bi = jnp.repeat(b, hg, axis=1)                        # [B,H,N]
    ci = jnp.repeat(c, hg, axis=1)
    upd = (dt[..., None, None] * x[..., None]) * bi[:, :, None, :]
    new_state = state * da[..., None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ci.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray | None = None
                  ) -> jnp.ndarray:
    """Depthwise causal conv. x: [B, T, C]; w: [K, C] -> [B, T, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i][None, None, :]
    if bias is not None:
        out = out + bias[None, None, :]
    return out.astype(x.dtype)


def causal_conv1d_step(conv_state: jnp.ndarray, x_new: jnp.ndarray,
                       w: jnp.ndarray, bias: jnp.ndarray | None = None):
    """Decode step for the depthwise conv. conv_state [B,K-1,C], x_new [B,C]."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
    if bias is not None:
        y = y + bias[None, :]
    new_state = window[:, 1:, :]
    return y.astype(x_new.dtype), new_state
