"""Gradient compression for cross-pod all-reduce (distributed-optimization).

int8 block-quantized gradients with error feedback: before the data-parallel
all-reduce, each leaf is quantized to int8 with a per-block f32 scale; the
quantization residual is carried to the next step (error feedback keeps the
update unbiased over time). At 512 chips the pod-crossing gradient traffic
drops ~4x (bf16->int8) — the same trick the paper plays at the sensor (4-bit
CRC codes instead of full-precision pixels) applied to the optimizer's
communication.

With GSPMD the all-reduce is implicit (grads of replicated params), so the
hook is exposed two ways:
  * ``compress_int8``/``decompress_int8`` — building blocks (tested exactly)
  * ``compressed_allreduce_update`` — shard_map-style explicit all-reduce
    over a named axis for the fault-tolerance/elastic runner.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jnp.ndarray, block: int = BLOCK
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (codes int8 [N], scales f32 [ceil(N/block)]). Flattens x."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scales = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scales = jnp.maximum(scales, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scales), -127, 127).astype(jnp.int8)
    return codes.reshape(-1), scales[:, 0]


def decompress_int8(codes: jnp.ndarray, scales: jnp.ndarray, shape,
                    block: int = BLOCK) -> jnp.ndarray:
    blocks = codes.reshape(-1, block).astype(jnp.float32) * scales[:, None]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape)


def compressed_allreduce_update(grads, error_state, axis_name: str,
                                block: int = BLOCK):
    """Error-feedback int8 all-reduce over ``axis_name`` (use in shard_map).

    Returns (averaged_grads, new_error_state).
    """
    def one(g, e):
        g_comp = g.astype(jnp.float32) + e
        codes, scales = compress_int8(g_comp, block)
        deq = decompress_int8(codes, scales, g.shape, block)
        new_e = g_comp - deq
        avg = jax.lax.pmean(deq, axis_name)
        return avg, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    avg = treedef.unflatten([o[0] for o in out])
    errs = treedef.unflatten([o[1] for o in out])
    return avg, errs


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
