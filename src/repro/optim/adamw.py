"""AdamW with mixed-precision master params — built in-repo (no optax here).

Design for scale:
  * Moments (and optional f32 master copy of bf16 params) are plain pytrees
    mirroring the param tree -> they inherit the params' NamedShardings
    (ZeRO-style: sharded over the same axes, never replicated when params
    are FSDP-sharded).
  * ``adamw_update`` is pure and jit-safe; the train step closes over the
    config.
  * Optional int8 gradient compression hooks live in optim.compression.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                    # peak lr (scheduled outside or const)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True            # keep f32 master for bf16 params


def adamw_init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 cfg: AdamWConfig,
                 lr: Optional[jnp.ndarray] = None) -> Tuple[PyTree, PyTree, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics).

    Grads are kept in their native (bf16) dtype until the per-leaf moment
    updates: the f32 upcast is elementwise and fuses AFTER any resharding
    collectives, so gradient reshards move 2-byte payloads, not 4-byte
    (measured 2x on the §Perf kimi cell). The global-norm reduction happens
    per-leaf in f32 scalars — no f32 gradient tensors are materialized.
    """
    from repro.optim.clip import global_norm
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    lr_t = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def g32(g):
        return g.astype(jnp.float32) * scale

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g32(g),
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32(g)),
                      state["nu"], grads)

    masters = state.get("master", params)

    def upd(p32, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return (p32.astype(jnp.float32)
                - lr_t * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32.astype(jnp.float32)))

    new_master = jax.tree.map(upd, masters, mu, nu)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"step": step, "mu": mu, "nu": nu}
    if "master" in state:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}
