from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.clip import global_norm, clip_by_global_norm
from repro.optim.compression import (compress_int8, decompress_int8,
                                     compressed_allreduce_update)
