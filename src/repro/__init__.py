"""repro — Lightator: optical near-sensor acceleration, reproduced as a JAX framework.

Layers:
  core/         the paper's contribution (photonic device models, quantization,
                optical-core mapping, compressive acquisition, power model)
  nn/, models/  model substrate (pure-functional JAX modules)
  kernels/      Pallas TPU kernels for the perf-critical compute (photonic MVM,
                compressive acquisition, bank-mapped convolution)
  imaging/      fixed-function image-processing pipelines (optical filters +
                CA compression/reconstruction) compiled on the plan runtime
  serve/        production serving runtime: multi-program router + async
                micro-batching scheduler over compiled Executables
  obs/          unified tracing/metrics/profiling (zero-dependency):
                spans + Chrome-trace export, counters/gauges/histograms
  distributed/  sharding rules, collectives, fault tolerance, elastic scaling
  optim/, checkpoint/, data/   training substrate
  configs/      assigned architectures + the paper's own CNNs
  launch/       production mesh, multi-pod dry-run, roofline, train/serve drivers
"""

__version__ = "1.0.0"

__all__ = ["Program", "Options", "Executable"]


def __getattr__(name):
    # Lazy re-export of the program-level front door (core.program) so that
    # `import repro` stays free of jax import cost for the config-only users.
    if name in __all__:
        from repro.core import program
        return getattr(program, name)
    if name == "obs":
        # zero-dependency observability layer — importable without jax
        import repro.obs as obs
        return obs
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
