"""stablelm-3b [dense]: 32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2; unverified]. head_dim 80, LayerNorm, full MHA
(kv == heads). Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, vocab=50304,
    n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, ffn="swiglu", norm="layer",
    tie_embeddings=False,
    remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke", family="dense",
    n_layers=2, d_model=64, vocab=128,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=160, ffn="swiglu", norm="layer",
    tie_embeddings=False,
    max_seq=64,
)

register(FULL, SMOKE)
