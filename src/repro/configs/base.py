"""ModelConfig — the single config schema every architecture instantiates.

Exact assigned configs live in sibling modules (one file per arch). Each
registers itself plus a ``smoke`` variant (same family, tiny dims) used by
the per-arch CPU smoke tests; the FULL configs are only ever lowered via
ShapeDtypeStructs in the dry-run (never allocated).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    causal: bool = True
    # ffn
    d_ff: int = 0
    ffn: str = "swiglu"         # swiglu | gelu
    norm: str = "rms"           # rms | layer
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "sorted"     # sorted | grouped  (§Perf)
    moe_combine_dtype: str = "none"  # none | float8_e4m3fn | bfloat16
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssd_chunk: int = 128
    # embeddings / head
    tie_embeddings: bool = True
    # modality frontend (STUB: input_specs provides precomputed embeddings)
    frontend: str = "none"      # none | audio | vision
    frontend_dim: int = 0
    n_patches: int = 0          # vlm: image patches at the sequence front
    ca_factor: int = 1          # compressive acquisition (1 = off)
    # photonic quantization (the paper's technique as a framework feature)
    quant_scheme: str = "none"  # none | w4a4 | w3a4 | w2a4
    # numerics / scale
    dtype: str = "bfloat16"
    remat: str = "none"         # none | full | dots
    max_seq: int = 4096
    # sharding hints (per-arch overrides consumed by distributed.sharding)
    fsdp: bool = False          # shard the non-model param dim over "data"

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:   # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    def quant_spec(self):
        from repro.core.quant import W4A4, W3A4, W2A4
        return {"none": None, "w4a4": W4A4, "w3a4": W3A4,
                "w2a4": W2A4}[self.quant_scheme]


_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def smoke_variant(name: str) -> ModelConfig:
    return _SMOKE[name]


def list_configs() -> list[str]:
    return sorted(_REGISTRY)
