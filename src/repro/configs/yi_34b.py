"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Llama-arch GQA [arXiv:2403.04652; hf]. head_dim 128, SwiGLU, RMSNorm,
rope_theta 5e6. Pure full attention -> long_500k skipped. FSDP on.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, vocab=64000,
    n_heads=56, n_kv_heads=8, head_dim=128, rope_theta=5e6,
    d_ff=20480, ffn="swiglu", norm="rms",
    tie_embeddings=False, fsdp=True, remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense",
    n_layers=2, d_model=64, vocab=128,
    n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=160, ffn="swiglu", norm="rms",
    tie_embeddings=False,
    max_seq=64,
)

register(FULL, SMOKE)
