"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

Llama-arch small [hf:HuggingFaceTB/SmolLM-360M]. head_dim 64, tied embeddings.
Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, vocab=49152,
    n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, ffn="swiglu", norm="rms",
    tie_embeddings=True,
    remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=48, vocab=96,
    n_heads=3, n_kv_heads=1, head_dim=16,
    d_ff=128, ffn="swiglu", norm="rms",
    tie_embeddings=True,
    max_seq=64,
)

register(FULL, SMOKE)
