"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only (same arch as wav2vec2-XL) [arXiv:2106.07447]. The CNN waveform
frontend is a STUB: ``input_specs`` provides precomputed 512-dim frame
embeddings; the framework's compressive-acquisition feature (ca_factor) can
mean-pool frames before the encoder (the paper's CA generalized to audio).
No decode path (encoder) -> decode_32k / long_500k cells are skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, vocab=504,
    n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, ffn="gelu", norm="layer", causal=False,
    tie_embeddings=False,
    frontend="audio", frontend_dim=512,
    remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="encoder",
    n_layers=2, d_model=64, vocab=32,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, ffn="gelu", norm="layer", causal=False,
    tie_embeddings=False,
    frontend="audio", frontend_dim=24,
    max_seq=64,
)

register(FULL, SMOKE)
