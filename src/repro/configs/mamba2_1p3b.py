"""mamba2-1.3b [ssm]: 48L d_model=2048 attn-free, ssm_state=128 vocab=50280.

SSD (state-space duality) [arXiv:2405.21060]. d_inner = 2*2048 = 4096,
head_dim 64 -> 64 SSM heads, 1 group, conv kernel 4. Attention-free with an
O(1) recurrent state -> runs the long_500k cell.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    conv_kernel=4, ssd_chunk=256,
    remat="full",
    max_seq=524288,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke", family="ssm",
    n_layers=2, d_model=64, vocab=64,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_groups=1,
    conv_kernel=4, ssd_chunk=16,
    max_seq=64,
)

register(FULL, SMOKE)
