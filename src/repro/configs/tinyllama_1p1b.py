"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.

Llama2-arch small [arXiv:2401.02385; hf]. head_dim 64.
Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, vocab=32000,
    n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, ffn="swiglu", norm="rms",
    tie_embeddings=False,
    remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="tinyllama-1.1b-smoke", family="dense",
    n_layers=2, d_model=64, vocab=128,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, ffn="swiglu", norm="rms",
    tie_embeddings=False,
    max_seq=64,
)

register(FULL, SMOKE)
