"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2 [hf:xai-org/grok-1; unverified].

head_dim 128. Expert FFNs are SwiGLU (3 * 6144 * 32768 per expert; 8 experts
x 64 layers ~= 309B expert params + ~6B attention = ~315B total). FSDP on;
experts shard over the model axis (EP).
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, vocab=131072,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, n_experts=8, top_k=2, capacity_factor=1.25,
    ffn="swiglu", norm="rms", moe_dispatch="grouped",
    tie_embeddings=False, fsdp=True, remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, vocab=128,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, n_experts=4, top_k=2, capacity_factor=2.0,
    ffn="swiglu", norm="rms",
    tie_embeddings=False,
    max_seq=64,
)

register(FULL, SMOKE)
