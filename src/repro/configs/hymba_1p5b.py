"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads [arXiv:2411.13676; hf].

Each layer runs attention heads and SSM heads in parallel on the same normed
input; branch outputs are RMS-normalized and averaged (Hymba's fused-head
module, simplified: learnable per-branch norms, fixed 0.5/0.5 mix).
Sliding-window attention (2048) on all layers + O(1) SSM state -> the
long-context decode cell (long_500k) is sub-quadratic; cache is a ring
buffer of the window size. (The released Hymba keeps 3 full-attention
layers; we use SWA everywhere — noted in DESIGN.md.)
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, vocab=32001,
    n_heads=25, n_kv_heads=5, head_dim=64,
    sliding_window=2048,
    d_ff=5504, ffn="swiglu", norm="rms",
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    conv_kernel=4, ssd_chunk=256,
    tie_embeddings=True,
    remat="full",
    max_seq=524288,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, vocab=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    sliding_window=32,
    d_ff=128, ffn="swiglu", norm="rms",
    ssm_state=8, ssm_head_dim=16, ssm_expand=2, ssm_groups=1,
    conv_kernel=4, ssd_chunk=16,
    tie_embeddings=True,
    max_seq=64,
)

register(FULL, SMOKE)
