"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT + InternLM2 [arXiv:2404.16821; hf]. The InternViT frontend is a
STUB: ``input_specs`` provides precomputed 3200-dim patch embeddings
(n_patches=1024) projected into the LM; the framework's compressive
acquisition (the paper's own use-case: visual inputs) can pool patches
before the LM via ca_factor. Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, vocab=92553,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, ffn="swiglu", norm="rms",
    tie_embeddings=False, fsdp=True, remat="full",
    frontend="vision", frontend_dim=3200, n_patches=1024,
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="internvl2-26b-smoke", family="vlm",
    n_layers=2, d_model=64, vocab=128,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, ffn="swiglu", norm="rms",
    tie_embeddings=False,
    frontend="vision", frontend_dim=48, n_patches=8,
    max_seq=64,
)

register(FULL, SMOKE)
