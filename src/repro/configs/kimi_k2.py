"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 [arXiv:2501.kimi2; paper-table].

Trillion-param MoE: 384 * 3 * 7168 * 2048 * 61 ~= 1.03T expert params,
~32B active per token (top-8). head_dim 128 (attn_dim 8192 != d_model).
Sort-based dispatch (384 experts make one-hot dispatch tensors infeasible);
experts shard over the model axis. FSDP on.
"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, vocab=163840,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, n_experts=384, top_k=8, capacity_factor=1.25,
    ffn="swiglu", norm="rms", moe_dispatch="grouped",
    tie_embeddings=False, fsdp=True, remat="full",
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, vocab=128,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, n_experts=8, top_k=2, capacity_factor=2.0,
    ffn="swiglu", norm="rms",
    tie_embeddings=False,
    max_seq=64,
)

register(FULL, SMOKE)
