"""configs — assigned architectures (+ the paper's own CNNs).

``get_config(name)`` resolves any registered architecture id, e.g.
``get_config("yi-34b")`` or ``get_config("kimi-k2-1t-a32b")``.
"""

from repro.configs.base import (ModelConfig, register, get_config,
                                list_configs, smoke_variant)

# importing the modules registers their configs
from repro.configs import (  # noqa: F401
    hubert_xlarge, mamba2_1p3b, yi_34b, smollm_360m, tinyllama_1p1b,
    stablelm_3b, hymba_1p5b, grok1_314b, kimi_k2, internvl2_26b,
    vision_cnns,
)

__all__ = ["ModelConfig", "register", "get_config", "list_configs",
           "smoke_variant"]
