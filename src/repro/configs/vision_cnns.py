"""The paper's own CNN configurations (LeNet / VGG9 / VGG16 / AlexNet).

These are not ModelConfig LMs — they are Lightator layer-IR builders (see
``models.vision``), exposed here so ``--arch lenet`` etc. resolve from the
same place as the assigned architectures.
"""

from repro.models.vision import (VISION_MODELS, lenet_ir, vgg9_ir, vgg16_ir,
                                 alexnet_ir)

__all__ = ["VISION_MODELS", "lenet_ir", "vgg9_ir", "vgg16_ir", "alexnet_ir"]
