"""distributed — sharding rules, collectives, fault tolerance, elasticity."""
