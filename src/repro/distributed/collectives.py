"""Collective/overlap helpers.

GSPMD inserts collectives automatically from shardings; these helpers cover
the places where *explicit* control matters:

  * ``async_allreduce_grads`` — kicks off the cross-pod gradient all-reduce
    per-bucket so XLA's latency-hiding scheduler can overlap it with the
    remaining backward compute (bucketing is what makes overlap possible —
    one giant fused all-reduce can't start until the last grad is ready).
  * ``pod_psum`` — shard_map psum over the "pod" axis only (the slow DCN
    hop), used with optim.compression for int8 cross-pod traffic.
"""

from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def bucket_leaves(tree: PyTree, bucket_bytes: int = 32 * 2**20) -> List[List]:
    """Greedy size-bucketing of tree leaves for staged all-reduce."""
    flat = jax.tree.leaves(tree)
    buckets, cur, cur_b = [], [], 0
    for leaf in flat:
        nb = leaf.size * leaf.dtype.itemsize
        if cur and cur_b + nb > bucket_bytes:
            buckets.append(cur)
            cur, cur_b = [], 0
        cur.append(leaf)
        cur_b += nb
    if cur:
        buckets.append(cur)
    return buckets


def pod_psum(tree: PyTree, mesh, in_specs) -> PyTree:
    """Explicit psum over the 'pod' mesh axis via shard_map."""
    from jax.experimental.shard_map import shard_map

    def f(x):
        return jax.tree.map(lambda v: jax.lax.psum(v, "pod"), x)

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=in_specs,
                     check_rep=False)(tree)


def with_optimization_barrier(x: PyTree) -> PyTree:
    """Prevent XLA from sinking comm past this point (manual overlap)."""
    return jax.lax.optimization_barrier(x)
