"""Pipeline parallelism (GPipe schedule) over a mesh axis.

Opt-in PP for depth scaling past what TP+FSDP covers: the stacked layer
params [L, ...] are split into S contiguous stages sharded over a mesh axis;
activations flow stage-to-stage via ``collective_permute`` while M
microbatches fill the pipe (GPipe: M + S - 1 ticks, bubble fraction
(S-1)/(M+S-1)).

Implementation: one ``shard_map`` over the stage axis. Each stage holds its
local layer slice; at tick t it runs microbatch (t - stage_id) if that index
is live, then shifts its output to the next stage. Stage 0 injects inputs;
the last stage's outputs are psum-broadcast at the end (cheap relative to a
training step; avoidable with stage-local consumers).

This module is deliberately self-contained (body_fn in, outputs out) so any
of the framework's layer bodies — including the photonic-quantized ones —
can ride the pipe. Used by tests/test_pipeline.py (4-device sim) and
available through ``launch.steps`` for depth-dominant configs.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_forward(layer_params: PyTree, x: jnp.ndarray,
                     body_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
                     mesh: Mesh, stage_axis: str,
                     n_microbatches: int) -> jnp.ndarray:
    """Run ``body_fn`` over stacked layers with GPipe staging.

    layer_params: pytree with leading layer axis [L, ...], L % S == 0
    x:            [B, T, D] with B % n_microbatches == 0
    body_fn:      (one-layer params, h) -> h
    Returns [B, T, D] — identical (up to reordering of reductions) to
    ``lax.scan(body_fn, x, layer_params)``.
    """
    n_stages = mesh.shape[stage_axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches
    l_total = jax.tree.leaves(layer_params)[0].shape[0]
    assert l_total % n_stages == 0, (l_total, n_stages)

    # [L, ...] -> [S, L/S, ...] so the stage axis can shard dim 0
    staged = jax.tree.map(
        lambda p: p.reshape((n_stages, l_total // n_stages) + p.shape[1:]),
        layer_params)
    xm = x.reshape((n_microbatches, mb) + x.shape[1:])

    other_axes = [a for a in mesh.axis_names if a != stage_axis]

    def stage_fn(p_local, xm_full):
        # p_local: [1, L/S, ...] (stage-sharded); xm_full replicated
        p_local = jax.tree.map(lambda q: q[0], p_local)
        sid = jax.lax.axis_index(stage_axis)
        n_ticks = n_microbatches + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def run_local(h):
            def body(carry, lp):
                return body_fn(lp, carry), None
            out, _ = jax.lax.scan(body, h, p_local)
            return out

        def tick(carry, t):
            buf, acc = carry
            mb_idx = t - sid
            live = (mb_idx >= 0) & (mb_idx < n_microbatches)
            inj = jnp.take(xm_full, jnp.clip(t, 0, n_microbatches - 1),
                           axis=0)
            h_in = jnp.where(sid == 0, inj, buf)
            h_out = run_local(h_in)
            h_out = jnp.where(live[..., None, None, None]
                              if h_out.ndim == 3 else live, h_out,
                              jnp.zeros_like(h_out))
            # last stage banks its result; everyone shifts forward
            acc = jax.lax.cond(
                (sid == n_stages - 1) & live,
                lambda a: a.at[jnp.clip(mb_idx, 0, n_microbatches - 1)]
                .set(h_out),
                lambda a: a, acc)
            buf_next = jax.lax.ppermute(h_out, stage_axis, perm)
            return (buf_next, acc), None

        buf0 = jnp.zeros_like(xm_full[0])
        acc0 = jnp.zeros_like(xm_full)
        (_, acc), _ = jax.lax.scan(tick, (buf0, acc0),
                                   jnp.arange(n_ticks))
        # broadcast the last stage's bank to all stages
        mask = (sid == n_stages - 1).astype(acc.dtype)
        return jax.lax.psum(acc * mask, stage_axis)

    from jax.experimental.shard_map import shard_map
    p_specs = jax.tree.map(
        lambda q: P(*((stage_axis,) + (None,) * (q.ndim - 1))), staged)
    out = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(p_specs, P(*((None,) * xm.ndim))),
        out_specs=P(*((None,) * xm.ndim)),
        check_rep=False)(staged, xm)
    return out.reshape(x.shape)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
