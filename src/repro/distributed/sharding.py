"""Logical-axis sharding: one rule table, applied via NamedSharding/GSPMD.

Models annotate tensors with *logical* axis names (``shard(x, "batch", None,
"embed")``); the launch layer activates a mesh + rule table mapping logical
names to mesh axes. Outside an active mesh the annotations are no-ops, so the
same model code runs single-device smoke tests and 512-chip dry-runs.

Default rule tables:

  TP+DP (small archs)            FSDP+TP (>=10B archs, cfg.fsdp=True)
    batch   -> (pod, data)         batch   -> (pod, data)
    embed   -> None                embed   -> data          (params only)
    heads   -> model               heads   -> model
    kv      -> model               kv      -> model
    ffn     -> model               ffn     -> model
    experts -> model               experts -> model
    vocab   -> model               vocab   -> model
    seq     -> None                seq     -> None (SP opt-in for prefill)

GSPMD handles non-divisible cases by padding (e.g. yi-34b's 56 heads on a
16-way model axis); the roofline notes flag the resulting waste and the perf
pass addresses the ones that matter.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_STATE = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, Axis]]]:
    return getattr(_STATE, "active", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, Axis]):
    """Activate a mesh + logical->mesh rule table for model annotations."""
    prev = _current()
    _STATE.active = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.active = prev


def base_rules(multi_pod: bool = False, fsdp: bool = False,
               seq_shard: bool = False) -> Dict[str, Axis]:
    batch = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, Axis] = {
        "batch": batch,
        "seq": ("data",) if seq_shard else None,
        "embed": ("data",) if fsdp else None,   # params only (FSDP)
        "act_embed": None,                      # activations stay replicated on d_model
        "heads": ("model",),
        "kv": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "ssm_heads": ("model",),
        "expert_embed": ("data",) if fsdp else None,
        "cache_seq": None,
        None: None,
    }
    return rules


def spec_for(*logical: Axis, rules: Optional[Dict[str, Axis]] = None) -> P:
    """Build a PartitionSpec from logical axis names using active rules."""
    if rules is None:
        cur = _current()
        if cur is None:
            return P()
        rules = cur[1]
    entries = []
    for name in logical:
        if name is None:
            entries.append(None)
            continue
        ax = rules.get(name, None)
        if ax is None:
            entries.append(None)
        elif isinstance(ax, tuple):
            entries.append(ax if len(ax) > 1 else ax[0])
        else:
            entries.append(ax)
    return P(*entries)


def shard(x: jax.Array, *logical: Axis) -> jax.Array:
    """Annotate ``x`` with the sharding implied by logical axis names.

    Dims whose logical axis resolves to nothing are left UNCONSTRAINED —
    the partitioner may propagate a better layout than forced replication
    (matters for head counts that don't divide the model axis; §Perf).
    """
    cur = _current()
    if cur is None:
        return x
    mesh, rules = cur
    spec = spec_for(*logical, rules=rules)
    entries = [e if e is not None else P.UNCONSTRAINED for e in spec]
    # batch dim stays a hard constraint; everything unresolved floats
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical: Axis,
                   rules: Optional[Dict[str, Axis]] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(*logical, rules=rules or
                                        base_rules("pod" in mesh.axis_names)))
