"""Fault tolerance: restartable training, straggler detection, preemption.

What "runs on thousands of nodes" requires and what this module provides:

  * **checkpoint/restart** — ``RestartableLoop`` drives train steps with a
    CheckpointManager; any crash resumes from the last complete step (the
    failure-injection test kills the loop mid-run and verifies bit-exact
    continuation thanks to deterministic batch(step)).
  * **preemption handling** — SIGTERM triggers a forced save before exit
    (maintenance events on TPU pods send an eviction signal).
  * **straggler mitigation** — ``StragglerMonitor`` keeps an EWMA of step
    times; steps slower than ``threshold x`` EWMA are flagged, and a
    configurable callback fires (log / re-shard / exclude host). On real
    fleets this hooks the health service; here the policy logic + tests.
  * **failure simulation** — ``FailureInjector`` deterministically raises at
    step k for tests/drills.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

from repro.checkpoint.ckpt import CheckpointManager


class FailureInjector:
    """Raise RuntimeError at a chosen step (deterministic drills)."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step \
                and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StragglerMonitor:
    """EWMA step-time tracker; flags outliers (straggling hosts/steps)."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup_steps: int = 3,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.events: list[StragglerEvent] = []
        self._n = 0

    def record(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        self._n += 1
        if self.ewma is None:
            self.ewma = step_time
            return None
        ev = None
        if self._n > self.warmup and step_time > self.threshold * self.ewma:
            ev = StragglerEvent(step, step_time, self.ewma,
                                step_time / self.ewma)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            # don't poison the EWMA with the outlier
            return ev
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return ev


class RestartableLoop:
    """Drives (state, batch) -> state steps with checkpoint/restart.

    ``state`` is any pytree (params, opt state, step counter inside).
    ``batch_fn(step)`` must be deterministic — restart replays the exact
    stream.
    """

    def __init__(self, step_fn: Callable[[Any, Dict], Any],
                 batch_fn: Callable[[int], Dict],
                 ckpt: CheckpointManager,
                 injector: Optional[FailureInjector] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 handle_sigterm: bool = False):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.injector = injector
        self.monitor = monitor or StragglerMonitor()
        self._preempted = False
        if handle_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, signum, frame):
        self._preempted = True

    def run(self, state: Any, start_step: int, num_steps: int,
            shardings: Any = None):
        """Returns (final_state, last_step, metrics_history)."""
        restored = self.ckpt.restore_latest(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         state) if shardings else state,
            shardings)
        if restored[0] is not None:
            start_step, state = restored
        history = []
        step = start_step
        while step < num_steps:
            if self.injector:
                self.injector.maybe_fail(step)
            t0 = time.time()
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            dt = time.time() - t0
            self.monitor.record(step, dt)
            history.append(metrics)
            step += 1
            if self._preempted:
                self.ckpt.save(step, state, force=True)
                raise SystemExit(143)
            self.ckpt.save(step, state)
        self.ckpt.save(step, state, force=True)
        return state, step, history


import jax  # noqa: E402  (bottom import keeps module load light)
