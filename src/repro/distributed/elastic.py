"""Elastic scaling: resume the same logical job on a different mesh.

Protocol (tested in tests/test_distributed.py):
  1. checkpoints are mesh-agnostic (full arrays + manifest — checkpoint/ckpt)
  2. on restart with a different device count, rebuild mesh + rules via
     ``launch.shardings`` and ``restore_checkpoint(..., shardings=new)``
  3. the data pipeline is a pure function of step, so the global batch is
     identical regardless of how many hosts slice it

``elastic_remesh`` is the one-call wrapper: given a checkpoint dir, a config
and a new mesh, it returns (step, params, opt_state) sharded for that mesh.
"""

from __future__ import annotations

from pathlib import Path
from typing import Optional, Tuple

import jax

from repro.checkpoint.ckpt import latest_step, restore_checkpoint
from repro.configs.base import ModelConfig
from repro.launch import shardings as sh


def elastic_remesh(ckpt_dir: str | Path, cfg: ModelConfig, mesh,
                   params_shape, opt_shape=None,
                   step: Optional[int] = None) -> Tuple:
    """Restore a checkpoint onto ``mesh`` (any shape/device count)."""
    rules = sh.build_rules(cfg, mesh)
    p_shard = sh.tree_shardings(params_shape, cfg, mesh, rules)
    target = {"params": params_shape}
    shard_tree = {"params": p_shard}
    if opt_shape is not None:
        target["opt"] = opt_shape
        shard_tree["opt"] = sh.tree_shardings(opt_shape, cfg, mesh, rules)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    restored = restore_checkpoint(ckpt_dir, target, step, shard_tree)
    return (step, restored["params"],
            restored.get("opt") if opt_shape is not None else None)
