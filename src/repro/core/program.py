"""repro.core.program — the one front door for optical programs.

Lightator's pitch is one device serving *versatile* workloads: CNN inference
and fixed-function imaging compile onto the same optical-core runtime. This
module gives them one uniform invocation, replacing three uncoordinated
conventions (``plan.compile_model`` kwargs, bare ``(layers, params)``
tuples, ``PIPELINES[name].build``) and four scattered ``REPRO_*`` env reads:

    Program     a value object bundling (layer IR, params, input frame
                shape, name). Built from models (``models.vision.
                vision_program`` / ``Program.from_model``), from imaging
                pipelines (``imaging.PIPELINES[name].program(h, w, c)`` /
                ``Program.from_pipeline``), or directly from IR + params.
                ``Program.then`` composes two programs into ONE program —
                an imaging chain (denoise -> edge_detect) compiles as a
                single ``CompiledPlan``, one jit, one power report.

    Options     every knob that was a ``compile_model`` kwarg or a
                ``REPRO_*`` env var, as explicit dataclass fields with
                env-var defaults: scheme, OC/circuit/profile/SRAM config,
                ``fc_batch``, kernel backend, Pallas interpret flag, conv
                strategy + VMEM budget, and batch sharding over local
                devices.

    Executable  ``program.compile(options)``: the cached ``CompiledPlan``
                plus the resolved options. ``.run(frames)`` executes
                batch-first under the options' backend/interpret pin (and
                shards the batch axis over a device mesh when asked),
                ``.report`` / ``.plan`` expose the power report and plan.

Quick start::

    import repro

    prog = repro.Program.from_pipeline("edge_detect", 64, 64, 3)
    exe = prog.compile(repro.Options(scheme=W4A4, backend="reference"))
    edges = exe.run(frames)                 # [B, 64, 64, 1]
    print(exe.report.kfps_per_w)

The old entry points (``plan.compile_model``, ``plan.execute``,
``LightatorDevice.run``) survive as deprecated shims that call the same
internals — bit-identical, regression-tested in tests/test_program_api.py.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import verifier as _verifier
from repro.core import optical_core as ocore
from repro.core import plan as plan_mod
from repro.core import power_model as pmod
from repro.core.quant import W4A4, MixedPrecisionScheme, WASpec
from repro.kernels import dispatch


# ---------------------------------------------------------------------------
# Options
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Options:
    """Everything that shapes how a :class:`Program` compiles and runs.

    One documented code path for what used to be ``compile_model`` kwargs
    plus four scattered env vars. Every ``None`` field defers to the same
    env-var/auto default the old path used, resolved at compile/run time —
    so ``Options()`` is exactly the ambient behaviour, and an explicit
    value equal to the ambient default hits the same cached plan:

    ==================  =========================  =======================
    field               env default when ``None``  meaning
    ==================  =========================  =======================
    ``backend``         ``REPRO_KERNEL_BACKEND``   ``pallas`` | ``reference``
                        (else pallas on TPU)       kernel dispatch target
    ``interpret``       ``REPRO_FORCE_INTERPRET``  Pallas interpret flag
                        (else off on TPU)
    ``conv_strategy``   ``REPRO_CONV_STRATEGY``    ``auto`` | ``resident``
                        (else ``auto``)            | ``strip`` | ``fused``
    ``conv_vmem_budget``  ``REPRO_CONV_VMEM_BUDGET``  heuristic budget, bytes
    ``fuse``            derived from the conv      megakernel chain fusion:
                        strategy mode              ``auto`` | ``on`` | ``off``
    ``trace``           ``REPRO_TRACE``            obs span/event emission:
                        (else ``auto``)            ``auto`` | ``on`` | ``off``
    ``verify``          ``REPRO_VERIFY``           plan verifier (repro.
                        (else ``auto``)            analysis): ``auto`` |
                                                   ``on`` | ``off``
    ==================  =========================  =======================

    ``fuse`` controls the megakernel pass (``dispatch.
    select_fused_segments``): runs of chainable convs execute as ONE kernel
    launch each, bit-identical to the unfused path. ``auto`` fuses runs of
    >= 2 stages under the channel cap + VMEM budget; ``on`` fuses every
    legal run (singletons included); ``off`` disables. ``None`` derives the
    mode from the conv strategy: ``fused`` -> on, forced ``resident``/
    ``strip`` -> off, ``auto`` -> auto.

    ``trace`` mirrors ``fuse``'s tri-state: ``auto`` emits spans/events
    only while an :func:`repro.obs.enable` collector is installed (the
    default — zero overhead otherwise), ``on`` forces emission (lazily
    installing a collector), ``off`` suppresses it even when a collector
    is live. The pin is per-thread for the duration of ``compile``/``run``
    (``obs.use_mode``) and deliberately stays OUT of the plan cache key:
    tracing never changes what gets compiled, so traced and untraced
    callers share the same cached plan.

    ``verify`` mirrors the same tri-state for the compile-time plan
    verifier (``repro.analysis.verify_plan``: the ``|acc| < 2^24``
    integer-exactness proof, shape legality, strip/fusion VMEM audit —
    docs/analysis.md). ``auto`` (the default) verifies on every
    cache-miss compile and raises
    :class:`repro.analysis.PlanVerificationError` at error severity;
    ``on`` additionally re-checks cache hits (a plan first compiled
    under "off" still gets proved before use); ``off`` skips. Findings
    at warning severity land in ``Executable.report.verification``
    without raising. Like ``trace``, the mode stays OUT of the plan
    cache key — verification never changes what gets compiled.

    ``shard_batch`` shards ``Executable.run``'s batch axis over the local
    devices (or an explicit ``mesh``) via ``NamedSharding`` — a graceful
    no-op on a single device or when the batch does not divide the device
    count. Sharding never changes the numerics: the only cross-example
    reduction in the execute pass is the CRC calibration ``max``, which is
    order-independent.
    """

    scheme: WASpec | MixedPrecisionScheme = W4A4
    oc: ocore.OCConfig = ocore.DEFAULT_OC
    circuit: pmod.CircuitConstants = pmod.DEFAULT_CIRCUIT
    profile: pmod.AcceleratorProfile = pmod.LIGHTATOR_PROFILE
    weight_sram_kb: float = 512.0
    act_sram_kb: float = 256.0
    fc_batch: int = 1
    backend: Optional[str] = None
    interpret: Optional[bool] = None
    conv_strategy: Optional[str] = None
    conv_vmem_budget: Optional[int] = None
    fuse: Optional[str] = None
    trace: Optional[str] = None
    verify: Optional[str] = None
    shard_batch: bool = False
    mesh: Optional[jax.sharding.Mesh] = None

    def __post_init__(self):
        if self.fc_batch < 1:
            raise ValueError(f"fc_batch must be >= 1, got {self.fc_batch}")
        if self.backend is not None and self.backend not in dispatch.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected "
                             f"one of {dispatch.BACKENDS}")
        if (self.conv_strategy is not None
                and self.conv_strategy not in dispatch.CONV_STRATEGIES):
            raise ValueError(
                f"unknown conv strategy {self.conv_strategy!r}; expected "
                f"one of {dispatch.CONV_STRATEGIES}")
        if self.conv_vmem_budget is not None and self.conv_vmem_budget <= 0:
            raise ValueError(f"conv_vmem_budget must be > 0, got "
                             f"{self.conv_vmem_budget}")
        if self.fuse is not None and self.fuse not in dispatch.FUSE_MODES:
            raise ValueError(f"unknown fuse mode {self.fuse!r}; expected "
                             f"one of {dispatch.FUSE_MODES}")
        if self.trace is not None and self.trace not in obs.TRACE_MODES:
            raise ValueError(f"unknown trace mode {self.trace!r}; expected "
                             f"one of {obs.TRACE_MODES}")
        if (self.verify is not None
                and self.verify not in _verifier.VERIFY_MODES):
            raise ValueError(f"unknown verify mode {self.verify!r}; "
                             f"expected one of {_verifier.VERIFY_MODES}")

    def resolve(self) -> "Options":
        """Fill every ``None`` field from its env-var/auto default.

        What ``compile``/``run`` actually act on — and what the serving
        header prints, so the operator sees the effective configuration,
        not the unresolved ``None``s.
        """
        return dataclasses.replace(
            self,
            backend=(self.backend if self.backend is not None
                     else dispatch.get_backend()),
            interpret=(self.interpret if self.interpret is not None
                       else dispatch.default_interpret()),
            conv_strategy=(self.conv_strategy if self.conv_strategy is not None
                           else dispatch.conv_strategy_mode()),
            conv_vmem_budget=(self.conv_vmem_budget
                              if self.conv_vmem_budget is not None
                              else dispatch.conv_vmem_budget()),
            fuse=(self.fuse if self.fuse is not None
                  else dispatch.conv_fuse_mode(self.conv_strategy)),
            trace=(self.trace if self.trace is not None
                   else obs.trace_mode()),
            verify=(self.verify if self.verify is not None
                    else _verifier.verify_mode()),
        )

    def describe(self) -> str:
        """One-line summary of the *resolved* options (serving headers)."""
        r = self.resolve()
        shard = ""
        if r.shard_batch:
            n = (r.mesh.devices.size if r.mesh is not None
                 else len(jax.local_devices()))
            shard = f" shard_batch={n}dev"
        vmem = (f"{r.conv_vmem_budget >> 20}MB"
                if r.conv_vmem_budget >= (1 << 20)
                else f"{r.conv_vmem_budget >> 10}KB")
        trace = f" trace={r.trace}" if r.trace != "auto" else ""
        verify = f" verify={r.verify}" if r.verify != "auto" else ""
        return (f"scheme={r.scheme.name} backend={r.backend} "
                f"interpret={r.interpret} conv={r.conv_strategy}"
                f"(vmem={vmem}) fuse={r.fuse} "
                f"fc_batch={r.fc_batch}{trace}{verify}{shard}")


# ---------------------------------------------------------------------------
# Program
# ---------------------------------------------------------------------------

def infer_output_hwc(layers: Sequence,
                     input_hwc: Tuple[int, int, int]) -> Tuple[int, int, int]:
    """Shape-infer a layer-IR program: input [H, W, C] -> output [H', W', C'].

    The same per-layer arithmetic the compile pass runs (dense outputs come
    back as ``(1, 1, fan_out)``) without scheduling anything — what
    :meth:`Program.then` uses to check chain compatibility. Pool/CA
    divisibility violations are *not* raised here; they surface with the
    compile pass's own error at ``Program.compile``.

    NB: keep the per-layer cases in lockstep with ``plan._compile_model``'s
    shape walk — ``tests/test_program_api.py`` pins the two against each
    other on every vision model and several pipelines.
    """
    from repro.core.accelerator import (CASpec, ConvSpec, DenseSpec,
                                        FlattenSpec, UpsampleSpec)
    h, w, c = input_hwc
    for layer in layers:
        if isinstance(layer, CASpec):
            h, w = h // layer.pool, w // layer.pool
            rgb = (layer.rgb_to_gray if layer.rgb_to_gray is not None
                   else c == 3)
            c = 1 if (rgb or c == 1) else c
        elif isinstance(layer, ConvSpec):
            h = plan_mod.conv_out_hw(h, layer.kernel, layer.stride,
                                     layer.padding)
            w = plan_mod.conv_out_hw(w, layer.kernel, layer.stride,
                                     layer.padding)
            c = layer.c_out
            if layer.pool is not None:
                h, w = h // layer.pool[1], w // layer.pool[1]
        elif isinstance(layer, UpsampleSpec):
            h, w = h * layer.factor, w * layer.factor
        elif isinstance(layer, FlattenSpec):
            h, w, c = 1, 1, h * w * c
        elif isinstance(layer, DenseSpec):
            h, w, c = 1, 1, layer.fan_out
        else:
            raise TypeError(f"unknown layer IR {layer!r}")
    return h, w, c


@dataclasses.dataclass(frozen=True, eq=False)
class Program:
    """A compilable optical program: layer IR + params + input frame shape.

    The uniform currency of the API — CNNs (:func:`models.vision.
    vision_program`), imaging pipelines (``PIPELINES[name].program``) and
    hand-written IR all become ``Program``s, and every one compiles and
    runs the same way::

        exe = program.compile(Options(scheme=MX_43))
        out = exe.run(frames)
    """

    layers: Tuple
    params: Dict[str, Dict]
    input_hwc: Tuple[int, int, int]
    name: str = "program"

    def __post_init__(self):
        object.__setattr__(self, "layers", tuple(self.layers))
        hwc = tuple(int(d) for d in self.input_hwc)
        if len(hwc) != 3:
            raise ValueError(f"input_hwc {self.input_hwc!r} must be "
                             f"(H, W, C)")
        object.__setattr__(self, "input_hwc", hwc)

    # -- constructors -----------------------------------------------------

    @classmethod
    def from_model(cls, name: str, key=None, params: Optional[Dict] = None
                   ) -> "Program":
        """A paper CNN by name (``lenet`` / ``vgg9`` / ``vgg16``) — see
        :func:`repro.models.vision.vision_program`."""
        from repro.models.vision import vision_program
        return vision_program(name, key=key, params=params)

    @classmethod
    def from_pipeline(cls, name: str, h: int, w: int, c: int = 3
                      ) -> "Program":
        """An imaging pipeline by registry name, built for [h, w, c]."""
        from repro.imaging import PIPELINES
        if name not in PIPELINES:
            raise ValueError(f"unknown pipeline {name!r}; choose from "
                             f"{sorted(PIPELINES)}")
        return PIPELINES[name].program(h, w, c)

    # -- composition ------------------------------------------------------

    @property
    def output_hwc(self) -> Tuple[int, int, int]:
        """The program's output frame shape (dense outputs: (1,1,n))."""
        return infer_output_hwc(self.layers, self.input_hwc)

    def then(self, other: "Program", name: Optional[str] = None) -> "Program":
        """Compose: this program's output feeds ``other``'s input.

        Returns ONE program — the concatenated IR compiles as a single
        ``CompiledPlan`` (one jit, one power report), which is how imaging
        chains (denoise -> edge_detect, compress -> recon -> sharpen) fuse
        at the program level instead of round-tripping through host memory
        between stages. ``other`` must have been built for this program's
        output shape. Layer names colliding with ours are suffixed
        (``grad`` -> ``grad.2``) in both the IR and the params, so chaining
        two instances of the same pipeline works.
        """
        out_hwc = self.output_hwc
        if tuple(other.input_hwc) != out_hwc:
            raise ValueError(
                f"cannot chain {self.name!r} -> {other.name!r}: output "
                f"{out_hwc} does not match {other.name!r}'s input "
                f"{tuple(other.input_hwc)}; rebuild the second program "
                f"for the first one's output shape")
        taken = {l.name for l in self.layers if hasattr(l, "name")}
        layers = list(self.layers)
        params = dict(self.params)
        for layer in other.layers:
            if hasattr(layer, "name"):
                new = layer.name
                i = 2
                while new in taken:
                    new, i = f"{layer.name}.{i}", i + 1
                taken.add(new)
                if new != layer.name:
                    if layer.name in other.params:
                        params[new] = other.params[layer.name]
                    layer = dataclasses.replace(layer, name=new)
                elif layer.name in other.params:
                    params[new] = other.params[layer.name]
            layers.append(layer)
        return Program(tuple(layers), params, self.input_hwc,
                       name=name or f"{self.name}>{other.name}")

    # -- compile ----------------------------------------------------------

    def compile(self, options: Optional[Options] = None) -> "Executable":
        """Static pass: resolve the (cached) plan under ``options``."""
        options = options or Options()
        with contextlib.ExitStack() as stack:
            if options.trace is not None:
                stack.enter_context(obs.use_mode(options.trace))
            plan = plan_mod._compile_model(
                self.layers, self.input_hwc, options.scheme, oc=options.oc,
                circuit=options.circuit, profile=options.profile,
                weight_sram_kb=options.weight_sram_kb,
                act_sram_kb=options.act_sram_kb, fc_batch=options.fc_batch,
                conv_strategy=options.conv_strategy,
                conv_vmem_budget=options.conv_vmem_budget,
                fuse=options.fuse, verify=options.verify)
        return Executable(self, options, plan)


# ---------------------------------------------------------------------------
# Executable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class Executable:
    """A compiled program: ``CompiledPlan`` + the options it runs under.

    ``run`` is batch-first and jit-cached per (backend, interpret, shape)
    on the shared plan — two Executables over the same plan with different
    backends each get their own trace (the ``executor()`` keying), and the
    plan itself is shared through the global plan cache.
    """

    program: Program
    options: Options
    _plan: plan_mod.CompiledPlan
    _sharded_params: Optional[Dict] = dataclasses.field(
        default=None, repr=False)
    _report_copy: Optional[pmod.ModelReport] = dataclasses.field(
        default=None, repr=False)
    _mesh: Optional[jax.sharding.Mesh] = dataclasses.field(
        default=None, repr=False)
    # device-bound view state (see bind()): the committed target device,
    # the params replicated onto it, whether input device buffers are
    # donated to the computation, and the reusable host staging buffers
    # run_padded pads into — a ring of `_staging_slots` buffers per
    # (bucket, frame shape) key, rotated per use so a buffer is never
    # mutated while an async-dispatched batch may still read it
    _device: Optional[jax.Device] = dataclasses.field(
        default=None, repr=False)
    _device_params: Optional[Dict] = dataclasses.field(
        default=None, repr=False)
    _donate: bool = dataclasses.field(default=False, repr=False)
    _staging: Dict = dataclasses.field(default_factory=dict, repr=False)
    _staging_slots: int = dataclasses.field(default=2, repr=False)

    @property
    def plan(self) -> plan_mod.CompiledPlan:
        return self._plan

    @property
    def report(self) -> pmod.ModelReport:
        """The architecture power/latency report (per frame).

        A private copy: the plan (and its report) is shared process-wide
        through the plan cache, so callers mutating what they got back must
        not corrupt other Executables or future cache hits (the same guard
        the ``LightatorDevice.run`` shim applies).
        """
        if self._report_copy is None:
            import copy
            self._report_copy = copy.deepcopy(self._plan.report)
        return self._report_copy

    def _pinned(self) -> contextlib.ExitStack:
        """Enter the options' backend/interpret/trace pins (per-thread)."""
        stack = contextlib.ExitStack()
        if self.options.backend is not None:
            stack.enter_context(dispatch.use_backend(self.options.backend))
        if self.options.interpret is not None:
            stack.enter_context(dispatch.use_interpret(self.options.interpret))
        if self.options.trace is not None:
            stack.enter_context(obs.use_mode(self.options.trace))
        return stack

    def run(self, frames) -> jnp.ndarray:
        """Execute ``frames`` [B, H, W, C] (or one [H, W, C] frame).

        Returns logits [B, n] for classifier programs or an image
        [B, H', W', C'] for spatial programs. An explicit
        ``options.backend`` / ``options.interpret`` is pinned for the
        duration of the call; ``None`` fields keep deferring to the
        ambient ``set_backend`` / env state, exactly like the old path.
        """
        frames = jnp.asarray(frames)
        with self._pinned():
            if self._device is not None:
                frames, params = self._place(frames)
            else:
                frames, params = self._shard(frames)
            return plan_mod._execute(self._plan, params, frames)

    def __call__(self, frames) -> jnp.ndarray:
        return self.run(frames)

    # -- device binding (the serving pool's per-device executables) -------

    @property
    def device(self) -> Optional[jax.Device]:
        """The committed target device (None: follow ambient placement)."""
        return self._device

    def bind(self, device, donate: Optional[bool] = None,
             staging_slots: int = 2) -> "Executable":
        """A device-committed view of this Executable (``repro.serve`` pool).

        The returned Executable shares this one's compiled plan (and jit
        cache) but commits execution to ``device``: frames are
        ``device_put`` there and the params are replicated onto it once
        and cached. It also enables the host-side serving optimizations:

        * ``run_padded`` pads into a **ring of reusable host staging
          buffers** per (bucket, frame-shape) instead of allocating +
          zero-filling a fresh array per batch. ``staging_slots`` is the
          ring depth: it must be >= the number of batches the caller may
          have async-dispatched but not yet awaited, plus one being
          staged — ``jax.device_put`` of a numpy array is not guaranteed
          to copy synchronously (zero-copy aliasing on CPU, lazy H2D
          elsewhere), so a buffer must not be rewritten until the batch
          that staged into it has materialized. The pool passes its
          per-device pipeline depth (``ServeConfig.max_inflight``); the
          default of 2 covers the worker's dispatch-then-await-previous
          overlap;
        * with ``donate`` (default: on everywhere except the CPU backend,
          which cannot alias the buffers and would warn), the frames'
          device buffer is **donated** to the computation, so XLA can
          reuse it rather than holding input and output live together.

        Both make the bound view unsafe for *shared-input* callers: the
        staging buffer means concurrent ``run_padded`` calls on one bound
        Executable race, and donation consumes whatever device array the
        run was given. The pool gives each device worker its own bound
        view and stages every input itself, so it satisfies both
        contracts; treat ``bind`` as the pool's seam, not a general API.
        ``shard_batch`` is ignored on a bound view (the batch is already
        placed on exactly one device).
        """
        if donate is None:
            donate = jax.default_backend() != "cpu"
        if staging_slots < 1:
            raise ValueError(
                f"staging_slots must be >= 1, got {staging_slots}")
        exe = Executable(self.program, self.options, self._plan)
        exe._device = device
        exe._donate = bool(donate)
        exe._staging_slots = int(staging_slots)
        return exe

    def _place(self, frames: jnp.ndarray):
        """Commit frames + (cached) params to the bound device."""
        if self._device_params is None:
            self._device_params = jax.device_put(self.program.params,
                                                 self._device)
        return jax.device_put(frames, self._device), self._device_params

    # -- serving: per-frame calibration + batch buckets -------------------

    def run_per_frame(self, frames) -> jnp.ndarray:
        """Execute with *per-frame* CRC calibration (serving semantics).

        The seed-faithful :meth:`run` reduces every CRC requant scale over
        the whole tensor, batch axis included, so a frame's output depends
        on its batch neighbours. This variant reduces each scale over the
        frame's own axes instead — the hardware's frame-per-pass
        calibration: every frame's result is a pure function of that frame,
        so batch composition (and zero-padding) can never perturb it, and
        each frame is bit-identical to the same frame at batch 1 under
        either method. This is the executor ``repro.serve``'s micro-batcher
        coalesces requests onto.
        """
        frames = jnp.asarray(frames)
        with self._pinned():
            if self._device is not None:
                frames, params = self._place(frames)
            else:
                frames, params = self._shard(frames)
            return plan_mod._execute(self._plan, params, frames,
                                     per_frame=True, donate=self._donate)

    def run_padded(self, frames, bucket: int) -> jnp.ndarray:
        """Padded-run helper: execute ``frames`` at a fixed batch bucket.

        Zero-pads the batch up to ``bucket`` (batches beyond it run in
        ``bucket``-sized chunks), executes per-frame-calibrated, and slices
        the real results back out — so a server always hits one of a few
        pre-compiled batch shapes instead of jit-tracing every queue
        length. Per-frame calibration severs every cross-frame data path,
        so the padding frames provably cannot change the real frames'
        results (bit-identical to batch-1 :meth:`run` calls per frame;
        regression-tested in tests/test_serve.py).

        A device-bound view (:meth:`bind`) pads into a ring of reusable
        host staging buffers per (bucket, frame shape) instead of
        allocating a fresh padded array every batch. The ring exists
        because ``jax.device_put`` of a numpy array need not copy
        synchronously (zero-copy aliasing on CPU, lazy H2D elsewhere):
        a pipelining pool worker dispatches batch N+1 before awaiting
        batch N, so N's buffer may still back N's in-flight computation
        while N+1 stages. Rotating ``staging_slots`` (>= pipeline depth)
        buffers guarantees a slot only comes back around after the batch
        that staged into it was awaited — each pool worker owns its
        bound view exclusively, so no further synchronization is needed,
        and pad content is provably inert either way (it cannot reach
        the real frames' results). Only the final chunk of an oversized
        batch can be partial, so one call uses at most one slot.
        """
        if bucket < 1:
            raise ValueError(f"bucket must be >= 1, got {bucket}")
        frames = np.asarray(frames, np.float32)
        if frames.ndim == 3:
            frames = frames[None]
        n = frames.shape[0]
        outs = []
        for off in range(0, n, bucket):
            chunk = frames[off:off + bucket]
            real = chunk.shape[0]
            if real < bucket:
                if self._device is not None:
                    key = (bucket, chunk.shape[1:])
                    ring = self._staging.setdefault(key, [])
                    if len(ring) < self._staging_slots:
                        buf = np.zeros((bucket, *chunk.shape[1:]),
                                       np.float32)
                    else:
                        # oldest slot: the batch that staged into it was
                        # awaited >= slots-1 dispatches ago
                        buf = ring.pop(0)
                    ring.append(buf)
                    buf[:real] = chunk
                    buf[real:] = 0.0
                    chunk = buf
                else:
                    chunk = np.concatenate(
                        [chunk, np.zeros((bucket - real, *chunk.shape[1:]),
                                         np.float32)])
            outs.append(self.run_per_frame(chunk)[:real])
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    def warm(self, buckets: Sequence[int] = (1,)) -> "Executable":
        """Trace + compile the per-frame executor at each bucket size.

        Serving warm-up: the first request at a new batch shape otherwise
        pays the full jit trace. Runs a zero batch per bucket and blocks,
        so device caches are primed too. Returns ``self`` for chaining.
        """
        h, w, c = self.program.input_hwc
        for b in sorted({int(b) for b in buckets}):
            if b < 1:
                raise ValueError(f"bucket must be >= 1, got {b}")
            self.run_per_frame(
                jnp.zeros((b, h, w, c), jnp.float32)).block_until_ready()
        return self

    # -- batch sharding ---------------------------------------------------

    def _shard(self, frames: jnp.ndarray):
        """Shard the batch axis over local devices (ROADMAP item).

        No-op unless ``options.shard_batch``, there are >= 2 devices, and
        the batch divides the device count — the single-device laptop path
        is byte-for-byte the unsharded one. Params are replicated (they are
        small: filter taps / CNN weights), frames are split on axis 0; the
        jitted executor picks the shardings up via GSPMD.
        """
        params = self.program.params
        if not self.options.shard_batch or frames.ndim != 4:
            return frames, params
        if self._mesh is None:
            mesh = self.options.mesh
            if mesh is None:
                if len(jax.local_devices()) <= 1:
                    return frames, params
                mesh = jax.sharding.Mesh(
                    np.asarray(jax.local_devices()), ("batch",))
            self._mesh = mesh          # invariant for this Executable
        mesh = self._mesh
        # the batch axis rides the mesh's FIRST axis (whatever the caller
        # named it); divisibility is against that axis alone
        axis = mesh.axis_names[0]
        n = mesh.shape[axis]
        if n <= 1 or frames.shape[0] % n != 0:
            return frames, params
        P = jax.sharding.PartitionSpec
        frames = jax.device_put(
            frames, jax.sharding.NamedSharding(mesh, P(axis)))
        if self._sharded_params is None:
            self._sharded_params = jax.device_put(
                params, jax.sharding.NamedSharding(mesh, P()))
        return frames, self._sharded_params
