"""Optical Core geometry and the paper's hardware-mapping methodology (Sec. 4).

Geometry: MRs are organized in groups of 9 per arm (matched to the ubiquitous
3x3 kernel), 6 arms per bank, 96 banks in an 8-column x 12-row array:
9 * 6 * 96 = 5184 MRs => at most 5184 MACs per optical cycle.

Mapping rules reproduced exactly (Fig. 6):
  3x3 kernel  -> 9 taps  -> 1 arm/stride,  6 strides/bank, 0 idle MRs, summation unused
  5x5 kernel  -> 25 taps -> 3 arms/stride, 2 strides/bank, 2 idle MRs/stride, stage-1 sum
  7x7 kernel  -> 49 taps -> 6 arms/stride, 1 stride/bank,  5 idle MRs/stride, stage-1+2 sum
  FC          -> fan-in segmented into 9-MAC chunks + summation tree

Execution model (weight-stationary, non-replicated — Sec. 3: "weight values
are stored in a dedicated memory and then mapped to the MRs during the
processing of each layer"):

  1. Map as many distinct kernels / output neurons as fit the 576 arms.
  2. Stream every input window (position / token) through the mapped set —
     one optical cycle per window; the DMVA broadcasts the window's
     activations to all banks.
  3. Remap the next round of kernels (DAC settle = ``remap`` latency) and
     repeat until all output channels are produced.

The scheduler turns layer shapes into optical cycles, remap rounds, and
mapped-MR occupancy — the inputs to the power/latency model (Fig. 8/9/10).
The same blocking is the tiling schema of the ``photonic_mvm`` Pallas
kernel: one round's weight tile resident in VMEM == one OC weight mapping.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict


@dataclasses.dataclass(frozen=True)
class OCConfig:
    mrs_per_arm: int = 9
    arms_per_bank: int = 6
    bank_cols: int = 8
    bank_rows: int = 12

    @property
    def n_banks(self) -> int:
        return self.bank_cols * self.bank_rows           # 96

    @property
    def mrs_per_bank(self) -> int:
        return self.mrs_per_arm * self.arms_per_bank      # 54

    @property
    def total_mrs(self) -> int:
        return self.mrs_per_bank * self.n_banks           # 5184

    @property
    def total_arms(self) -> int:
        return self.arms_per_bank * self.n_banks          # 576

    @property
    def macs_per_cycle(self) -> int:
        return self.total_mrs                             # 5184


DEFAULT_OC = OCConfig()


@dataclasses.dataclass(frozen=True)
class ConvMapping:
    """How one stride (output position) of a kernel maps onto bank arms."""

    kernel_taps: int          # k*k*c_in taps feeding one output
    arms_per_stride: int      # arms needed for one stride
    strides_per_bank: int     # concurrent strides in one bank (0 => multi-bank)
    banks_per_stride: int     # banks needed when a stride spans banks
    idle_mrs_per_stride: int  # MRs left unused (gray in Fig. 6)
    summation_stages: int     # 0 (BPD only), 1, or 2


def conv_mapping(kernel_size: int, c_in: int = 1, oc: OCConfig = DEFAULT_OC) -> ConvMapping:
    """Paper Fig. 6 mapping, generalized to multi-channel inputs.

    For the paper's single-channel examples this reproduces exactly:
      k=3 -> (1 arm, 6 strides/bank, 0 idle, 0 stages)
      k=5 -> (3 arms, 2 strides/bank, 2 idle, 1 stage)
      k=7 -> (6 arms, 1 stride/bank, 5 idle, 2 stages)
    """
    taps = kernel_size * kernel_size * c_in
    arms = math.ceil(taps / oc.mrs_per_arm)
    if arms <= oc.arms_per_bank:
        strides_per_bank = oc.arms_per_bank // arms
        banks_per_stride = 1
    else:
        strides_per_bank = 0
        banks_per_stride = math.ceil(arms / oc.arms_per_bank)
    idle = arms * oc.mrs_per_arm - taps
    if arms == 1:
        stages = 0
    elif arms <= 3:
        stages = 1
    else:
        stages = 2
    return ConvMapping(taps, arms, strides_per_bank, banks_per_stride, idle, stages)


def fc_mapping(fan_in: int, oc: OCConfig = DEFAULT_OC) -> ConvMapping:
    """FC layers: segment fan_in into 9-MAC chunks, aggregate in the tree."""
    return conv_mapping(1, c_in=fan_in, oc=oc)


def kernels_resident(m: ConvMapping, oc: OCConfig = DEFAULT_OC) -> int:
    """Distinct kernels / output neurons concurrently mapped on the OC."""
    if m.strides_per_bank > 0:
        return m.strides_per_bank * oc.n_banks
    return max(oc.n_banks // m.banks_per_stride, 1)


# ---------------------------------------------------------------------------
# Cycle scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OCSchedule:
    """Optical-cycle schedule for one layer — feeds the power/latency model."""

    name: str
    kind: str                 # "conv" | "fc" | "ca" | "matmul"
    cycles: int               # streaming optical cycles
    macs: int                 # useful MACs
    mapped_mrs_avg: float     # MRs concurrently holding weights (DAC/TUN load)
    idle_mr_fraction: float   # fraction of occupied-arm MRs idle (mapping waste)
    weight_remaps: int        # weight-mapping rounds (DAC settle events)
    vcsel_channels: float     # concurrent activation wavelengths (DMVA load)
    bpd_reads: int            # arm read-outs over the layer
    summation_ops: int        # electronic partial-sum additions over the layer
    mapping: ConvMapping | None = None

    @property
    def utilization(self) -> float:
        """Useful MACs / theoretical OC MACs over the streaming cycles."""
        total = self.cycles * DEFAULT_OC.macs_per_cycle
        return self.macs / total if total else 0.0


def _schedule_mvm(name: str, kind: str, n_windows: int, taps: int,
                  n_outputs: int, m: ConvMapping,
                  oc: OCConfig = DEFAULT_OC,
                  preset_weights: bool = False) -> OCSchedule:
    """Common engine: n_outputs kernels of ``taps`` taps over n_windows."""
    resident = min(kernels_resident(m, oc), n_outputs)
    rounds = math.ceil(n_outputs / resident)
    cycles = rounds * n_windows
    macs = n_windows * n_outputs * taps
    mapped_mrs = resident * m.arms_per_stride * oc.mrs_per_arm
    # average over rounds (last round may be partially filled)
    avg_resident = n_outputs / rounds
    mapped_mrs_avg = avg_resident * m.arms_per_stride * oc.mrs_per_arm
    vcsel_channels = min(float(taps), float(oc.total_mrs))
    bpd_reads = n_windows * n_outputs * m.arms_per_stride
    summation_ops = n_windows * n_outputs * max(m.arms_per_stride - 1, 0)
    idle_frac = m.idle_mrs_per_stride / (m.arms_per_stride * oc.mrs_per_arm)
    return OCSchedule(name, kind, cycles, macs,
                      min(mapped_mrs_avg, float(oc.total_mrs)), idle_frac,
                      0 if preset_weights else rounds,
                      vcsel_channels, bpd_reads, summation_ops, m)


def schedule_conv(name: str, h_out: int, w_out: int, c_in: int, c_out: int,
                  kernel_size: int, oc: OCConfig = DEFAULT_OC) -> OCSchedule:
    """Conv layer: windows = output positions, outputs = output channels."""
    m = conv_mapping(kernel_size, c_in, oc)
    return _schedule_mvm(name, "conv", h_out * w_out, m.kernel_taps,
                         c_out, m, oc)


def schedule_fc(name: str, fan_in: int, fan_out: int, batch: int = 1,
                oc: OCConfig = DEFAULT_OC) -> OCSchedule:
    m = fc_mapping(fan_in, oc)
    return _schedule_mvm(name, "fc", batch, fan_in, fan_out, m, oc)


def schedule_matmul(name: str, m_rows: int, k: int, n_cols: int,
                    oc: OCConfig = DEFAULT_OC) -> OCSchedule:
    """Generic MVM (used for the LM-arch cost model): [M,K] @ [K,N]."""
    m = fc_mapping(k, oc)
    s = _schedule_mvm(name, "matmul", m_rows, k, n_cols, m, oc)
    return s


def schedule_ca(name: str, h_out: int, w_out: int, pool: int,
                channels: int = 3, oc: OCConfig = DEFAULT_OC) -> OCSchedule:
    """Compressive Acquisitor: fused RGB->gray + pool x pool mean pooling.

    One fused "kernel" with pre-set coefficients (paper eq. (1)): no DACs,
    no remaps — the CA banks are weight-preset at design time.
    """
    m = conv_mapping(pool, channels, oc)
    return _schedule_mvm(name, "ca", h_out * w_out, m.kernel_taps, 1, m, oc,
                         preset_weights=True)


def layer_dict(s: OCSchedule) -> Dict:
    d = dataclasses.asdict(s)
    d["utilization"] = s.utilization
    return d
