"""Device-to-architecture power / latency / FPS-per-W simulator (paper Fig. 7).

The paper evaluates Lightator bottom-up: device (MR spectra) -> circuit
(CRC/VCSEL/driver/DAC power in 45nm) -> architecture (bank scheduling ->
execution time + power) -> application (accuracy). This module is the
architecture level: it consumes ``OCSchedule``s from ``core.optical_core``
and per-component circuit constants, and emits the quantities of Fig. 8
(layer-wise power breakdown), Fig. 9 (component pie), Fig. 10 (execution
time) and Table 1 (max power, kFPS/W).

Component model (who burns power in Lightator):
  DAC   - weight-tuning DACs. One per concurrently-mapped MR; power scales
          ~2^w_bits (current-steering DAC with per-bit power gating — the
          paper's stated source of the 2.4x saving when dropping bits and of
          the >85% DAC share in Fig. 9).
  TUN   - microheater holding power per active MR (mean detuning).
  DMVA  - CRC comparators + VCSEL + driver transistors (activation path).
          This replaces the ADC+DAC activation path of prior designs.
  BPD   - balanced photodetectors + TIA per arm.
  ADC   - 0 for Lightator (ADC-less); >0 for baseline profiles that read
          analog MAC results back to digital per output.
  MISC  - controller + weight/activation SRAM (Cacti-class constants).

Calibration: constants below are set so that VGG9/CIFAR on the 96-bank OC
lands at Table 1's operating points (5.28 / 2.71 / 1.46 W and O(100) kFPS/W).
They are *circuit-level inputs*, not fit per-experiment; every reported
number downstream is computed from schedules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

from repro.core.optical_core import OCConfig, DEFAULT_OC, OCSchedule
from repro.core.quant import WASpec, MixedPrecisionScheme, resolve_layer_specs


@dataclasses.dataclass(frozen=True)
class CircuitConstants:
    """45nm-class per-component constants (device/circuit layer outputs)."""

    # Weight path ------------------------------------------------------
    dac_unit_w: float = 70e-6        # per-MR DAC power at 1 effective bit-slice
    tun_per_mr_w: float = 4.2e-6     # mean microheater holding power per MR
    # Activation path (DMVA) -------------------------------------------
    crc_comparator_w: float = 0.8e-6   # per comparator (15 per CRC unit)
    vcsel_w: float = 1.9e-6            # per VCSEL (incl. bias)
    driver_w: float = 1.1e-6           # per driver stack (16 transistors)
    # Readout ----------------------------------------------------------
    bpd_w: float = 2.6e-6              # per BPD + TIA
    adc_w: float = 3.1e-3              # per ADC channel (baselines only)
    # Electronic misc ----------------------------------------------------
    summation_w: float = 0.9e-6        # per summation-tree adder
    sram_w_per_kb: float = 1.6e-6      # weight/act SRAM leakage+dynamic proxy
    controller_w: float = 8.0e-2       # sequencer/controller
    # Timing -------------------------------------------------------------
    cycle_hz: float = 20e9             # optical cycle rate (photodetection >100GHz)
    remap_cycles: int = 128            # DAC settle + SRAM fetch per weight remap


DEFAULT_CIRCUIT = CircuitConstants()


def dac_power_per_mr(w_bits: int, c: CircuitConstants = DEFAULT_CIRCUIT) -> float:
    """Current-steering DAC with power-gated bit slices: ~ 2^bits."""
    return c.dac_unit_w * (2 ** w_bits)


@dataclasses.dataclass(frozen=True)
class AcceleratorProfile:
    """What a design spends energy on. Lightator vs prior MR accelerators."""

    name: str
    act_in_mrs: bool = False     # activations tuned into MRs (needs DAC each)
    adc_readout: bool = False    # analog MAC results digitized by ADCs
    dac_weights: bool = True     # weights tuned via DACs
    process_nm: int = 45


LIGHTATOR_PROFILE = AcceleratorProfile("Lightator", act_in_mrs=False,
                                       adc_readout=False, dac_weights=True)
# Prior designs (Sec. 2): activation values also occupy MRs (tuning + DAC) and
# outputs go through ADCs.
CROSSLIGHT_PROFILE = AcceleratorProfile("CrossLight", act_in_mrs=True,
                                        adc_readout=True, process_nm=45)
LIGHTBULB_PROFILE = AcceleratorProfile("LightBulb", act_in_mrs=True,
                                       adc_readout=True, process_nm=32)
HOLYLIGHT_PROFILE = AcceleratorProfile("HolyLight", act_in_mrs=True,
                                       adc_readout=False, process_nm=32)
ROBIN_PROFILE = AcceleratorProfile("Robin", act_in_mrs=True,
                                   adc_readout=True, process_nm=45)


@dataclasses.dataclass
class LayerSchedule:
    """An OCSchedule + the [W:A] spec it runs under."""

    schedule: OCSchedule
    spec: WASpec


@dataclasses.dataclass
class LayerPower:
    name: str
    breakdown_w: Dict[str, float]
    cycles: int
    remap_cycles: int

    @property
    def total_w(self) -> float:
        return sum(self.breakdown_w.values())

    @property
    def time_s(self) -> float:
        return (self.cycles + self.remap_cycles) / DEFAULT_CIRCUIT.cycle_hz


@dataclasses.dataclass
class ModelReport:
    layers: List[LayerPower]
    max_power_w: float
    avg_power_w: float
    exec_time_s: float
    fps: float
    kfps_per_w: float
    # conv execution strategy per conv layer (resident vs strip-mined +
    # strip geometry), recorded by the compile pass (core.plan) and by the
    # eager interpreter so reports stay comparable field-for-field; empty
    # for schedule-only reports (PowerModel.model_report)
    conv_strategy: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    # fused megakernel segments (runs of conv steps executing as one
    # launch, kernels.dispatch.select_fused_segments) — recorded by both
    # the compile pass and the eager interpreter; empty when fusion is off
    # or for schedule-only reports
    fused_segments: List[Dict] = dataclasses.field(default_factory=list)
    # plan-verifier findings (repro.analysis, Options(verify=)): warning/
    # error Diagnostic dicts only — info-level findings (per-step headroom)
    # stay out so a clean model's report is [] on every path and the
    # eager/compiled report-identity contract is preserved
    verification: List[Dict] = dataclasses.field(default_factory=list)

    def component_totals(self) -> Dict[str, float]:
        """Time-weighted component powers across the model (Fig. 9 pie)."""
        acc: Dict[str, float] = {}
        t_total = sum(l.time_s for l in self.layers) or 1.0
        for l in self.layers:
            for k, v in l.breakdown_w.items():
                acc[k] = acc.get(k, 0.0) + v * l.time_s / t_total
        return acc


class PowerModel:
    """Architecture-level simulator: schedules -> power/latency/FPS/W."""

    def __init__(self, oc: OCConfig = DEFAULT_OC,
                 circuit: CircuitConstants = DEFAULT_CIRCUIT,
                 profile: AcceleratorProfile = LIGHTATOR_PROFILE,
                 weight_sram_kb: float = 512.0,
                 act_sram_kb: float = 256.0):
        self.oc = oc
        self.c = circuit
        self.profile = profile
        self.weight_sram_kb = weight_sram_kb
        self.act_sram_kb = act_sram_kb

    # -- per-layer -----------------------------------------------------
    def layer_power(self, ls: LayerSchedule) -> LayerPower:
        s, spec = ls.schedule, ls.spec
        c, oc, prof = self.c, self.oc, self.profile
        # MRs concurrently holding weights while this layer runs:
        mapped_mrs = min(s.mapped_mrs_avg, float(oc.total_mrs))
        arms_active = mapped_mrs / oc.mrs_per_arm
        # weight DACs: per concurrently-mapped MR (weights stay mapped, DACs
        # hold the tuning voltage). Pre-set CA banks need no DAC (kind=="ca").
        dac_w = 0.0
        if prof.dac_weights and s.kind != "ca":
            dac_w = mapped_mrs * dac_power_per_mr(spec.w_bits, c)
        if prof.act_in_mrs:
            # prior designs burn DAC + tuning for activations too, at a_bits
            dac_w += mapped_mrs * dac_power_per_mr(spec.a_bits, c)
        tun_w = mapped_mrs * c.tun_per_mr_w * (2 if prof.act_in_mrs else 1)
        # DMVA: one CRC+VCSEL+driver per wavelength channel in flight (the
        # activations of one input window, broadcast to all banks).
        dmva_w = 0.0
        if not prof.act_in_mrs:
            dmva_w = s.vcsel_channels * (c.crc_comparator_w * 15 / 16.0
                                         + c.vcsel_w + c.driver_w)
        bpd_w = arms_active * c.bpd_w
        adc_w = 0.0
        if prof.adc_readout:
            outputs_per_cycle = s.bpd_reads / max(s.cycles, 1)
            adc_w = outputs_per_cycle * c.adc_w
        sum_w = (s.summation_ops / max(s.cycles, 1)) * c.summation_w
        misc_w = (c.controller_w
                  + (self.weight_sram_kb + self.act_sram_kb) * c.sram_w_per_kb)
        breakdown = {"DAC": dac_w, "TUN": tun_w, "DMVA": dmva_w,
                     "BPD": bpd_w, "ADC": adc_w,
                     "MISC": misc_w + sum_w}
        return LayerPower(s.name, breakdown, s.cycles,
                          s.weight_remaps * c.remap_cycles)

    # -- whole model -----------------------------------------------------
    def model_report(self, layers: Sequence[OCSchedule],
                     scheme: WASpec | MixedPrecisionScheme) -> ModelReport:
        """Whole-model report.

        Lightator-MX co-mapping model: the first layer's weight banks stay
        resident at [4:*] for the whole frame (the first layer runs on every
        frame, so re-tuning it is wasted DAC settle time); later layers map
        into the remaining capacity. That costs (i) a constant first-layer
        DAC/TUN power rail under all layers and (ii) a capacity reduction
        (more remap rounds) for the rest — reproducing the paper's
        observation that MX sits between the pure configurations in both
        power and kFPS/W.
        """
        specs = resolve_layer_specs(len(layers), scheme)
        lps = [self.layer_power(LayerSchedule(s, sp))
               for s, sp in zip(layers, specs)]
        return self.finalize_report(lps, layers, scheme)

    def finalize_report(self, lps: List["LayerPower"],
                        layers: Sequence[OCSchedule],
                        scheme: WASpec | MixedPrecisionScheme) -> ModelReport:
        if isinstance(scheme, MixedPrecisionScheme) and len(lps) > 1:
            first_compute = next((i for i, s in enumerate(layers)
                                  if s.kind != "ca"), None)
            if first_compute is not None:
                s1 = layers[first_compute]
                m1 = min(s1.mapped_mrs_avg, float(self.oc.total_mrs))
                rail_dac = m1 * dac_power_per_mr(scheme.first.w_bits, self.c)
                rail_tun = m1 * self.c.tun_per_mr_w
                cap = max(1.0 - m1 / self.oc.total_mrs, 1e-3)
                for i, l in enumerate(lps):
                    if i <= first_compute:
                        continue
                    l.breakdown_w["DAC"] += rail_dac
                    l.breakdown_w["TUN"] += rail_tun
                    l.cycles = int(math.ceil(l.cycles / cap))
                    l.remap_cycles = int(math.ceil(l.remap_cycles / cap))
        t = sum(l.time_s for l in lps)
        max_p = max(l.total_w for l in lps)
        avg_p = sum(l.total_w * l.time_s for l in lps) / t if t else 0.0
        fps = 1.0 / t if t else 0.0
        kfps_w = fps / avg_p / 1e3 if avg_p else 0.0
        return ModelReport(lps, max_p, avg_p, t, fps, kfps_w)
