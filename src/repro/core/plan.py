"""Static compile pass + jitted execute pass for the Lightator device.

The seed ``LightatorDevice.run`` was an eager per-frame Python interpreter:
every call re-resolved [W:A] specs, rebuilt OC schedules, re-ran the power
model, and dispatched each layer's math as separate un-jitted XLA calls.
All of that scheduling work is data-independent — it depends only on the
layer IR, the [W:A] scheme, and the input shape. This module splits it out:

  compile_model(layers, input_shape, scheme, ...) -> CompiledPlan
      Runs shape inference over the IR once, resolves per-layer ``WASpec``s,
      builds every ``OCSchedule`` and the full power/latency ``ModelReport``,
      and precomputes the static geometry (conv pads, strides, output dims)
      the execute pass needs. Plans are cached on
      ``(layers, input_shape, scheme, oc, circuit, profile, sram)`` so a
      serving loop compiles exactly once per model/shape.

  execute(plan, params, frames) -> logits
      A pure function of (params, frames), jitted once per plan, batch-first.
      It reproduces the eager interpreter's integer-exact quantized numerics
      bit-for-bit, but routes the MAC work through the kernel dispatch layer
      (``kernels.dispatch``): on the pallas backend convs go via im2col into
      the photonic MVM kernel and the CA through the fused ca_pool kernel;
      the reference backend uses the integer-exact jnp/lax oracles (convs
      stay ``conv_general_dilated`` — no patch materialization on large
      frames). Because the OC accumulate is exact integer arithmetic on
      both backends, conv/dense routing cannot change the logits; with the
      dequant/activation/requant expressions kept textually identical to
      preserve float associativity, the compiled path is bit-identical to
      the seed eager path. One carve-out: the CA stage is *float* math, and
      the fused ca_pool kernel's summation order differs from the reference
      einsum by ~1 ulp — so on the pallas backend, CA-bearing models are
      bit-identical only up to CRC requant absorbing that ulp (models
      without a CASpec, like LeNet, stay exactly bit-identical on every
      backend; everything is exact on the reference backend).

``LightatorDevice.run`` is now a thin compatibility wrapper over these two
passes; ``launch.serve_vision`` streams frame batches through a compiled
plan and reports measured frames/s next to the model's simulated FPS/W.

The public front door over both passes is ``repro.core.program``:
``Program.compile(Options) -> Executable`` — ``compile_model`` / ``execute``
remain as deprecated bit-identical shims (see docs/api.md).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import optical_core as ocore
from repro.core import power_model as pmod
from repro.core.quant import (ACT_BITS, WASpec, MixedPrecisionScheme,
                              resolve_layer_specs)
from repro.kernels import dispatch


# ---------------------------------------------------------------------------
# Bit-identity helpers
#
# The eager interpreter runs op-by-op: every scalar literal is staged as an
# executable *parameter* and every mul/add is its own XLA computation. Under
# one fused jax.jit, XLA inlines literals (rewriting x/15 into x * (1/15),
# off by 1 ULP) and LLVM contracts mul+add chains into FMAs. Both break
# bit-identity with the eager reference, and neither optimization_barrier
# nor the XLA fast-math flags stop them. So:
#
#   * quantization divisors (CRC a_qmax, MR w_qmax) are passed into the
#     jitted executor as *traced* scalars — divisions by a parameter are
#     never rewritten, exactly like the eager path's weak-typed literals;
#   * `_nofma` (nextafter(x, x), an exact identity XLA expands to integer
#     bit-ops) is inserted between the dequant multiply and the bias add,
#     so LLVM never sees a contractible fmul->fadd edge.
# ---------------------------------------------------------------------------

def _nofma(x: jnp.ndarray) -> jnp.ndarray:
    """Exact identity that blocks FMA contraction of producer*... + b."""
    return jnp.nextafter(x, x)


def _crc_requant_traced(x: jnp.ndarray, a_qmax: jnp.ndarray,
                        per_frame: bool = False):
    """`accelerator._crc_requant` with the divisor as a traced scalar.

    ``per_frame=False`` is the seed semantics: ONE scale from a max over the
    whole tensor, batch axis included — a frame's codes depend on the other
    frames in its batch. ``per_frame=True`` reduces the max over each
    frame's own axes instead (scale shape [B, 1, ...]), the hardware's
    frame-per-pass calibration: every frame's numerics become independent
    of batch composition, which is what lets the serving micro-batcher
    coalesce and pad requests without perturbing anyone's results. At
    batch 1 the two modes are the same reduction — bit-identical.
    """
    x = jnp.maximum(x, 0.0)
    if per_frame:
        axes = tuple(range(1, x.ndim))
        amax = jnp.max(x, axis=axes, keepdims=True)
    else:
        amax = jnp.max(x)
    scale = jnp.maximum(amax, 1e-8) / a_qmax
    codes = jnp.clip(jnp.round(x / scale), 0, (1 << ACT_BITS) - 1)
    return codes, scale


def _quantize_weight_traced(w: jnp.ndarray, spec: WASpec,
                            w_qmax: jnp.ndarray):
    """`quant.quantize_weight(axis=-1)` with the divisor as a traced scalar."""
    reduce_axes = tuple(range(w.ndim - 1))
    if spec.per_channel:
        amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    s = jnp.maximum(amax, 1e-8) / w_qmax
    q = jnp.clip(jnp.round(w / s), -spec.w_qmax, spec.w_qmax).astype(jnp.int8)
    return q, s


# ---------------------------------------------------------------------------
# Shape inference helpers (shared with models.vision.vision_schedules)
# ---------------------------------------------------------------------------

def conv_out_hw(hw: int, kernel: int, stride: int, padding: str) -> int:
    """Spatial output size of a conv, matching XLA's SAME/VALID semantics."""
    if padding == "VALID":
        return (hw - kernel) // stride + 1
    return -(-hw // stride)                      # SAME: ceil(hw / stride)


# ---------------------------------------------------------------------------
# Plan steps: the IR annotated with everything shape-derived
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CAStep:
    pool: int
    rgb_to_gray: bool


@dataclasses.dataclass(frozen=True)
class ConvStep:
    name: str
    wa: WASpec
    kernel: int
    stride: int
    act: str
    pool: Optional[Tuple[str, int]]
    pads: Tuple[Tuple[int, int], Tuple[int, int]]   # ((lo,hi) per spatial dim)
    groups: int = 1                 # feature groups (c_in for depthwise)
    # conv execution strategy (resident vs strip-mined + strip geometry),
    # resolved once at compile time from the layer's output dims and the
    # REPRO_CONV_STRATEGY / VMEM-budget environment (kernels.dispatch)
    strategy: Optional[dispatch.ConvStrategy] = None
    # static chain geometry for the megakernel fusion pass — input dims,
    # pads, act/pool; what select_fused_segments and conv_chain consume
    geom: Optional[dispatch.ChainGeom] = None


@dataclasses.dataclass(frozen=True)
class UpsampleStep:
    factor: int
    method: str                     # "bilinear" | "nearest"


@dataclasses.dataclass(frozen=True)
class FlattenStep:
    pass


@dataclasses.dataclass(frozen=True)
class DenseStep:
    name: str
    wa: WASpec
    act: str


PlanStep = CAStep | ConvStep | UpsampleStep | FlattenStep | DenseStep


@dataclasses.dataclass(eq=False)
class CompiledPlan:
    """Everything ``execute`` needs, resolved once from shapes.

    ``report`` is the architecture-level power/latency/FPS-per-W report for
    one ``frame_shape`` frame — identical to what the eager interpreter
    recomputed on every call. A plan is batch-agnostic: ``execute`` accepts
    any leading batch dimension (each shape jit-compiles once).

    Calibration caveat (inherited from the eager reference, preserved for
    bit-identity): the CRC requant scale is a per-*tensor* max, reduced over
    the batch axis too, so a frame's logits depend on the other frames in
    its batch — serving the same frame at batch 1 vs batch 8 can classify
    differently. Per-frame accuracy numbers should be measured at the batch
    size they will be served at (or batch 1 for the hardware's per-frame
    semantics).
    """

    layers: tuple
    frame_shape: Tuple[int, int, int]         # per-frame [H, W, C]
    scheme: WASpec | MixedPrecisionScheme
    steps: Tuple[PlanStep, ...]
    schedules: Tuple[ocore.OCSchedule, ...]
    layer_specs: Tuple[WASpec, ...]
    report: pmod.ModelReport
    out_features: int
    consts: Dict[str, object] = dataclasses.field(default_factory=dict)
    # fused megakernel segments (runs of conv steps executing as one
    # launch each, see kernels.dispatch.select_fused_segments); resolved
    # at compile time, applied by the executor when calibration allows
    # (per-frame, or per-tensor at batch 1)
    fused_segments: Tuple[dispatch.FusedSegmentSpec, ...] = ()
    _exec_fns: Dict[str, object] = dataclasses.field(default_factory=dict,
                                                     repr=False)
    # has the analysis verifier run over this plan? (verify="auto" runs it
    # on first compile; "on" also re-checks cache hits — see _compile_model)
    _verified: bool = dataclasses.field(default=False, repr=False)

    def executor(self, per_frame: bool = False, donate: bool = False):
        """The jitted (params, frames) -> logits function for this plan.

        Keyed by the active kernel backend AND the Pallas interpret flag:
        both are baked in at trace time, so switching either (set_backend /
        REPRO_KERNEL_BACKEND / REPRO_FORCE_INTERPRET) gets its own jitted
        executable instead of silently reusing the old trace.

        ``per_frame`` keys a third trace family: the per-frame-calibrated
        executor (CRC requant scales reduced per frame, not per tensor)
        that the serving micro-batcher runs — see ``_crc_requant_traced``.

        ``donate`` keys a fourth: the frames argument's device buffer is
        donated to the computation, so XLA may reuse it instead of holding
        input and output live together — the serving device pool's
        host-memory pass. Only safe when the caller owns the frames array
        and never touches it again (a device-bound ``Executable`` stages
        its own input buffers, so it qualifies; the general ``run`` path
        must not, since callers may reuse what they passed).
        """
        key = (dispatch.get_backend(), dispatch.default_interpret(), per_frame,
               donate)
        fn = self._exec_fns.get(key)
        if fn is None:
            fn = jax.jit(
                lambda params, frames, consts: _execute_steps(
                    self.steps, params, frames, consts, per_frame=per_frame,
                    segments=self.fused_segments),
                donate_argnums=(1,) if donate else ())
            self._exec_fns[key] = fn
        return fn


# ---------------------------------------------------------------------------
# Compile pass
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[tuple, CompiledPlan] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def plan_cache_stats() -> Dict[str, int]:
    return dict(_CACHE_STATS)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


def _compile_model(layers: Sequence, input_shape: Tuple[int, ...],
                   scheme: WASpec | MixedPrecisionScheme,
                   oc: ocore.OCConfig = ocore.DEFAULT_OC,
                   circuit: pmod.CircuitConstants = pmod.DEFAULT_CIRCUIT,
                   profile: pmod.AcceleratorProfile = pmod.LIGHTATOR_PROFILE,
                   weight_sram_kb: float = 512.0,
                   act_sram_kb: float = 256.0,
                   fc_batch: int = 1,
                   conv_strategy: Optional[str] = None,
                   conv_vmem_budget: Optional[int] = None,
                   fuse: Optional[str] = None,
                   verify: Optional[str] = None) -> CompiledPlan:
    """Resolve specs, shapes, OC schedules and the power report — once.

    ``input_shape`` is the frame shape, batched [B, H, W, C] or per-frame
    [H, W, C]. The schedule / report describe one frame and the plan is
    batch-agnostic (the device processes a frame per pass; the batch
    dimension only feeds the jitted execute pass), so plans are cached on
    the per-frame dims: streaming a ragged final batch or sweeping batch
    sizes reuses the same ``CompiledPlan`` object — and its jitted
    executors — without re-scheduling.

    ``fc_batch`` schedules FC layers at the served batch size: one weight
    mapping round streams ``fc_batch`` input vectors before remapping, so
    the DAC-settle remap cycles amortize across the batch. The report stays
    *per-frame* (FC cycles and remap cycles are divided back by
    ``fc_batch``); only the amortized terms change — per-cycle power
    breakdowns are scale-invariant in the batch. The default (1) is the
    seed's per-frame semantics, bit-identical to ``run_eager`` reports.

    ``conv_strategy`` / ``conv_vmem_budget`` pin the conv execution
    strategy explicitly (what ``repro.Options`` passes down); ``None``
    defers to the ``REPRO_CONV_STRATEGY`` / ``REPRO_CONV_VMEM_BUDGET`` env
    defaults. The cache key holds the *resolved* values, so an explicit
    option equal to the ambient env default hits the same cached plan.

    ``fuse`` pins the megakernel chain-fusion mode ("auto" | "on" | "off",
    what ``Options(fuse=...)`` passes down); ``None`` derives it from the
    resolved conv strategy mode (``dispatch.conv_fuse_mode``: forced
    resident/strip disable fusion, ``fused`` forces it on).

    ``verify`` pins the plan-verifier mode ("auto" | "on" | "off", what
    ``Options(verify=...)`` passes down; ``None`` defers to
    ``REPRO_VERIFY``, default "auto"). "auto" runs ``repro.analysis.
    verify_plan`` on every cache-miss compile and raises
    :class:`~repro.analysis.PlanVerificationError` at error severity
    (the plan is NOT cached — a later verify="off" compile starts
    clean); "on" additionally re-checks cache hits, so a plan first
    compiled under "off" still gets proved before use; "off" skips.
    Warning/error findings land in ``report.verification``. Like
    ``trace``, the mode stays OUT of the cache key: verification never
    changes what gets compiled, so verified and unverified callers
    share the same cached plan.
    """
    from repro.core.accelerator import (CASpec, ConvSpec, DenseSpec,
                                        FlattenSpec, UpsampleSpec)
    if fc_batch < 1:
        raise ValueError(f"fc_batch must be >= 1, got {fc_batch}")
    layers = tuple(layers)
    frame_shape = tuple(int(d) for d in input_shape[-3:])
    if len(frame_shape) != 3:
        raise ValueError(f"input_shape {input_shape} must be [B,H,W,C] or "
                         f"[H,W,C]")
    conv_mode = (conv_strategy if conv_strategy is not None
                 else dispatch.conv_strategy_mode())
    conv_budget = (conv_vmem_budget if conv_vmem_budget is not None
                   else dispatch.conv_vmem_budget())
    fuse_mode = fuse if fuse is not None else dispatch.conv_fuse_mode(conv_mode)
    from repro.analysis import verifier as _verifier
    verify_mode = verify if verify is not None else _verifier.verify_mode()
    if verify_mode not in _verifier.VERIFY_MODES:
        raise ValueError(f"unknown verify mode {verify_mode!r}; expected "
                         f"one of {_verifier.VERIFY_MODES}")
    key = (layers, frame_shape, scheme, oc, circuit, profile,
           weight_sram_kb, act_sram_kb, fc_batch,
           (conv_mode, conv_budget, fuse_mode))
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _CACHE_STATS["hits"] += 1
        obs.counter("plan.cache.hit").inc()
        if obs.enabled():
            obs.event("plan.cache.hit",
                      attrs={"frame_shape": list(frame_shape),
                             "layers": len(layers)})
        if verify_mode == "on":
            # a hit may predate verification (first compiled under "off")
            _verify_plan(cached, conv_budget)
        return cached
    _CACHE_STATS["misses"] += 1
    obs.counter("plan.cache.miss").inc()
    with obs.span("plan.compile",
                  attrs={"frame_shape": list(frame_shape),
                         "layers": len(layers), "fc_batch": fc_batch,
                         "conv_strategy": conv_mode, "fuse": fuse_mode}):
        plan = _compile_model_uncached(
            layers, frame_shape, scheme, oc, circuit, profile,
            weight_sram_kb, act_sram_kb, fc_batch, conv_mode, conv_budget,
            fuse_mode)
    if verify_mode != "off":
        # verify BEFORE caching: a plan that fails at error severity is
        # never published, so a later verify="off" compile starts clean
        _verify_plan(plan, conv_budget)
    _PLAN_CACHE[key] = plan
    return plan


def _verify_plan(plan: CompiledPlan, budget: int) -> None:
    """Run the analysis verifier over ``plan`` once (idempotent).

    Warning/error findings are stored in ``plan.report.verification``
    (info-level headroom facts stay out — see ModelReport); error
    severity raises :class:`repro.analysis.PlanVerificationError`. A plan
    already verified re-raises from its stored findings instead of
    re-walking.
    """
    from repro import analysis
    if plan._verified:
        stored = plan.report.verification
        if any(d["severity"] == "error" for d in stored):
            raise analysis.PlanVerificationError(
                [analysis.Diagnostic(**d) for d in stored])
        return
    with obs.span("plan.verify", attrs={"layers": len(plan.layers)}):
        # info (the headroom report) never reaches ModelReport, so the
        # compile path skips constructing it (scripts/verify_plan.py asks
        # for it explicitly)
        diags = analysis.verify_plan(plan, budget=budget,
                                     include_info=False)
    plan.report.verification = [d.asdict() for d in diags
                                if d.severity != "info"]
    plan._verified = True
    obs.counter("plan.verify.run").inc()
    if analysis.errors(diags):
        obs.counter("plan.verify.error").inc()
        raise analysis.PlanVerificationError(diags)


def _compile_model_uncached(layers, frame_shape, scheme, oc, circuit,
                            profile, weight_sram_kb, act_sram_kb, fc_batch,
                            conv_mode, conv_budget,
                            fuse_mode) -> CompiledPlan:
    """The cache-miss body of :func:`_compile_model` (span-wrapped)."""
    from repro.core.accelerator import (CASpec, ConvSpec, DenseSpec,
                                        FlattenSpec, UpsampleSpec)
    compute_layers = [l for l in layers if isinstance(l, (ConvSpec, DenseSpec))]
    specs = resolve_layer_specs(len(compute_layers), scheme)
    spec_iter = iter(specs)

    steps: List[PlanStep] = []
    schedules: List[ocore.OCSchedule] = []
    spec_list: List[WASpec] = []

    h, w, c = frame_shape
    out_features = 0
    for layer in layers:
        if isinstance(layer, CASpec):
            if h % layer.pool or w % layer.pool:
                raise ValueError(
                    f"CA pool={layer.pool} does not divide frame "
                    f"{h}x{w}")
            h, w = h // layer.pool, w // layer.pool
            # fused RGB->gray collapses channels; per-channel pooling keeps c
            rgb = layer.rgb_to_gray if layer.rgb_to_gray is not None else (c == 3)
            c_out = 1 if (rgb or c == 1) else c
            schedules.append(ocore.schedule_ca(
                "CA", h, w, layer.pool, channels=frame_shape[-1], oc=oc))
            spec_list.append(WASpec(4, 4))
            steps.append(CAStep(layer.pool, rgb))
            c = c_out
        elif isinstance(layer, ConvSpec):
            wa = next(spec_iter)
            if layer.depthwise and layer.c_out != layer.c_in:
                raise ValueError(
                    f"{layer.name}: depthwise conv needs c_out == c_in "
                    f"(got {layer.c_in} -> {layer.c_out})")
            pads = jax.lax.padtype_to_pads(
                (h, w), (layer.kernel, layer.kernel),
                (layer.stride, layer.stride), layer.padding)
            pads = tuple((int(lo), int(hi)) for lo, hi in pads)
            h_out = conv_out_hw(h, layer.kernel, layer.stride, layer.padding)
            w_out = conv_out_hw(w, layer.kernel, layer.stride, layer.padding)
            # resident vs strip-mined, from the conv's own (pre-pool) output
            # dims — part of the plan AND the power report (serving surfaces)
            strat = dispatch.select_conv_strategy(
                h_out, w_out, layer.c_in, layer.c_out, layer.kernel,
                layer.stride, groups=layer.c_in if layer.depthwise else 1,
                mode=conv_mode, budget=conv_budget)
            geom = dispatch.ChainGeom(
                layer.name, h, w, layer.c_in, layer.c_out, layer.kernel,
                layer.stride, pads,
                groups=layer.c_in if layer.depthwise else 1,
                act=layer.act, pool=layer.pool)
            h, w, c = h_out, w_out, layer.c_out
            if layer.pool is not None:
                kind, size = layer.pool
                if h % size or w % size:
                    raise ValueError(
                        f"{layer.name}: {kind}-pool size={size} does not "
                        f"divide its {h}x{w} conv output (the eager path "
                        f"fails the same way, at reshape time)")
                h, w = h // size, w // size
                if kind == "avg":
                    # avg pooling runs on CA banks with pre-set weights —
                    # scheduled before the conv, as the eager interpreter did
                    schedules.append(ocore.schedule_ca(
                        f"{layer.name}.pool", h, w, size, channels=1, oc=oc))
                    spec_list.append(WASpec(4, 4))
            # NB: the eager interpreter scheduled the conv with its
            # *post-pool* output dims (it read y.shape after pooling);
            # reproduced here so reports stay bit-identical.
            # Depthwise: each output channel sees 1 input channel (k*k taps
            # per stride, c_out independent kernels).
            sched_c_in = 1 if layer.depthwise else layer.c_in
            schedules.append(ocore.schedule_conv(
                layer.name, h, w, sched_c_in, layer.c_out, layer.kernel,
                oc=oc))
            spec_list.append(wa)
            steps.append(ConvStep(layer.name, wa, layer.kernel, layer.stride,
                                  layer.act, layer.pool, pads,
                                  groups=layer.c_in if layer.depthwise else 1,
                                  strategy=strat, geom=geom))
        elif isinstance(layer, UpsampleSpec):
            if layer.method not in ("bilinear", "nearest"):
                raise ValueError(f"unknown upsample method {layer.method!r}")
            h, w = h * layer.factor, w * layer.factor
            # preset interpolation banks: weighted sums of <= 4 neighbours,
            # scheduled like the CA (no DACs, no remap rounds). Windows =
            # output pixels x channels (each channel interpolates
            # independently); name indexed so stacked upsamples stay distinct.
            taps = 2 if layer.method == "bilinear" else 1
            schedules.append(ocore.schedule_ca(
                f"upsample.{len(steps)}", h, w * c, taps, channels=1, oc=oc))
            spec_list.append(WASpec(4, 4))
            steps.append(UpsampleStep(layer.factor, layer.method))
        elif isinstance(layer, FlattenSpec):
            h, w, c = 1, 1, h * w * c
            steps.append(FlattenStep())
        elif isinstance(layer, DenseSpec):
            wa = next(spec_iter)
            schedules.append(ocore.schedule_fc(
                layer.name, layer.fan_in, layer.fan_out, batch=fc_batch,
                oc=oc))
            spec_list.append(wa)
            steps.append(DenseStep(layer.name, wa, layer.act))
            c = layer.fan_out
            out_features = layer.fan_out
        else:
            raise TypeError(f"unknown layer IR {layer!r}")

    power = pmod.PowerModel(oc, circuit, profile, weight_sram_kb, act_sram_kb)
    lps = []
    for s, sp in zip(schedules, spec_list):
        lp = power.layer_power(pmod.LayerSchedule(s, sp))
        if fc_batch > 1 and s.kind == "fc":
            # back to per-frame terms: one mapping round streamed fc_batch
            # input vectors, so the streaming cycles divide exactly and the
            # remap (DAC settle) cycles amortize. Per-cycle power rates are
            # batch-invariant, so the breakdown is untouched.
            lp.cycles = -(-lp.cycles // fc_batch)
            lp.remap_cycles = -(-lp.remap_cycles // fc_batch)
        lps.append(lp)
    report = power.finalize_report(lps, schedules, scheme)
    report.conv_strategy = {
        s.name: dataclasses.asdict(s.strategy) for s in steps
        if isinstance(s, ConvStep)}
    fused_segments = dispatch.select_fused_segments(
        [s.geom if isinstance(s, ConvStep) else None for s in steps],
        mode=fuse_mode, budget=conv_budget)
    report.fused_segments = [dataclasses.asdict(f) for f in fused_segments]

    # quantization divisors, fed to the executor as traced scalars (see the
    # bit-identity note at the top of this module)
    consts = {
        "a_qmax": np.float32((1 << ACT_BITS) - 1),
        "w_qmax": {s.name: np.float32(s.wa.w_qmax) for s in steps
                   if isinstance(s, (ConvStep, DenseStep))},
    }

    return CompiledPlan(layers, frame_shape, scheme, tuple(steps),
                        tuple(schedules), tuple(spec_list), report,
                        out_features or c, consts,
                        fused_segments=fused_segments)


# ---------------------------------------------------------------------------
# Execute pass (pure, jitted once per plan)
# ---------------------------------------------------------------------------

def _execute_steps(steps: Tuple[PlanStep, ...], params: Dict[str, Dict],
                   frames: jnp.ndarray, consts: Dict[str, object],
                   per_frame: bool = False,
                   segments: Tuple[dispatch.FusedSegmentSpec, ...] = ()
                   ) -> jnp.ndarray:
    """The device forward, batch-first, kernels via ``kernels.dispatch``.

    Numerics contract: bit-identical to ``LightatorDevice.run_eager`` (on
    the pallas backend, for CA-bearing models, up to the ca_pool float
    summation-order ulp — see the module docstring). The MAC accumulates
    are exact integers (so conv/dense kernel routing cannot change them);
    every dequant/activation/requant expression keeps the eager path's
    association order, with traced divisors + ``_nofma`` guards
    neutralizing the jit-only rewrites (see module-top note).

    ``per_frame`` switches every CRC requant to per-frame calibration
    (scale shape [B, 1, ...] instead of a batch-shared scalar): each
    frame's result becomes a pure function of that frame alone — the
    invariant the serving micro-batcher's pad/coalesce soundness rests on.
    Everything between requants is already per-frame independent (the MAC
    accumulates are exact integers, the dequant/activation chain is
    elementwise), so a frame served at any batch position is bit-identical
    to the same frame run at batch 1.

    ``segments`` are the plan's fused megakernel runs: when a run's start
    index comes up, its conv steps execute as ONE launch via
    ``dispatch.conv_chain`` (tap-loop accumulate + full fused epilogue
    per stage), bit-identical to the step-by-step path. The inter-stage
    CRC scale is a whole-frame reduction, so fusion applies only when
    frames are calibration-independent — per-frame mode, or per-tensor at
    batch 1 (the batch is static under jit, so this is a trace-time
    fallback, not a runtime branch).
    """
    from repro.core.accelerator import _activation

    a_qmax = consts["a_qmax"]
    codes, act_scale = _crc_requant_traced(frames, a_qmax, per_frame)
    x = codes
    fuse_ok = per_frame or frames.shape[0] == 1
    if segments and not fuse_ok:
        # per-tensor calibration at batch > 1 couples frames through the
        # batch-wide CRC max: the fused segments cannot run, and this
        # whole trace falls back to the per-layer path (trace-time event —
        # the jitted executable re-runs it for free afterwards)
        obs.counter("dispatch.fused.fallback").inc(len(segments))
        if obs.enabled():
            obs.event("dispatch.fused.fallback",
                      attrs={"segments": len(segments),
                             "batch": int(frames.shape[0])})
    seg_at = {s.start: s for s in segments} if fuse_ok else {}
    # NB: the spans below run at jit-TRACE time (this function executes
    # once per (backend, shape, calibration) trace family) — they profile
    # trace priming, one of serving's cold-start costs, not steady-state
    # device time (that is serve.batch.* territory).
    i, n = 0, len(steps)
    while i < n:
        step = steps[i]
        seg = seg_at.get(i)
        if seg is not None:
            with obs.span("plan.trace.fused_segment",
                          attrs={"names": list(seg.names)}):
                stages = []
                for s in steps[i:i + seg.length]:
                    p = params[s.name]
                    wq, ws = _quantize_weight_traced(
                        p["w"], s.wa, consts["w_qmax"][s.name])
                    stages.append((s.geom, wq, ws, p.get("b")))
                x, act_scale = dispatch.conv_chain(x, act_scale, stages,
                                                   a_qmax, per_frame)
            i += seg.length
            continue
        if isinstance(step, CAStep):
            intens = x * act_scale
            g = dispatch.ca_acquire(intens, step.pool, step.rgb_to_gray)
            if g.ndim == 3:
                g = g[..., None]
            x, act_scale = _crc_requant_traced(g, a_qmax, per_frame)
        elif isinstance(step, ConvStep):
            p = params[step.name]
            wq, ws = _quantize_weight_traced(p["w"], step.wa,
                                             consts["w_qmax"][step.name])
            acc = dispatch.conv_int(x, wq, step.stride, step.pads,
                                    groups=step.groups,
                                    strategy=step.strategy)
            out = acc * (act_scale * ws.reshape(1, 1, 1, -1))
            if p.get("b") is not None:
                out = _nofma(out) + p["b"]
            y = _activation(out, step.act)
            if step.pool is not None:
                kind, size = step.pool
                b_, h_, w_, c_ = y.shape
                yr = y.reshape(b_, h_ // size, size, w_ // size, size, c_)
                y = yr.max(axis=(2, 4)) if kind == "max" else yr.mean(axis=(2, 4))
            x, act_scale = _crc_requant_traced(y, a_qmax, per_frame)
        elif isinstance(step, UpsampleStep):
            from repro.core.compressive import upsample_reconstruct
            intens = x * act_scale
            up = upsample_reconstruct(intens, step.factor, step.method)
            x, act_scale = _crc_requant_traced(up, a_qmax, per_frame)
        elif isinstance(step, FlattenStep):
            intens = x * act_scale
            flat = intens.reshape(intens.shape[0], -1)
            x, act_scale = _crc_requant_traced(flat, a_qmax, per_frame)
        elif isinstance(step, DenseStep):
            p = params[step.name]
            wq, ws = _quantize_weight_traced(p["w"], step.wa,
                                             consts["w_qmax"][step.name])
            acc = dispatch.matmul_int(x, wq)
            out = acc * (act_scale * ws.reshape(1, -1))
            if p.get("b") is not None:
                out = _nofma(out) + p["b"]
            if step.act != "none":
                y = _activation(out, step.act)
                x, act_scale = _crc_requant_traced(y, a_qmax, per_frame)
            else:
                x, act_scale = out, jnp.asarray(1.0)
        else:
            raise TypeError(f"unknown plan step {step!r}")
        i += 1
    # dequantize the final stage (act_scale is 1.0 after a no-act dense, a
    # scalar per-tensor scale, or a [B, 1, ...] per-frame scale — all
    # broadcast-exact, and the per-tensor multiply is the seed expression)
    return x * act_scale


def _execute(plan: CompiledPlan, params: Dict[str, Dict],
             frames: jnp.ndarray, per_frame: bool = False,
             donate: bool = False) -> jnp.ndarray:
    """Run ``frames`` [B, H, W, C] through a compiled plan.

    Returns logits [B, n] for classifier plans, or an image [B, H', W', C']
    for plans whose last step is spatial (the ``repro.imaging`` pipelines) —
    the dequantized intensities of the final CRC stage.

    ``per_frame`` selects the per-frame-calibrated executor (the serving
    micro-batcher's batch-composition-independent semantics — see
    ``_crc_requant_traced``); the default is the seed's per-tensor
    calibration.

    The underlying function is jitted once per plan; repeated calls with the
    same frame shape reuse the XLA executable (no re-tracing, no
    re-scheduling — the schedules live on the plan).
    """
    if frames.ndim == 3:                       # single frame [H, W, C]
        frames = frames[None]
    if frames.ndim != 4 or tuple(frames.shape[1:]) != plan.frame_shape:
        raise ValueError(f"frames {frames.shape} do not match plan frame "
                         f"shape {plan.frame_shape}; expected "
                         f"[B, {', '.join(map(str, plan.frame_shape))}]")
    return plan.executor(per_frame, donate)(params, frames, plan.consts)


# ---------------------------------------------------------------------------
# Back-compat shims
#
# ``core.program`` (Program / Options / Executable) is the public front door;
# these keep the PR-1 function API working, bit-identical (they call the very
# same internals the new API calls), with a one-shot DeprecationWarning.
# ---------------------------------------------------------------------------

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(old: str, replacement: str,
                     doc: str = "docs/api.md") -> None:
    """One-shot-per-process DeprecationWarning (the shared shim helper —
    ``launch.serve`` reuses it with its own ``doc``)."""
    if old in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(old)
    warnings.warn(f"{old} is deprecated; use {replacement} "
                  f"(see {doc})", DeprecationWarning, stacklevel=3)


def compile_model(layers: Sequence, input_shape: Tuple[int, ...],
                  scheme: WASpec | MixedPrecisionScheme,
                  oc: ocore.OCConfig = ocore.DEFAULT_OC,
                  circuit: pmod.CircuitConstants = pmod.DEFAULT_CIRCUIT,
                  profile: pmod.AcceleratorProfile = pmod.LIGHTATOR_PROFILE,
                  weight_sram_kb: float = 512.0,
                  act_sram_kb: float = 256.0,
                  fc_batch: int = 1) -> CompiledPlan:
    """Deprecated shim over the compile pass — use ``repro.Program``.

    ``Program(layers, params, input_hwc).compile(Options(scheme=...))``
    resolves the same cached plan; this wrapper keeps the full PR-1
    signature (positional calls included) for existing callers and is
    regression-tested bit-identical to the new path.
    """
    _warn_deprecated(
        "core.plan.compile_model",
        "repro.Program(...).compile(repro.Options(scheme=...))")
    return _compile_model(layers, input_shape, scheme, oc=oc,
                          circuit=circuit, profile=profile,
                          weight_sram_kb=weight_sram_kb,
                          act_sram_kb=act_sram_kb, fc_batch=fc_batch)


def execute(plan: CompiledPlan, params: Dict[str, Dict],
            frames: jnp.ndarray) -> jnp.ndarray:
    """Deprecated shim over the execute pass — use ``Executable.run``."""
    _warn_deprecated("core.plan.execute",
                     "repro.Program(...).compile(...).run(frames)")
    return _execute(plan, params, frames)
