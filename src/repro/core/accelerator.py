"""LightatorDevice — the paper's "custom in-house simulator" (Sec. 5).

The device models a vision model the way the hardware runs it:

  step 1  frame captured; CRC quantizes pixels to uint4 (ADC-less imager)
  step 2  optional Compressive Acquisitor (fused RGB->gray + pooling)
  step 3  All-in-One Convolver runs the layer's MACs on the OC banks
  step 4  electronic activation (Sign/ReLU/tanh) + CRC requantization feeds
          the DMVA for the next layer (activation banks eliminated)
  step 5  repeat 3<->4 until the classifier output

Execution is split into two passes (``core.plan``):

  * **compile** — ``plan.compile_model`` resolves per-layer [W:A] specs, OC
    schedules, and the power/latency report once from shapes, and caches the
    resulting ``CompiledPlan`` per (layers, scheme, input shape, hardware).
  * **execute** — ``plan.execute`` runs the integer-exact quantized numerics
    end-to-end under a single ``jax.jit``, batch-first, with the MAC work
    routed through the Pallas kernels via ``kernels.dispatch``.

``LightatorDevice.run`` is a thin wrapper over the two passes and keeps the
seed signature: it returns (logits, report). The original eager per-layer
interpreter survives as ``run_eager`` — the executable specification that
the compiled path must match bit-for-bit (see tests/test_plan_compile.py).

The model is described by a small layer IR (``ConvSpec``/``DenseSpec``/...)
emitted by ``models.vision``; weights are plain pytrees from QAT training.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import optical_core as ocore
from repro.core import power_model as pmod
from repro.core.compressive import compressive_acquire
from repro.core.quant import (WASpec, MixedPrecisionScheme, ACT_BITS,
                              quantize_weight, resolve_layer_specs)
from repro.kernels import dispatch


# ---------------------------------------------------------------------------
# Layer IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CASpec:
    pool: int = 2
    rgb_to_gray: bool = True


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    c_in: int
    c_out: int
    kernel: int = 3
    stride: int = 1
    padding: str = "SAME"
    act: str = "relu"               # relu | sign | tanh | abs | none
    pool: Optional[Tuple[str, int]] = None   # ("avg"|"max", size)
    # Depthwise filtering: the same (or a per-channel) k x k filter applied to
    # each input channel independently (c_out == c_in, weights [k,k,1,c]).
    # This is how the imaging pipelines run fixed-function filters over RGB
    # frames without collapsing channels — each channel is one single-channel
    # conv on the OC banks (k*k taps per arm group, c_out strides).
    depthwise: bool = False


@dataclasses.dataclass(frozen=True)
class UpsampleSpec:
    """Reconstruction upsample (the CA's inverse for compress->recon).

    Runs as preset-weight interpolation banks: every output pixel is a fixed
    weighted sum of <= 4 neighbouring inputs (bilinear) or a copy (nearest) —
    the same preset-MAC structure as the CA, so it is scheduled like a CA
    layer (no DACs, no remaps).
    """

    factor: int = 2
    method: str = "bilinear"        # bilinear | nearest


@dataclasses.dataclass(frozen=True)
class DenseSpec:
    name: str
    fan_in: int
    fan_out: int
    act: str = "relu"


@dataclasses.dataclass(frozen=True)
class FlattenSpec:
    pass


LayerIR = CASpec | ConvSpec | DenseSpec | FlattenSpec | UpsampleSpec


def _activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "sign":
        return jnp.sign(x)
    if kind == "tanh":
        return jnp.tanh(x)
    if kind == "abs":
        # magnitude readout: the BPD's two rails measured without sign —
        # what edge-magnitude pipelines consume
        return jnp.abs(x)
    if kind == "none":
        return x
    raise ValueError(f"unknown activation {kind}")


def _crc_requant(x: jnp.ndarray, a_bits: int = ACT_BITS):
    """Electronic output -> CRC codes for the next layer's DMVA.

    Returns (codes uint, scale). Unsigned: activations are light intensity.
    Scale calibrated per-tensor to the observed max (the reference-voltage
    ladder spans the pixel/previous-layer output range).
    """
    qmax = (1 << a_bits) - 1
    x = jnp.maximum(x, 0.0)
    scale = jnp.maximum(jnp.max(x), 1e-8) / qmax
    codes = jnp.clip(jnp.round(x / scale), 0, qmax)
    return codes, scale


class LightatorDevice:
    """Execute a layer-IR model with photonic quantized semantics + report."""

    def __init__(self, oc: ocore.OCConfig = ocore.DEFAULT_OC,
                 circuit: pmod.CircuitConstants = pmod.DEFAULT_CIRCUIT,
                 profile: pmod.AcceleratorProfile = pmod.LIGHTATOR_PROFILE):
        self.oc = oc
        self.power = pmod.PowerModel(oc, circuit, profile)

    # -- numerics ---------------------------------------------------------
    def _conv(self, codes: jnp.ndarray, act_scale: jnp.ndarray,
              w: jnp.ndarray, b: jnp.ndarray | None, spec: ConvSpec,
              wa: WASpec) -> jnp.ndarray:
        """Integer-exact quantized conv. codes: [B,H,W,Cin] uint codes."""
        wq, ws = quantize_weight(w, wa, axis=-1)   # w: [k,k,cin,cout]
        acc = jax.lax.conv_general_dilated(
            codes.astype(jnp.float32), wq.astype(jnp.float32),
            window_strides=(spec.stride, spec.stride), padding=spec.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        out = acc * (act_scale * ws.reshape(1, 1, 1, -1))
        if b is not None:
            out = out + b
        return out

    def _dense(self, codes: jnp.ndarray, act_scale: jnp.ndarray,
               w: jnp.ndarray, b: jnp.ndarray | None, wa: WASpec):
        wq, ws = quantize_weight(w, wa, axis=-1)
        acc = codes.astype(jnp.float32) @ wq.astype(jnp.float32)
        out = acc * (act_scale * ws.reshape(1, -1))
        if b is not None:
            out = out + b
        return out

    # -- the device -------------------------------------------------------
    def compile(self, layers: Sequence[LayerIR],
                input_shape: Tuple[int, ...],
                scheme: WASpec | MixedPrecisionScheme):
        """Static pass: layers + input shape -> cached ``CompiledPlan``."""
        from repro.core import plan as plan_mod
        return plan_mod._compile_model(
            tuple(layers), tuple(input_shape), scheme, oc=self.oc,
            circuit=self.power.c, profile=self.power.profile,
            weight_sram_kb=self.power.weight_sram_kb,
            act_sram_kb=self.power.act_sram_kb)

    def run(self, layers: Sequence[LayerIR], params: Dict[str, Dict],
            image: jnp.ndarray,
            scheme: WASpec | MixedPrecisionScheme) -> Tuple[jnp.ndarray, pmod.ModelReport]:
        """image: [B,H,W,C] float in [0,1]. Returns (logits, report).

        Deprecated compatibility wrapper (cached compile + jitted batched
        execute, bit-identical to ``run_eager``) — the front door is now
        ``repro.Program(layers, params, hwc).compile(Options(...))``, which
        also exposes the report without recomputation.
        """
        import copy

        from repro.core import plan as plan_mod
        plan_mod._warn_deprecated(
            "LightatorDevice.run",
            "repro.Program(layers, params, input_hwc)"
            ".compile(repro.Options(scheme=...)).run(image)")
        plan = self.compile(layers, image.shape, scheme)
        logits = plan_mod._execute(plan, params, image)
        # deep copy: the plan (and its report) is shared via the global plan
        # cache; callers mutating their report must not corrupt future runs
        return logits, copy.deepcopy(plan.report)

    def run_eager(self, layers: Sequence[LayerIR], params: Dict[str, Dict],
                  image: jnp.ndarray,
                  scheme: WASpec | MixedPrecisionScheme) -> Tuple[jnp.ndarray, pmod.ModelReport]:
        """The seed per-layer eager interpreter (reference semantics).

        Re-schedules and re-runs the power model on every call; kept as the
        specification the compiled path is regression-tested against, and as
        the baseline for ``benchmarks.bench_pipeline``.

        Covers the seed IR only: the imaging extensions (depthwise convs,
        ``UpsampleSpec``) execute exclusively on the compiled path — their
        quality oracle is the float reference (``imaging.apply_float``), not
        this interpreter — and are rejected here with a clear error.
        """
        compute_layers = [l for l in layers
                          if isinstance(l, (ConvSpec, DenseSpec))]
        specs = resolve_layer_specs(len(compute_layers), scheme)
        spec_iter = iter(specs)

        schedules: List[ocore.OCSchedule] = []
        spec_list: List[WASpec] = []
        conv_strategy: Dict[str, Dict] = {}
        # chain geoms aligned with the plan's step indices (each seed-IR
        # layer compiles to exactly one step), so the fused-segment report
        # resolves identically to the compile pass
        geoms: List[Optional[dispatch.ChainGeom]] = []

        # step 1: ADC-less imager — CRC on raw pixels
        codes, act_scale = _crc_requant(image)
        x = codes

        for layer in layers:
            if isinstance(layer, CASpec):
                # step 2: compressive acquisition on *dequantized* intensities
                intens = x * act_scale
                g = compressive_acquire(intens, layer.pool, layer.rgb_to_gray)
                if g.ndim == 3:
                    g = g[..., None]
                h, w_ = g.shape[1:3]
                schedules.append(ocore.schedule_ca(
                    "CA", h, w_, layer.pool,
                    channels=image.shape[-1], oc=self.oc))
                spec_list.append(WASpec(4, 4))
                geoms.append(None)
                x, act_scale = _crc_requant(g)
            elif isinstance(layer, ConvSpec):
                if layer.depthwise:
                    raise NotImplementedError(
                        f"{layer.name}: depthwise convs run on the compiled "
                        f"path only (core.plan.execute); the eager "
                        f"interpreter covers the seed IR")
                wa = next(spec_iter)
                p = params[layer.name]
                pads = jax.lax.padtype_to_pads(
                    (x.shape[1], x.shape[2]), (layer.kernel, layer.kernel),
                    (layer.stride, layer.stride), layer.padding)
                geoms.append(dispatch.ChainGeom(
                    layer.name, x.shape[1], x.shape[2], layer.c_in,
                    layer.c_out, layer.kernel, layer.stride,
                    tuple((int(lo), int(hi)) for lo, hi in pads),
                    act=layer.act, pool=layer.pool))
                y = self._conv(x, act_scale, p["w"], p.get("b"), layer, wa)
                # record the conv strategy the kernel path would choose for
                # this layer's (pre-pool) output dims — same resolution as
                # the compile pass, so reports stay field-for-field equal
                conv_strategy[layer.name] = dataclasses.asdict(
                    dispatch.select_conv_strategy(
                        y.shape[1], y.shape[2], layer.c_in, layer.c_out,
                        layer.kernel, layer.stride))
                y = _activation(y, layer.act)
                if layer.pool is not None:
                    kind, size = layer.pool
                    b_, h_, w_, c_ = y.shape
                    yr = y.reshape(b_, h_ // size, size, w_ // size, size, c_)
                    y = yr.max(axis=(2, 4)) if kind == "max" else yr.mean(axis=(2, 4))
                    if kind == "avg":
                        # avg pooling runs on CA banks with pre-set weights
                        schedules.append(ocore.schedule_ca(
                            f"{layer.name}.pool", y.shape[1], y.shape[2],
                            size, channels=1, oc=self.oc))
                        spec_list.append(WASpec(4, 4))
                h_out, w_out = y.shape[1:3]
                schedules.append(ocore.schedule_conv(
                    layer.name, h_out, w_out, layer.c_in, layer.c_out,
                    layer.kernel, oc=self.oc))
                spec_list.append(wa)
                x, act_scale = _crc_requant(y)        # step 4: DMVA reuse
            elif isinstance(layer, FlattenSpec):
                intens = x * act_scale
                flat = intens.reshape(intens.shape[0], -1)
                geoms.append(None)
                x, act_scale = _crc_requant(flat)
            elif isinstance(layer, DenseSpec):
                geoms.append(None)
                wa = next(spec_iter)
                p = params[layer.name]
                y = self._dense(x, act_scale, p["w"], p.get("b"), wa)
                schedules.append(ocore.schedule_fc(
                    layer.name, layer.fan_in, layer.fan_out,
                    batch=1, oc=self.oc))
                spec_list.append(wa)
                if layer.act != "none":
                    y = _activation(y, layer.act)
                    x, act_scale = _crc_requant(y)
                else:
                    # classifier head: logits leave the device (transmitter)
                    x, act_scale = y, jnp.asarray(1.0)
            else:
                raise TypeError(f"unknown layer IR {layer!r}")

        logits = x * act_scale if act_scale.ndim == 0 else x
        # architecture report with the per-layer specs actually used
        lps = [self.power.layer_power(pmod.LayerSchedule(s, sp))
               for s, sp in zip(schedules, spec_list)]
        report = self.power.finalize_report(lps, schedules, scheme)
        report.conv_strategy = conv_strategy
        report.fused_segments = [
            dataclasses.asdict(f)
            for f in dispatch.select_fused_segments(geoms)]
        return logits, report
