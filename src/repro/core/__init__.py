"""core — the Lightator paper's contribution as composable JAX modules.

- quant:          CRC ADC-less uint4 activation quantization, int{2,3,4} weight
                  quantization, QAT straight-through estimators, [W:A] schemes,
                  Lightator-MX mixed precision.
- optical_core:   OC geometry (9 MRs/arm, 6 arms/bank, 96 banks) and the
                  hardware-mapping methodology (3x3/5x5/7x7/FC) + cycle scheduler.
- compressive:    Compressive Acquisitor — fused RGB->gray + avg-pool weighted MAC.
- photonics:      MR transmission / VCSEL / BPD device models + noise.
- power_model:    device-to-architecture power/latency/FPS-per-W simulator.
- accelerator:    LightatorDevice — compile + execute wrapper over a mapped
                  model (eager reference interpreter kept as ``run_eager``).
- plan:           static compile pass (cached CompiledPlan: specs, schedules,
                  power report) + jitted batched execute pass that dispatches
                  to the Pallas kernels.
- program:        Program / Options / Executable — the unified front door
                  over both passes for CNNs and imaging pipelines alike
                  (the old compile_model/execute remain as deprecated shims).
"""

from repro.core.quant import (
    WASpec,
    MixedPrecisionScheme,
    crc_quantize_act,
    fake_quant_act,
    fake_quant_weight,
    quantize_weight,
    weight_scale,
)
from repro.core.optical_core import (
    OCConfig,
    ConvMapping,
    conv_mapping,
    fc_mapping,
    schedule_conv,
    schedule_fc,
    schedule_matmul,
)
from repro.core.compressive import (
    ca_coefficients,
    compressive_acquire,
    sequence_ca,
)
from repro.core.photonics import (
    MRDevice,
    mr_through_transmission,
    weight_to_detuning,
    vcsel_intensity,
)
from repro.core.power_model import PowerModel, LayerSchedule
from repro.core.plan import CompiledPlan, compile_model, execute
from repro.core.program import Executable, Options, Program

__all__ = [
    "CompiledPlan", "compile_model", "execute",
    "Program", "Options", "Executable",
    "WASpec", "MixedPrecisionScheme",
    "crc_quantize_act", "fake_quant_act", "fake_quant_weight",
    "quantize_weight", "weight_scale",
    "OCConfig", "ConvMapping", "conv_mapping", "fc_mapping",
    "schedule_conv", "schedule_fc", "schedule_matmul",
    "ca_coefficients", "compressive_acquire", "sequence_ca",
    "MRDevice", "mr_through_transmission", "weight_to_detuning",
    "vcsel_intensity",
    "PowerModel", "LayerSchedule",
]
