"""Compressive Acquisitor (CA) — paper Sec. 3.2.

The CA fuses RGB->grayscale conversion and kxk average pooling into a single
weighted-sum MAC executed in ONE optical cycle, by pre-setting the MR weights
to the product coefficients (paper eq. (1)):

    P_AvgGray = sum_{i in pool} sum_{j in {R,G,B}} (1/k^2) * c_j * P_ij
    c = (0.299, 0.587, 0.114)

Two realizations:
  * ``compressive_acquire`` — the pure-jnp reference (ref for the ca_pool
    Pallas kernel).
  * ``sequence_ca`` — generalization used for LM-family frontends: strided
    mean-pooling of frame/patch embeddings with a fused channel mix. This is
    the "compressive acquisition as a first-class feature" hook for the
    assigned [audio]/[vlm] architectures.
"""

from __future__ import annotations

import jax.numpy as jnp

RGB_COEFFS = (0.299, 0.587, 0.114)


def ca_coefficients(pool: int, channels: int = 3) -> jnp.ndarray:
    """The pre-set MR weights for one CA stride: shape [pool, pool, channels].

    channels==3 -> RGB->gray fused with mean pooling; channels==1 -> pure
    mean pooling (the paper's 'pooling layers implemented within CA banks').
    """
    if channels == 3:
        chan = jnp.asarray(RGB_COEFFS, jnp.float32)
    else:
        chan = jnp.full((channels,), 1.0 / channels, jnp.float32)
    w = jnp.ones((pool, pool, channels), jnp.float32) / float(pool * pool)
    return w * chan[None, None, :]


def compressive_acquire(img: jnp.ndarray, pool: int = 2,
                        rgb_to_gray: bool | None = None) -> jnp.ndarray:
    """Fused RGB->gray + pool x pool average pooling (single weighted MAC).

    img: [..., H, W, C] with H, W divisible by pool.
    Returns [..., H/pool, W/pool] (gray) or [..., H/pool, W/pool, C]
    (per-channel pooling when rgb_to_gray=False).
    """
    *lead, h, w, c = img.shape
    if h % pool or w % pool:
        raise ValueError(f"H({h}), W({w}) must be divisible by pool={pool}")
    if rgb_to_gray is None:
        rgb_to_gray = (c == 3)
    x = img.reshape(*lead, h // pool, pool, w // pool, pool, c)
    if rgb_to_gray:
        coeffs = ca_coefficients(pool, c)            # [pool, pool, c]
        return jnp.einsum("...hpwqc,pqc->...hw", x, coeffs)
    return x.mean(axis=(-4, -2))


def strided_conv_acquire(img: jnp.ndarray, weights: jnp.ndarray,
                         stride: int) -> jnp.ndarray:
    """The CA's other mode: configurable strided convolution at acquisition.

    img: [..., H, W, C]; weights: [k, k, C]; returns [..., H', W'].
    Implemented as patch extraction + the same weighted-sum MAC (one optical
    cycle per strides_per_cycle outputs).
    """
    k = weights.shape[0]
    *lead, h, w, c = img.shape
    h_out = (h - k) // stride + 1
    w_out = (w - k) // stride + 1
    # gather patches [..., h_out, w_out, k, k, c]
    rows = jnp.arange(h_out) * stride
    cols = jnp.arange(w_out) * stride
    patches = img[..., rows[:, None] + jnp.arange(k)[None, :], :, :]
    patches = patches[..., :, :, cols[:, None] + jnp.arange(k)[None, :], :]
    # patches: [..., h_out, k, w_out, k, c] -> weighted sum
    return jnp.einsum("...hpwqc,pqc->...hw", patches, weights)


def upsample_reconstruct(img: jnp.ndarray, factor: int = 2,
                         method: str = "bilinear") -> jnp.ndarray:
    """The CA's inverse: reconstruct a full-resolution frame from a
    compressively acquired one (paper's versatile-processing direction:
    acquisition *and* reconstruction on the same preset-MAC fabric).

    img: [B, H, W, C] -> [B, H*factor, W*factor, C]. ``bilinear`` models
    preset interpolation banks (each output a fixed weighted sum of <= 4
    inputs); ``nearest`` is a pure copy. Deterministic and differentiable —
    the learned deconv head trains through it.
    """
    import jax
    if method not in ("bilinear", "nearest"):
        raise ValueError(f"unknown upsample method {method!r}")
    b, h, w, c = img.shape
    return jax.image.resize(img, (b, h * factor, w * factor, c), method)


def sequence_ca(embeds: jnp.ndarray, factor: int,
                channel_mix: jnp.ndarray | None = None) -> jnp.ndarray:
    """Compressive acquisition for token/frame/patch embedding streams.

    embeds: [..., T, D]; returns [..., T/factor, D]. Mean-pools ``factor``
    consecutive embeddings (the CA's mean pooling) with an optional fused
    per-feature mix (the RGB->gray analogue). Used by the audio/VLM frontends.
    """
    *lead, t, d = embeds.shape
    if t % factor:
        raise ValueError(f"T({t}) must be divisible by factor={factor}")
    x = embeds.reshape(*lead, t // factor, factor, d).mean(axis=-2)
    if channel_mix is not None:
        x = x * channel_mix
    return x
