"""Lightator quantization: ADC-less CRC activations + MR weight imprinting.

The paper's compute model (Sec. 3):

* Activations are captured / regenerated through the Comparator-based Reading
  Circuit (CRC): 15 voltage comparators -> 16 levels -> **unsigned 4-bit**
  activations, thermometer-coded onto the VCSEL driver transistors. There is
  never a DAC or ADC in the activation path, so activation precision is fixed
  at 4 bits throughout ([W:4] for every configuration in Table 1).

* Weights are imprinted on microring resonators (MRs). Balanced photodetection
  (BPD) at the arm output gives a *signed* accumulate, so weights are
  symmetric signed integers with ``2^(b-1)-1`` magnitude levels per rail:
  [4] -> [-7, 7], [3] -> [-3, 3], [2] -> [-1, 1].

* Lightator-MX keeps the first layer at [4:4] and drops the remaining layers
  to [3:4] or [2:4] (Table 1, MX rows).

QAT uses straight-through estimators (STE): the forward pass sees the exact
quantized values the optical core would compute with, the backward pass sees
identity. The paper fine-tunes 6 epochs quantization-aware; our training
drivers do the same.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# [W:A] specification
# ---------------------------------------------------------------------------

CRC_LEVELS = 16          # 15 comparators -> 16 output codes
CRC_COMPARATORS = 15
ACT_BITS = 4             # fixed by the DMVA hardware


@dataclasses.dataclass(frozen=True)
class WASpec:
    """A [W:A] configuration, e.g. WASpec(4, 4) == "[4:4]"."""

    w_bits: int = 4
    a_bits: int = ACT_BITS
    per_channel: bool = True        # per-output-channel weight scales
    # Optional photonic non-ideality: std of Gaussian noise applied to the
    # dequantized weight transmission (fraction of one quant step).
    mr_noise_std: float = 0.0

    def __post_init__(self):
        if self.w_bits not in (1, 2, 3, 4, 8):
            raise ValueError(f"unsupported weight bit-width {self.w_bits}")
        if self.a_bits != ACT_BITS:
            # The CRC/DMVA fix activations at 4 bits; other widths are allowed
            # for ablation but flagged.
            if self.a_bits not in (2, 3, 8):
                raise ValueError(f"unsupported activation bit-width {self.a_bits}")

    @property
    def w_qmax(self) -> int:
        return (1 << (self.w_bits - 1)) - 1  # symmetric signed

    @property
    def a_qmax(self) -> int:
        return (1 << self.a_bits) - 1        # unsigned (light intensity)

    @property
    def name(self) -> str:
        return f"[{self.w_bits}:{self.a_bits}]"


@dataclasses.dataclass(frozen=True)
class MixedPrecisionScheme:
    """Lightator-MX: first layer [4:4], remaining layers at ``rest``."""

    first: WASpec = WASpec(4, 4)
    rest: WASpec = WASpec(3, 4)

    def spec_for_layer(self, layer_idx: int) -> WASpec:
        return self.first if layer_idx == 0 else self.rest

    @property
    def name(self) -> str:
        return f"MX {self.first.name}{self.rest.name}"


W4A4 = WASpec(4, 4)
W3A4 = WASpec(3, 4)
W2A4 = WASpec(2, 4)
MX_43 = MixedPrecisionScheme(W4A4, W3A4)
MX_42 = MixedPrecisionScheme(W4A4, W2A4)


# ---------------------------------------------------------------------------
# Straight-through rounding
# ---------------------------------------------------------------------------

def _ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round(x) in the forward pass, identity gradient in the backward pass."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


# ---------------------------------------------------------------------------
# Activation quantization (CRC / VCSEL path)
# ---------------------------------------------------------------------------

def crc_quantize_act(x: jnp.ndarray, scale: jnp.ndarray, a_bits: int = ACT_BITS):
    """The CRC: compare against 15 reference levels -> integer code 0..15.

    ``scale`` maps one quant step to physical units; the reference voltages
    are ``scale * (i + 0.5)`` i.e. mid-rise uniform. Returns the integer code
    (int8 carrier) — what the VCSEL driver transistor count encodes.
    """
    qmax = (1 << a_bits) - 1
    code = jnp.clip(jnp.round(x / scale), 0, qmax)
    return code.astype(jnp.int8)


def fake_quant_act(x: jnp.ndarray, scale: jnp.ndarray, a_bits: int = ACT_BITS,
                   train: bool = True) -> jnp.ndarray:
    """Fake-quantized activation: value the optical core actually streams.

    Unsigned (light intensity cannot be negative): inputs are expected
    post-ReLU / post-shift. STE when ``train``.
    """
    qmax = (1 << a_bits) - 1
    xs = x / scale
    xs = jnp.clip(xs, 0.0, float(qmax))
    q = _ste_round(xs) if train else jnp.round(xs)
    return q * scale


def act_scale_for_range(max_val: float | jnp.ndarray, a_bits: int = ACT_BITS):
    """Scale that maps [0, max_val] onto the CRC's levels."""
    qmax = (1 << a_bits) - 1
    return jnp.asarray(max_val, jnp.float32) / qmax


# ---------------------------------------------------------------------------
# Weight quantization (MR imprinting path)
# ---------------------------------------------------------------------------

def weight_scale(w: jnp.ndarray, w_bits: int, per_channel: bool = True,
                 axis: int = -1) -> jnp.ndarray:
    """Symmetric scale. Per-channel = per output feature (axis=-1 for [in,out])."""
    qmax = (1 << (w_bits - 1)) - 1
    if per_channel:
        reduce_axes = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
        amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_weight(w: jnp.ndarray, spec: WASpec, axis: int = -1):
    """-> (q_int8, scale). q in [-w_qmax, w_qmax]; dequant = q * scale."""
    s = weight_scale(w, spec.w_bits, spec.per_channel, axis)
    q = jnp.clip(jnp.round(w / s), -spec.w_qmax, spec.w_qmax).astype(jnp.int8)
    return q, s


def fake_quant_weight(w: jnp.ndarray, spec: WASpec, axis: int = -1,
                      noise_key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Fake-quantized weight with STE; optional MR transmission noise.

    The noise models thermal drift of the ring resonance: a Gaussian
    perturbation of the *dequantized* transmission, std expressed in quant
    steps (spec.mr_noise_std).
    """
    s = weight_scale(w, spec.w_bits, spec.per_channel, axis)
    ws = jnp.clip(w / s, -float(spec.w_qmax), float(spec.w_qmax))
    q = _ste_round(ws)
    if spec.mr_noise_std > 0.0 and noise_key is not None:
        q = q + spec.mr_noise_std * jax.random.normal(noise_key, q.shape, q.dtype)
    return q * s


# ---------------------------------------------------------------------------
# Quantized matmul semantics (the reference the kernels must match)
# ---------------------------------------------------------------------------

def qmatmul_reference(x: jnp.ndarray, w: jnp.ndarray, spec: WASpec,
                      act_scale: jnp.ndarray | float = 1.0) -> jnp.ndarray:
    """Integer-exact photonic MVM semantics on float carriers.

    x: [..., K] non-negative activations; w: [K, N].
    1. CRC-quantize x to codes 0..15.
    2. MR-quantize w per output channel.
    3. Integer MAC (what the arm/BPD/summation tree computes).
    4. Dequantize with act_scale * w_scale.
    """
    a_codes = jnp.clip(jnp.round(x / act_scale), 0, spec.a_qmax)
    wq, ws = quantize_weight(w, spec, axis=-1)
    acc = jnp.matmul(a_codes.astype(jnp.float32), wq.astype(jnp.float32))
    return acc * (jnp.asarray(act_scale, jnp.float32) * jnp.squeeze(ws))


# ---------------------------------------------------------------------------
# Per-layer scheme resolution
# ---------------------------------------------------------------------------

def resolve_layer_specs(n_layers: int,
                        scheme: WASpec | MixedPrecisionScheme) -> Sequence[WASpec]:
    if isinstance(scheme, MixedPrecisionScheme):
        return [scheme.spec_for_layer(i) for i in range(n_layers)]
    return [scheme] * n_layers
