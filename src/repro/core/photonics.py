"""Device-level photonic models: microring resonators, VCSELs, photodetectors.

These are the "device layer" of the paper's bottom-up evaluation framework
(Fig. 7). They serve two purposes in the reproduction:

1. Physics-grounded *weight transfer*: how a target weight value becomes an MR
   detuning, and what transmission error a thermal drift causes. This feeds
   the optional noise model in ``core.quant.fake_quant_weight``.
2. Energy bookkeeping inputs to ``core.power_model`` (tuning power scales with
   detuning; VCSEL power scales with driver level).

The resonant wavelength is ``lambda_res = n_eff * L / m`` (paper Sec. 2); the
through-port transmission of an all-pass ring near resonance is Lorentzian in
the detuning, parameterized directly by FWHM so device Q factors map cleanly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MRDevice:
    """An MR with the parameters the paper's device layer reports."""

    lambda_res_nm: float = 1550.0       # resonant wavelength
    fwhm_nm: float = 0.10               # full width at half maximum of the notch
    n_eff: float = 2.37                 # effective refractive index (SOI ring)
    circumference_um: float = 65.4      # L
    mode_order: int = 100               # m
    tuning_nm_per_mw: float = 0.25      # microheater tuning efficiency
    max_detuning_nm: float = 0.4        # tuning range

    @property
    def q_factor(self) -> float:
        return self.lambda_res_nm / self.fwhm_nm


def mr_through_transmission(detuning_nm: jnp.ndarray, fwhm_nm: float = 0.10):
    """Through-port power transmission vs detuning (Lorentzian notch).

    T(delta) = delta^2 / (delta^2 + (FWHM/2)^2)

    At resonance (delta=0) all power drops into the ring (T=0); far off
    resonance T -> 1. Monotone in |delta|, which is what makes the ring a
    programmable attenuator: *imprinting a parameter in the transmitted
    signal* (paper Fig. 1).
    """
    half = fwhm_nm / 2.0
    d2 = jnp.square(detuning_nm)
    return d2 / (d2 + half * half)


def weight_to_detuning(t_target: jnp.ndarray, fwhm_nm: float = 0.10):
    """Invert the Lorentzian: detuning that realizes transmission ``t_target``.

    t in [0, 1) -> delta = (FWHM/2) * sqrt(t / (1 - t)).
    """
    half = fwhm_nm / 2.0
    t = jnp.clip(t_target, 0.0, 1.0 - 1e-6)
    return half * jnp.sqrt(t / (1.0 - t))


def detuning_tuning_power_mw(detuning_nm: jnp.ndarray,
                             dev: MRDevice = MRDevice()) -> jnp.ndarray:
    """Microheater power needed to hold a detuning (linear tuning model)."""
    return jnp.abs(detuning_nm) / dev.tuning_nm_per_mw


def transmission_with_drift(t_target: jnp.ndarray, drift_nm: jnp.ndarray,
                            fwhm_nm: float = 0.10) -> jnp.ndarray:
    """Realized transmission when the ring drifts by ``drift_nm`` (thermal)."""
    delta = weight_to_detuning(t_target, fwhm_nm)
    return mr_through_transmission(delta + drift_nm, fwhm_nm)


def photonic_noise(key: jax.Array, t_target: jnp.ndarray,
                   drift_std_nm: float = 0.0, fwhm_nm: float = 0.10):
    """Sample realized transmissions under Gaussian thermal drift."""
    if drift_std_nm <= 0.0:
        return t_target
    drift = drift_std_nm * jax.random.normal(key, t_target.shape, jnp.float32)
    return transmission_with_drift(t_target, drift, fwhm_nm)


# ---------------------------------------------------------------------------
# VCSEL / DMVA
# ---------------------------------------------------------------------------

def vcsel_intensity(code: jnp.ndarray, i_unit_ma: float = 0.125,
                    slope_mw_per_ma: float = 0.3, i_threshold_ma: float = 0.2):
    """Optical output power of a directly-modulated VCSEL.

    ``code`` is the number of ON driver transistors (0..15, thermometer code
    from the CRC / previous-layer output). Driving current = code * i_unit,
    emitted power follows the L-I curve above threshold.
    """
    current = code.astype(jnp.float32) * i_unit_ma
    return jnp.maximum(current - i_threshold_ma, 0.0) * slope_mw_per_ma


def bpd_differential(pos_mw: jnp.ndarray, neg_mw: jnp.ndarray,
                     responsivity_a_per_w: float = 1.1) -> jnp.ndarray:
    """Balanced photodetector: signed accumulate of two optical rails."""
    return (pos_mw - neg_mw) * 1e-3 * responsivity_a_per_w


def shot_noise_current(key: jax.Array, photocurrent_a: jnp.ndarray,
                       bandwidth_hz: float = 5e9) -> jnp.ndarray:
    """Shot noise: sigma_i = sqrt(2 q I B). Returns noisy photocurrent."""
    q = 1.602e-19
    sigma = jnp.sqrt(2.0 * q * jnp.abs(photocurrent_a) * bandwidth_hz)
    return photocurrent_a + sigma * jax.random.normal(key, photocurrent_a.shape)
