"""Fault-tolerant checkpointing (built in-repo; no orbax available).

Guarantees:
  * **atomicity** — writes go to ``<dir>/tmp.<step>/`` and are renamed to
    ``<dir>/step_<step>/`` only after an fsync'd manifest lands; a crash
    mid-write can never corrupt the latest complete checkpoint.
  * **resharding on restore** — arrays are saved as full (unsharded) host
    npz blobs with a JSON manifest of tree structure + dtypes; restore
    accepts any target sharding tree (different mesh shape / device count),
    which is what elastic scaling needs (save on 256 chips, restore on 512).
  * **keep-k GC** — old steps are pruned after a successful save.
  * **multi-host layout** — each process saves its addressable shards under
    ``proc_<i>``; this container is single-process so proc_0 holds all
    leaves, but the layout and the manifest match the multi-host protocol.

For multi-TB models a production deployment would stream per-shard blobs;
the manifest/atomic-rename/keep-k protocol here is the part the fault
tolerance depends on and is what the failure-injection tests exercise.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        items[name] = leaf
    return items, treedef


def save_checkpoint(directory: str | Path, step: int, tree: PyTree,
                    extra: Optional[Dict] = None) -> Path:
    """Atomic save of a pytree. Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"tmp.{step}.{os.getpid()}"
    final = directory / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    items, _ = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": {}}
    for name, leaf in items.items():
        arr = np.asarray(jax.device_get(leaf))
        stored_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":      # ml_dtypes (bf16/f8): store f32
            arr = arr.astype(np.float32)
        arrays[name.replace("/", "__")] = arr
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": stored_dtype}
    np.savez(tmp / "proc_0.npz", **arrays)
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic on POSIX
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str | Path, target: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (optional pytree of NamedShardings)
    reshards on load — the elastic-restart path."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    cdir = directory / f"step_{step:08d}"
    data = np.load(cdir / "proc_0.npz")

    items, treedef = _flatten(target)
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten(shardings)

    leaves = []
    for name, ref in items.items():
        key = name.replace("/", "__")
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = np.asarray(jnp.asarray(arr).astype(ref.dtype))  # bf16-safe cast
        if shard_items is not None:
            leaves.append(jax.device_put(arr, shard_items[name]))
        else:
            leaves.append(jnp.asarray(arr))
    # tree_unflatten wants leaves in treedef order == items insertion order
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """keep-k manager with restart support + preemption-signal save hook."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 save_interval_steps: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.save_interval_steps = save_interval_steps

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval_steps == 0

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None,
             force: bool = False) -> Optional[Path]:
        if not force and not self.should_save(step):
            return None
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def restore_latest(self, target: PyTree,
                       shardings: Optional[PyTree] = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, target, step,
                                        shardings)

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_"))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for p in self.directory.glob("tmp.*"):
            shutil.rmtree(p, ignore_errors=True)
