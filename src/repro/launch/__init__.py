"""launch — production mesh, multi-pod dry-run, roofline, train/serve drivers.

``serve_vision`` streams frame batches through the compiled device pipeline
(core.plan) and reports measured frames/s next to the simulated FPS/W.
"""
