"""launch — production mesh, multi-pod dry-run, roofline, train/serve drivers.

``serve_vision`` hosts compiled programs in the ``repro.serve`` runtime
(async micro-batching scheduler, admission control, latency metrics) and
reports measured frames/s next to the simulated FPS/W; ``serve`` is the
retired pre-``repro.serve`` LM stub, kept as a deprecation shim.
"""
