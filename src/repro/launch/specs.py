"""Shape cells and input ShapeDtypeStructs for every (arch x shape) pair.

The four LM shape cells (assigned):
    train_4k     seq=4096,   global_batch=256   -> train_step
    prefill_32k  seq=32768,  global_batch=32    -> prefill_step (forward)
    decode_32k   seq=32768,  global_batch=128   -> serve_step (1 tok + cache)
    long_500k    seq=524288, global_batch=1     -> serve_step (SSM/hybrid/SWA)

Skips (DESIGN.md §Arch-applicability):
    encoder-only (hubert)            -> no decode_32k / long_500k
    pure full-attention archs        -> no long_500k
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm as lm_mod


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

FULL_ATTENTION_ARCHS = {
    "yi-34b", "smollm-360m", "tinyllama-1.1b", "stablelm-3b",
    "grok-1-314b", "kimi-k2-1t-a32b", "internvl2-26b",
}


def cell_status(arch: str, shape: str, cfg: ModelConfig) -> Optional[str]:
    """None if runnable, else the skip reason recorded in the tables."""
    if cfg.family == "encoder" and shape in ("decode_32k", "long_500k"):
        return "skip: encoder-only, no autoregressive step"
    if shape == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return "skip: pure full attention (system directive: sub-quadratic only)"
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
    b, t = cell.global_batch, cell.seq
    act_dtype = jnp.dtype(cfg.dtype)
    if cell.kind in ("train", "prefill"):
        batch: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.frontend == "audio":
            batch["frames"] = _sds((b, t, cfg.frontend_dim), act_dtype)
            if cell.kind == "train":
                batch["labels"] = _sds((b, t), jnp.int32)
        elif cfg.frontend == "vision":
            t_text = t - cfg.n_patches
            batch["patches"] = _sds((b, cfg.n_patches, cfg.frontend_dim),
                                    act_dtype)
            batch["tokens"] = _sds((b, t_text), jnp.int32)
            if cell.kind == "train":
                batch["labels"] = _sds((b, t_text), jnp.int32)
        else:
            batch["tokens"] = _sds((b, t), jnp.int32)
            if cell.kind == "train":
                batch["labels"] = _sds((b, t), jnp.int32)
        return batch
    # decode: one token + cache
    token = _sds((b, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: lm_mod.init_cache(cfg, b, t, dtype=act_dtype))
    return {"token": token, "cache": cache}


def params_shape(cfg: ModelConfig):
    """Param tree as ShapeDtypeStructs via eval_shape (no allocation)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: lm_mod.init_lm(k, cfg), key)
