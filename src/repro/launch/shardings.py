"""Param / optimizer / input / cache sharding rules per (arch x mesh).

Name-based rules over the param tree paths (every projection is a ".../w"
leaf; stacked layer params carry a leading [L] axis mapped to None).

Key decisions (see DESIGN.md §5):
  * batch        -> ("pod","data"); model axis carries TP everywhere
  * attn heads / kv heads / d_ff / vocab -> "model" (GSPMD pads uneven dims,
    e.g. yi's 56 heads; flagged in roofline notes)
  * FSDP (cfg.fsdp): the non-model param dim ("embed") -> "data"
  * MoE: experts -> "model" when n_experts >= model-axis size (kimi: 384),
    otherwise the per-expert FFN dim -> "model" (grok: 8 experts x 32768 ffn)
  * SSM: params replicated over model; activations shard on ssm heads
  * decode caches: batch -> ("pod","data"), cache seq -> "model"
    (sequence-sharded KV avoids padding 8 kv heads onto 16 shards)
  * optimizer moments/master mirror the param specs exactly (ZeRO)
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import base_rules

PyTree = Any


def build_rules(cfg: ModelConfig, mesh: Mesh, serve: bool = False) -> Dict:
    multi_pod = "pod" in mesh.axis_names
    model_size = mesh.shape["model"]
    fsdp = cfg.fsdp
    if serve and fsdp:
        # serving profile (§Perf yi-decode): FSDP re-gathers every layer's
        # weights per decoded token — pure TP is strictly better whenever
        # the TP-sharded params fit HBM (<= 8 GiB/chip leaves room for cache)
        from repro.models.lm import count_params
        per_chip = count_params(cfg) * 2 / model_size
        if per_chip <= 8 * 2**30:
            fsdp = False
    rules = base_rules(multi_pod=multi_pod, fsdp=fsdp)
    rules["experts"] = ("model",) if cfg.n_experts >= model_size else None
    rules["moe_ffn"] = None if rules["experts"] else ("model",)
    rules["ssm_inner"] = None
    # GQA kv heads that don't divide the model axis force padded resharding
    # between q (heads-sharded) and k/v — XLA emits "involuntary full
    # rematerialization" copies plus per-block all-gathers (§Perf, kimi
    # iter 4). Replicating the kv ACTIVATIONS over model is cheaper: wk/wv
    # params still shard on their flattened output dim.
    if cfg.n_kv_heads and cfg.n_kv_heads % model_size != 0:
        rules["kv"] = None
    if cfg.n_heads and cfg.n_heads % model_size != 0:
        rules["heads"] = None
    return rules


def _ax(rules, name):
    ax = rules.get(name)
    if ax is None:
        return None
    return ax if len(ax) > 1 else ax[0]


def param_spec(path: str, ndim: int, cfg: ModelConfig, rules: Dict) -> P:
    """PartitionSpec for one param leaf identified by its tree path."""
    a = lambda name: _ax(rules, name)
    stacked = path.startswith("layers/")
    lead = (None,) if stacked else ()
    # photonic serving storage: the int carrier shards like the fp weight;
    # per-channel scales are tiny -> replicate
    if path.endswith("/ws"):
        return P(*([None] * ndim))
    if path.endswith("/wq"):
        path = path[:-3] + "/w"

    def spec(*axes):
        return P(*(lead + axes))

    if path.endswith("embed/table"):
        return P(a("vocab"), a("embed"))
    if path.startswith("lm_head"):
        return P(a("embed"), a("vocab"))
    if path.startswith("frontend"):
        return P() if ndim == 1 else P(None, None)
    if "/attn/" in path:
        if "/wo/" in path:
            return spec(a("heads"), a("embed"))
        return spec(a("embed"), a("heads"))          # wq/wk/wv
    if "/mlp/" in path:
        if "/w_down/" in path:
            return spec(a("ffn"), a("embed"))
        return spec(a("embed"), a("ffn"))
    if "/moe/" in path:
        if path.endswith("router"):
            return spec(a("embed"), None)
        if "w_down" in path:
            return spec(a("experts"), a("moe_ffn"), a("expert_embed"))
        return spec(a("experts"), a("expert_embed"), a("moe_ffn"))
    if "/ssm/" in path:
        if "/in_proj/" in path:
            return spec(a("embed"), a("ssm_inner"))
        if "/out_proj/" in path:
            return spec(a("ssm_inner"), a("embed"))
        # conv/dt/a_log/d_skip/norm: replicate
        return spec(*([None] * (ndim - 1)))
    # norms, biases, everything else: replicated
    if stacked:
        return spec(*([None] * (ndim - 1)))
    return P(*([None] * ndim))


def _sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes that don't divide the dimension (pjit arg shardings
    must divide evenly; advisory constraints inside the program may pad,
    explicit argument shardings may not)."""
    entries = []
    for i, ax in enumerate(spec):
        if ax is None:
            entries.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        entries.append(ax if shape[i] % size == 0 else None)
    return P(*entries)


def tree_shardings(tree: PyTree, cfg: ModelConfig, mesh: Mesh,
                   rules: Dict) -> PyTree:
    """NamedSharding tree matching ``tree`` (params or optimizer state)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def leaf_sharding(pathkeys, leaf):
        parts = []
        for pk in pathkeys:
            if hasattr(pk, "key"):
                parts.append(str(pk.key))
            elif hasattr(pk, "name"):
                parts.append(str(pk.name))
        path = "/".join(parts)
        # optimizer wrappers: mu/nu/master mirror the param below them
        for prefix in ("mu/", "nu/", "master/", "error/"):
            if path.startswith(prefix):
                path = path[len(prefix):]
        if path == "step" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = param_spec(path, leaf.ndim, cfg, rules)
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    shardings = [leaf_sharding(pk, leaf) for pk, leaf in flat]
    return treedef.unflatten(shardings)


def batch_shardings(batch: Dict, cfg: ModelConfig, mesh: Mesh,
                    rules: Dict) -> Dict:
    b = _ax(rules, "batch")
    out = {}
    for k, v in batch.items():
        spec = _sanitize(P(*((b,) + (None,) * (v.ndim - 1))), v.shape, mesh)
        out[k] = NamedSharding(mesh, spec)
    return out


def cache_shardings(cache: PyTree, cfg: ModelConfig, mesh: Mesh,
                    rules: Dict) -> PyTree:
    """Decode caches: [L, B, S, K, D] -> (None, batch, model-on-seq, .., ..)."""
    b = _ax(rules, "batch")

    def one(pathkeys, leaf):
        parts = [str(getattr(pk, "key", "")) for pk in pathkeys]
        path = "/".join(parts)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if "/kv/" in path or path.endswith("/k") or path.endswith("/v"):
            # [L, B, S, K, D]: sequence-sharded KV cache
            spec = P(None, b, "model", None, None)
        elif path.endswith("/ssm"):
            # [L, B, H, P, N]: shard SSM state over heads
            spec = P(None, b, "model", None, None)
        elif path.endswith("/conv"):
            spec = P(None, b, None, None)
        else:
            spec = P(*((None,) * leaf.ndim))
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return treedef.unflatten([one(pk, leaf) for pk, leaf in flat])
