"""Step builders: train / prefill / serve as pure jit-able functions."""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_update

PyTree = Any


def vocab_chunk_for(cfg: ModelConfig, seq: int) -> int:
    """Chunk the CE loss when the [B,T,V] logits tensor would be monstrous."""
    if cfg.vocab * seq >= 32768 * 4096:
        return 512
    if cfg.vocab >= 64000 and seq >= 4096:
        return 1024
    return 0


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, seq: int,
                    grad_shardings: PyTree = None):
    """``grad_shardings`` (a tree of NamedShardings mirroring the params)
    pins gradients to the param layout BEFORE the global-norm reduction.
    Without the pin, SPMD satisfies the two consumers (scalar norm + sharded
    moment update) by ALL-REDUCING full weight gradients instead of
    reduce-scattering them (~770 GiB/step on grok-1 — §Perf iter 3)."""
    vc = vocab_chunk_for(cfg, seq)

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[PyTree, PyTree, Dict[str, jnp.ndarray]]:
        def loss_fn(p):
            return lm_mod.lm_loss(p, batch, cfg, vocab_chunk=vc)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt)
        return new_params, new_opt, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params: PyTree, batch: Dict[str, jnp.ndarray]):
        logits, aux = lm_mod.lm_forward(params, batch, cfg)
        # serving returns the last-position logits (next-token distribution)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params: PyTree, cache: PyTree, token: jnp.ndarray):
        return lm_mod.decode_step(params, cache, token, cfg)

    return serve_step
