import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Perf hillclimbing harness (§Perf): lower a cell under a named variant,
report the three roofline terms, log hypothesis -> change -> before/after.

    python -m repro.launch.hillclimb --cell yi-decode --variant serve_tp
    python -m repro.launch.hillclimb --cell kimi-train --all-variants

Variants change ONE lever each (sharding rules, dispatch algorithm, carrier
dtypes) so deltas are attributable; results append to
experiments/hillclimb/<cell>.json.
"""

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.sharding import use_rules
from repro.launch import mesh as mesh_mod
from repro.launch import shardings as sh
from repro.launch import specs as specs_mod
from repro.launch.hlo_analysis import weighted_collectives
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.roofline import analytic_flops, analytic_hbm_bytes
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init


@dataclasses.dataclass
class Variant:
    name: str
    hypothesis: str
    cfg_patch: Dict = dataclasses.field(default_factory=dict)
    rules_patch: Dict = dataclasses.field(default_factory=dict)
    quant_serving: Optional[str] = None      # "w4a4" etc -> wq/ws params
    cache_dtype: Optional[str] = None        # e.g. "float8_e4m3fn"
    # analytic memory-term adjustments (bytes factors vs baseline model)
    param_bytes: float = 2.0                 # bytes per weight read
    cache_elem_bytes: float = 2.0


def lower_variant(arch: str, shape: str, v: Variant, multi_pod=False):
    cfg = dataclasses.replace(get_config(arch), **v.cfg_patch)
    cell = specs_mod.SHAPES[shape]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    rules = sh.build_rules(cfg, mesh)
    rules.update(v.rules_patch)

    if v.quant_serving:
        from repro.core import quant as Q
        spec = {"w4a4": Q.W4A4, "w3a4": Q.W3A4, "w2a4": Q.W2A4}[v.quant_serving]
        params_s = jax.eval_shape(
            lambda k: lm_mod.quantize_lm_params(
                lm_mod.init_lm(k, cfg), cfg, spec),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    else:
        params_s = specs_mod.params_shape(cfg)
    p_shard = sh.tree_shardings(params_s, cfg, mesh, rules)
    inputs = specs_mod.input_specs(cfg, cell)
    if v.cache_dtype and "cache" in inputs:
        cdt = jnp.dtype(v.cache_dtype)
        inputs["cache"] = jax.tree.map(
            lambda x: (jax.ShapeDtypeStruct(x.shape, cdt)
                       if x.dtype == jnp.bfloat16 else x), inputs["cache"])

    with use_rules(mesh, rules):
        if cell.kind == "train":
            opt_cfg = AdamWConfig()
            opt_s = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_s)
            o_shard = sh.tree_shardings(opt_s, cfg, mesh, rules)
            b_shard = sh.batch_shardings(inputs, cfg, mesh, rules)
            step = make_train_step(cfg, opt_cfg, cell.seq,
                                   grad_shardings=p_shard)
            lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                              out_shardings=(p_shard, o_shard, None),
                              donate_argnums=(0, 1)).lower(
                params_s, opt_s, inputs)
        elif cell.kind == "prefill":
            b_shard = sh.batch_shardings(inputs, cfg, mesh, rules)
            lowered = jax.jit(make_prefill_step(cfg),
                              in_shardings=(p_shard, b_shard)).lower(
                params_s, inputs)
        else:
            c_shard = sh.cache_shardings(inputs["cache"], cfg, mesh, rules)
            t_shard = sh.batch_shardings({"token": inputs["token"]}, cfg,
                                         mesh, rules)["token"]
            lowered = jax.jit(make_serve_step(cfg),
                              in_shardings=(p_shard, c_shard, t_shard),
                              out_shardings=(None, c_shard),
                              donate_argnums=(1,)).lower(
                params_s, inputs["cache"], inputs["token"])
        compiled = lowered.compile()
    return compiled, cfg


def measure(arch: str, shape: str, v: Variant, multi_pod=False) -> Dict:
    chips = 512 if multi_pod else 256
    t0 = time.time()
    compiled, cfg = lower_variant(arch, shape, v, multi_pod)
    wall = time.time() - t0
    hlo = compiled.as_text()
    cw = weighted_collectives(hlo)["bytes"]
    coll = cw["total"] + cw["all-reduce"]        # ring AR ~ 2x payload
    flops = analytic_flops(arch, shape)
    hbm = analytic_hbm_bytes(arch, shape)
    # dtype adjustments to the analytic memory model
    hbm *= 1.0
    if v.param_bytes != 2.0 or v.cache_elem_bytes != 2.0:
        hbm = analytic_hbm_bytes_adjusted(arch, shape, v)
    mem = compiled.memory_analysis()
    terms = {
        "t_compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "t_memory_s": hbm / (chips * HBM_BW),
        "t_collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape, "variant": v.name,
        "hypothesis": v.hypothesis, **terms,
        "dominant": dominant.replace("t_", "").replace("_s", ""),
        "roofline_fraction": terms["t_compute_s"] / max(terms.values()),
        "collective_bytes_per_dev": int(coll),
        "collectives": cw,
        "temp_gib_per_dev": mem.temp_size_in_bytes / 2**30,
        "args_gib_per_dev": mem.argument_size_in_bytes / 2**30,
        "compile_wall_s": round(wall, 1),
    }


def analytic_hbm_bytes_adjusted(arch: str, shape: str, v: Variant) -> float:
    """Re-derive the decode memory model with variant carrier widths."""
    from repro.launch.roofline import _cache_bytes
    from repro.models.lm import active_params, count_params
    cfg = dataclasses.replace(get_config(arch), **v.cfg_patch)
    cell = specs_mod.SHAPES[shape]
    if cell.kind != "decode":
        return analytic_hbm_bytes(arch, shape)
    if cfg.family == "moe":
        frac = min(1.0, cell.global_batch * cfg.top_k / cfg.n_experts)
        expert_n = (count_params(cfg) - active_params(cfg)) \
            / max(cfg.n_experts - cfg.top_k, 1) * cfg.n_experts
        nonexpert_n = count_params(cfg) - expert_n
        traffic = (nonexpert_n + expert_n * frac) * v.param_bytes
    else:
        traffic = count_params(cfg) * v.param_bytes
    traffic += _cache_bytes(cfg, cell.global_batch, cell.seq) \
        * (v.cache_elem_bytes / 2.0)
    return float(traffic)


# ---------------------------------------------------------------------------
# Experiment registry — one cell per assigned hillclimb target
# ---------------------------------------------------------------------------

CELLS = {
    "yi-decode": ("yi-34b", "decode_32k"),
    "kimi-train": ("kimi-k2-1t-a32b", "train_4k"),
    "grok-train": ("grok-1-314b", "train_4k"),
}

VARIANTS: Dict[str, list] = {
    "yi-decode": [
        Variant("base", "baseline: FSDP rules at inference"),
        Variant("serve_tp",
                "FSDP all-gathers dominate decode (params re-gathered per "
                "layer). Pure-TP serving rules (params replicated over data) "
                "eliminate them: collective term should drop ~100x to the "
                "level of attention psums",
                rules_patch={"embed": None, "expert_embed": None}),
        Variant("serve_tp_w4",
                "int4 MR carriers (the paper's storage) cut param bytes 4x "
                "-> expect memory term ~4x down. REFUTED: at batch=128 x "
                "32k the KV cache (1.03 TB) dominates params (69 GB) 15:1; "
                "memory moved only 5%. Lesson: weight quantization is the "
                "lever for SMALL-batch decode; here the cache is the wall",
                rules_patch={"embed": None, "expert_embed": None},
                quant_serving="w4a4", param_bytes=0.5),
        Variant("serve_tp_kv8",
                "narrow the dominant stream instead: f8 KV cache (the CRC "
                "4-bit-activation idea applied to cache storage) halves "
                "cache reads -> memory term ~1.9x down",
                rules_patch={"embed": None, "expert_embed": None},
                cache_dtype="float8_e4m3fn", cache_elem_bytes=1.0),
        Variant("serve_tp_kv8_w4",
                "stack both narrow carriers: memory -> ~0.5x cache + 0.25x "
                "params; collective term (TP layer all-reduces, 2.6 ms) "
                "should now be within ~2x of memory",
                rules_patch={"embed": None, "expert_embed": None},
                quant_serving="w4a4", param_bytes=0.5,
                cache_dtype="float8_e4m3fn", cache_elem_bytes=1.0),
    ],
    "kimi-train": [
        Variant("base", "baseline: sorted global dispatch"),
        Variant("grouped",
                "the [E*C,d] dispatch buffer scatter lowers to a ~32 GB "
                "all-reduce over data PER LAYER (2.5 TB/step). group-local "
                "dispatch scatters within each batch row -> that AR "
                "disappears; remaining comm = combine gather over model",
                cfg_patch={"moe_dispatch": "grouped"}),
        Variant("grouped_cf1",
                "capacity_factor 1.25 -> 1.0 cuts buffer/combine payload "
                "20% with the same drop semantics at batch scale",
                cfg_patch={"moe_dispatch": "grouped",
                           "capacity_factor": 1.0}),
        Variant("grouped_f8",
                "combine payload in f8 (CRC-style narrow carriers across "
                "the wire) halves the remaining all-gather",
                cfg_patch={"moe_dispatch": "grouped",
                           "capacity_factor": 1.0,
                           "moe_combine_dtype": "float8_e4m3fn"}),
    ],
    "grok-train": [
        Variant("base", "baseline: sorted global dispatch"),
        Variant("grouped",
                "same dispatch-buffer AR pathology as kimi (32 GB/layer "
                "over data); group-local dispatch removes it. Experts (8) "
                "can't shard on the 16-way model axis -> per-expert FFN "
                "shards on model (Megatron-style partial-sum AR of the "
                "expert outputs expected instead)",
                cfg_patch={"moe_dispatch": "grouped"}),
        Variant("grouped_cf1_f8",
                "stack capacity 1.0 + f8 combine on top",
                cfg_patch={"moe_dispatch": "grouped",
                           "capacity_factor": 1.0,
                           "moe_combine_dtype": "float8_e4m3fn"}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all-variants", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    arch, shape = CELLS[args.cell]
    variants = VARIANTS[args.cell]
    if args.variant:
        variants = [v for v in variants if v.name == args.variant]
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    log_path = out_dir / f"{args.cell}.json"
    log = json.loads(log_path.read_text()) if log_path.exists() else []

    for v in variants:
        rec = measure(arch, shape, v, args.multipod)
        log = [r for r in log if r["variant"] != v.name] + [rec]
        log_path.write_text(json.dumps(log, indent=1))
        print(f"[{args.cell}/{v.name}] compute={rec['t_compute_s']:.4g}s "
              f"memory={rec['t_memory_s']:.4g}s "
              f"collective={rec['t_collective_s']:.4g}s "
              f"dominant={rec['dominant']} "
              f"frac={rec['roofline_fraction']:.3f} "
              f"(compile {rec['compile_wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
