"""Loop-aware HLO analysis: collective bytes weighted by while-loop trips.

``compiled.as_text()`` contains each while body ONCE, but a scan over 60
layers executes it 60 times — raw op counts undercount collective traffic by
the trip count. This parser:

  1. splits the module into computations,
  2. finds ``while`` instructions and reads the trip count out of the
     condition computation (the ``s32[] constant(N)`` the induction variable
     is compared against),
  3. propagates multipliers ENTRY -> while bodies (nested loops multiply),
  4. sums result-shape bytes of every collective op weighted by its
     computation's multiplier.

Bytes are per-device (shapes in the SPMD module are post-partitioning).
``all-reduce`` moves ~2x its shape bytes on a ring (reduce-scatter +
all-gather); we report raw shape bytes and apply the ring factor in the
roofline, where the algorithm term lives.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:,|\s).*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"branches=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """Split the module by column-0 structure: headers are unindented lines
    ending in '{'; bodies are indented; '}' at column 0 closes. (Header
    param lists can contain nested parens — tuple-typed params — so no
    attempt is made to parse them.)"""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line:
            continue
        unindented = not line[0].isspace()
        stripped = line.strip()
        if cur is None or unindented:
            if unindented and stripped.endswith("{"):
                m = _COMP_HEADER.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        comps["__entry__"] = comps[cur]
                    continue
            if unindented and stripped.startswith("}"):
                cur = None
            continue
        if stripped == "}":
            cur = None
            continue
        comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Trip count heuristic: the largest s32 constant compared in the cond.

    jax.lax.scan lowers to a while whose condition is `iter < N`; N shows up
    as an s32[] constant. Falls back to 1 when nothing is found.
    """
    consts = []
    for line in cond_lines:
        consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """ENTRY has multiplier 1; while bodies inherit parent x trip count."""
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                edges.setdefault(name, []).append((body, float(trips)))
                edges.setdefault(name, []).append((cond, float(trips)))
                continue
            cm = _CALL_RE.search(line)
            if cm:
                edges.setdefault(name, []).append((cm.group(1), 1.0))
            bm = _COND_RE.search(line)
            if bm:
                for br in bm.group(1).split(","):
                    edges.setdefault(name, []).append(
                        (br.strip().lstrip("%"), 1.0))

    entry = None
    for name, lines in comps.items():
        if name != "__entry__" and comps.get("__entry__") is lines:
            entry = name
    mult: Dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}
    # BFS propagate (computations form a DAG)
    stack = [(entry, 1.0)]
    while stack:
        name, m = stack.pop()
        mult[name] = mult.get(name, 0.0) + m
        for child, w in edges.get(name, []):
            stack.append((child, m * w))
    return mult


def weighted_collectives(hlo: str) -> Dict:
    """-> {"bytes": {op: weighted}, "counts": {...}, "raw_bytes": {...}}."""
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    w_bytes = {k: 0.0 for k in COLLECTIVE_OPS}
    r_bytes = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    op_re = re.compile(
        r"=\s*(.+?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0)
        for line in lines:
            om = op_re.search(line)
            if not om:
                continue
            if f"{om.group(2)}-done" in line:
                continue
            shape_part, op = om.group(1), om.group(2)
            b = _shape_bytes(shape_part)
            w_bytes[op] += b * m
            r_bytes[op] += b
            counts[op] += 1
    w_bytes["total"] = sum(w_bytes[k] for k in COLLECTIVE_OPS)
    r_bytes["total"] = sum(r_bytes[k] for k in COLLECTIVE_OPS)
    counts["total"] = sum(counts[k] for k in COLLECTIVE_OPS)
    return {"bytes": {k: int(v) for k, v in w_bytes.items()},
            "raw_bytes": r_bytes, "counts": counts,
            "n_computations": len(comps) - 1}
