"""Vision/imaging serving driver over the ``repro.serve`` runtime.

Three serving modes, one API (``repro.Program`` / ``Options`` /
``Executable`` hosted in a ``repro.serve.Server``):

    # CNN classification throughput (closed-loop saturation)
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model lenet --scheme mx43 --batch 8 --batches 50

    # fixed-function imaging (repro.imaging pipelines)
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --pipeline edge_detect --batch 8 --batches 50

    # open-loop Poisson load (latency under offered load)
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model lenet --load 500 --requests 200 --deadline-ms 100

    # device pool: fan batches across 4 (virtual) devices
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model lenet --load 500 --requests 64 --devices 4

Each run compiles once (``Server.register`` -> ``Executable``), warms
every batch bucket, then streams *single-frame requests* through the
async micro-batching scheduler: requests are coalesced up to
``--batch`` / ``--max-wait-ms``, padded to the nearest compiled bucket,
executed with per-frame CRC calibration (results bit-identical to
per-request ``Executable.run``), and completed on a separate thread while
the next batch is being collected — the serving-runtime descendant of the
old double-buffered feeder (its ``--depth`` knob is now
``ServeConfig.max_inflight``).

The default mode reports sustained frames/s under full backlog next to
the power model's simulated device FPS and kFPS/W — and, for imaging
pipelines, the PSNR of the quantized device output against the float
reference. ``--load`` switches to the open-loop Poisson generator and
reports p50/p95/p99 latency, achieved rate, and sheds at the offered
load. The kernel backend and conv strategy stay serving flags
(``--backend``, ``--conv-strategy``) mapped through ``Options``, and the
run header prints the fully *resolved* options.

``--trace out.json`` records the whole run through ``repro.obs`` and
exports Chrome-trace JSON: every request's latency decomposes into
queue-wait -> batch-assembly -> device -> split spans on its own lane
(open the file in chrome://tracing or https://ui.perfetto.dev), and the
run ends with the verbose per-program stats table plus the plan-cache /
conv-dispatch footer. See docs/observability.md.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import serve
from repro.core.program import Options
from repro.core.quant import W4A4, W3A4, W2A4, MX_43, MX_42
from repro.kernels import dispatch
from repro.models.vision import MODEL_INPUT_HWC, vision_program

SCHEMES = {"w4a4": W4A4, "w3a4": W3A4, "w2a4": W2A4,
           "mx43": MX_43, "mx42": MX_42}


def _imaging_frames(batch: int, size: int, seed: int) -> np.ndarray:
    from repro.data.synthetic import synthetic_textures
    imgs, _ = synthetic_textures(batch, hw=size, seed=seed)
    return np.asarray(imgs, np.float32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    # choices = the IRs the executable device path supports (alexnet's IR
    # is schedule-only; see models.vision.MODEL_INPUT_HWC)
    ap.add_argument("--model", default="lenet",
                    choices=sorted(MODEL_INPUT_HWC))
    ap.add_argument("--pipeline", default=None,
                    help="serve a repro.imaging pipeline instead of a CNN")
    ap.add_argument("--scheme", default="mx43", choices=sorted(SCHEMES))
    ap.add_argument("--batch", type=int, default=8,
                    help="scheduler max_batch (largest micro-batch)")
    ap.add_argument("--batches", type=int, default=50,
                    help="device batches worth of frames to stream "
                         "(total frames = batch * batches)")
    ap.add_argument("--size", type=int, default=64,
                    help="imaging frame height/width (pipeline mode)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="micro-batch collection window")
    ap.add_argument("--load", type=float, default=None,
                    help="open-loop Poisson mode: offered requests/s")
    ap.add_argument("--requests", type=int, default=64,
                    help="requests to offer in --load mode")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (late requests are shed)")
    ap.add_argument("--backend", default=None,
                    choices=sorted(dispatch.BACKENDS),
                    help="kernel backend (default: REPRO_KERNEL_BACKEND / "
                         "auto: pallas on TPU, reference elsewhere)")
    ap.add_argument("--conv-strategy", default=None,
                    choices=sorted(dispatch.CONV_STRATEGIES),
                    help="conv execution strategy (default: "
                         "REPRO_CONV_STRATEGY / auto VMEM heuristic)")
    ap.add_argument("--devices", type=int, default=1,
                    help="device-pool width: one warmed executable per "
                         "local device, least-loaded placement + work "
                         "stealing (on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--placement", default="least_loaded",
                    choices=sorted(serve.PLACEMENTS),
                    help="pool placement policy (--devices > 1)")
    ap.add_argument("--shard-batch", action="store_true",
                    help="shard the batch axis over local devices "
                         "(no-op on 1 device)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record an obs trace of the whole run and export "
                         "Chrome-trace JSON (open in chrome://tracing or "
                         "Perfetto); also prints the verbose stats table")
    ap.add_argument("--admin-port", type=int, default=None,
                    help="serve the ops endpoint (/metrics /healthz /readyz "
                         "/statusz /tracez) on this port for the run's "
                         "lifetime; 0 binds an ephemeral port and prints it")
    ap.add_argument("--log", default=None, metavar="OUT.jsonl",
                    help="structured JSON-lines event log (serve lifecycle, "
                         "SLO breaches, worker failures, flight dumps)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.batch < 1 or args.batches < 1 or args.requests < 1:
        ap.error("--batch, --batches and --requests must be >= 1")
    if args.devices < 1:
        ap.error("--devices must be >= 1")
    if args.load is not None and args.load <= 0:
        ap.error("--load must be > 0 requests/s")

    trace = None
    if args.trace is not None:
        from repro import obs
        trace = obs.enable()

    options = Options(scheme=SCHEMES[args.scheme], fc_batch=args.batch,
                      backend=args.backend, conv_strategy=args.conv_strategy,
                      shard_batch=args.shard_batch)

    if args.pipeline is not None:
        from repro.imaging import PIPELINES
        if args.pipeline not in PIPELINES:
            ap.error(f"unknown pipeline {args.pipeline!r}; "
                     f"choose from {sorted(PIPELINES)}")
        prog = PIPELINES[args.pipeline].program(args.size, args.size, 3)
        pool = _imaging_frames(max(2 * args.batch, 8), args.size, args.seed)
        name = f"pipeline={prog.name}"
    else:
        prog = vision_program(args.model, key=jax.random.PRNGKey(args.seed))
        h, w, c = prog.input_hwc
        rng = np.random.default_rng(args.seed + 1)
        pool = rng.random((max(2 * args.batch, 8), h, w, c), np.float32)
        name = f"model={args.model}"

    server = serve.Server(serve.ServeConfig(
        max_batch=args.batch, max_wait_ms=args.max_wait_ms,
        max_queue=max(8 * args.batch, 64),
        default_deadline_ms=args.deadline_ms,
        devices=args.devices, placement=args.placement,
        admin_port=args.admin_port, log_path=args.log))
    t0 = time.perf_counter()
    hosted = server.register(prog.name, prog, options)
    t_compile = time.perf_counter() - t0
    server.start(warm=True)
    if server.admin is not None:
        print(f"[serve_vision] admin endpoint at {server.admin.url} "
              f"(/metrics /healthz /readyz /statusz /tracez)")

    r = hosted.executable.report
    print(f"[serve_vision] {name} max_batch={args.batch} "
          f"buckets={list(hosted.buckets)} wait={args.max_wait_ms}ms "
          f"devices={args.devices} compile={t_compile * 1e3:.1f}ms")
    print(f"[serve_vision] options: {options.describe()}")
    if r.conv_strategy:
        # annotate each conv with its fused-segment membership: a conv
        # inside a segment executes in that segment's single launch, not
        # under its per-conv strategy
        seg_of = {n: i for i, seg in enumerate(r.fused_segments)
                  for n in seg["names"]}
        strat = " ".join(
            f"{n}={v['kind']}" + (f"({v['n_strips']}x{v['strip_rows']}rows)"
                                  if v["kind"] == "strip" else "")
            + (f"[fused#{seg_of[n]}]" if n in seg_of else "")
            for n, v in r.conv_strategy.items())
        print(f"[serve_vision] conv strategy: {strat}")
        if r.fused_segments:
            segs = " ".join(
                f"#{i}:{'+'.join(seg['names'])}"
                f"(halo={seg['halo_rows']}rows,"
                f"vmem={seg['vmem_bytes'] >> 10}KB)"
                for i, seg in enumerate(r.fused_segments))
            print(f"[serve_vision] fused segments: {segs}")

    if args.load is not None:
        rep = serve.poisson_load(server, prog.name, pool, rate_rps=args.load,
                                 n_requests=args.requests, seed=args.seed,
                                 deadline_ms=args.deadline_ms)
        assert rep.submitted + rep.rejected == args.requests
        assert rep.served + rep.shed == rep.submitted, \
            f"unaccounted requests: {rep}"
        lat = rep.latency_ms
        print(f"[serve_vision] offered {rep.offered_rps:,.0f} req/s x "
              f"{args.requests}: served {rep.served} "
              f"(shed {rep.shed}, rejected {rep.rejected}) at "
              f"{rep.achieved_rps:,.0f} req/s")
        if lat.get("count"):
            print(f"[serve_vision] latency p50={lat['p50']:.2f}ms "
                  f"p95={lat['p95']:.2f}ms p99={lat['p99']:.2f}ms "
                  f"max={lat['max']:.2f}ms")
        fps = rep.achieved_fps
    else:
        rep = serve.saturate(server, prog.name, pool,
                             n_requests=args.batches * args.batch)
        fps = rep.achieved_fps
    stats = server.stats(verbose=args.trace is not None)
    snap = stats["programs"][prog.name]
    print(f"[serve_vision] measured {fps:,.0f} frames/s on "
          f"{jax.default_backend()} (avg_batch "
          f"{snap['avg_batch']:.1f}, padding waste "
          f"{snap['padding_waste']:.1%}) | device model: "
          f"{r.fps:,.0f} FPS, {r.avg_power_w:.2f} W, "
          f"{r.kfps_per_w:.1f} kFPS/W")
    if args.devices > 1:
        p = stats["pool"]
        occ = " ".join(f"d{d['device']}={d['occupancy']:.0%}"
                       for d in p["per_device"])
        print(f"[serve_vision] pool: {p['devices']} devices "
              f"[{p['placement']}] steals={p['steals']} occupancy {occ}")

    if args.pipeline is not None:
        from repro.imaging import apply_float, psnr
        frames = pool[:args.batch]
        out = hosted.executable.run_per_frame(frames)
        ref = apply_float(prog.layers, prog.params, frames)
        print(f"[serve_vision] quantized-vs-float PSNR "
              f"{float(psnr(ref, out)):.2f} dB (per-frame calibration)")
    server.stop()
    if trace is not None:
        from repro import obs
        obs.disable()
        trace.export(args.trace)
        summ = trace.summary()
        dev = summ.get("serve.request.device", {"count": 0, "total_ms": 0.0})
        print("[serve_vision] stats breakdown:")
        print(serve.format_stats(stats))
        print(f"[serve_vision] trace: {len(trace.records())} records "
              f"({dev['count']} device spans, {dev['total_ms']:.1f} ms "
              f"device time) -> {args.trace}")
    return fps


if __name__ == "__main__":
    main()
