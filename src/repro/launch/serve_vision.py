"""Batched streaming vision driver over the compiled device pipeline.

Two serving modes, one API (``repro.Program`` / ``Options`` /
``Executable``):

    # CNN classification (the paper's Table-1 models)
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model lenet --scheme mx43 --batch 8 --batches 50

    # fixed-function imaging (repro.imaging pipelines)
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --pipeline edge_detect --batch 8 --batches 50

Compiles once (``Program.compile(Options) -> Executable``), then streams
host frame batches through the single jitted execute pass with
*double-buffered* feeding: batch i+1 is transferred and dispatched while
batch i is still in flight, and the host only blocks on the oldest
outstanding batch (``--depth`` controls the in-flight window; ``--depth 0``
forces the old synchronous feed for comparison). Reports measured
steady-state frames/s next to the power model's simulated device FPS and
kFPS/W — and, for imaging pipelines, the PSNR of the quantized device
output against the float reference path.

The kernel backend and conv strategy are serving flags now (``--backend``,
``--conv-strategy``), mapped through ``Options`` — no env vars needed —
and the run header prints the fully *resolved* options, so the effective
configuration is always visible in logs.

FC layers are scheduled at the served batch size (``fc_batch=--batch``) so
weight-remap DAC settles amortize across the batch; the report stays
per-frame (see ``docs/api.md``).

NB: the CRC calibration scale is per-tensor (batch included) to stay
bit-identical with the reference interpreter, so logits depend mildly on
batch composition — evaluate accuracy at the batch size you serve at
(see core.plan.CompiledPlan).
"""

from __future__ import annotations

import argparse
import collections
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import Executable, Options
from repro.core.quant import W4A4, W3A4, W2A4, MX_43, MX_42
from repro.kernels import dispatch
from repro.models.vision import MODEL_INPUT_HWC, vision_program

SCHEMES = {"w4a4": W4A4, "w3a4": W3A4, "w2a4": W2A4,
           "mx43": MX_43, "mx42": MX_42}


def stream(exe: Executable, host_batches: List[np.ndarray], n_batches: int,
           depth: int = 2) -> float:
    """Feed ``n_batches`` host batches through the executable -> frames/s.

    Double-buffered: each iteration transfers + dispatches the next batch,
    then blocks only on the result ``depth`` batches back, so host->device
    transfer of batch i+1 overlaps compute of batch i (the ROADMAP's async
    frame-feeding item). ``depth=0`` degenerates to the synchronous
    dispatch-then-block loop. Timing starts after a warmup batch, so the
    rate is steady-state (no jit trace included).
    """
    batch = host_batches[0].shape[0]
    # warmup: trace + compile, and fill device caches
    exe.run(jnp.asarray(host_batches[0])).block_until_ready()
    inflight: collections.deque = collections.deque()
    t0 = time.perf_counter()
    for i in range(n_batches):
        frames = jax.device_put(host_batches[i % len(host_batches)])
        out = exe.run(frames)
        inflight.append(out)
        if len(inflight) > depth:
            inflight.popleft().block_until_ready()
    while inflight:
        inflight.popleft().block_until_ready()
    dt = time.perf_counter() - t0
    return n_batches * batch / dt


def _imaging_frames(batch: int, size: int, seed: int) -> np.ndarray:
    from repro.data.synthetic import synthetic_textures
    imgs, _ = synthetic_textures(batch, hw=size, seed=seed)
    return imgs


def main(argv=None):
    ap = argparse.ArgumentParser()
    # choices = the IRs the executable device path supports (alexnet's IR
    # is schedule-only; see models.vision.MODEL_INPUT_HWC)
    ap.add_argument("--model", default="lenet",
                    choices=sorted(MODEL_INPUT_HWC))
    ap.add_argument("--pipeline", default=None,
                    help="serve a repro.imaging pipeline instead of a CNN")
    ap.add_argument("--scheme", default="mx43", choices=sorted(SCHEMES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--size", type=int, default=64,
                    help="imaging frame height/width (pipeline mode)")
    ap.add_argument("--depth", type=int, default=2,
                    help="in-flight batches (0 = synchronous feeding)")
    ap.add_argument("--backend", default=None,
                    choices=sorted(dispatch.BACKENDS),
                    help="kernel backend (default: REPRO_KERNEL_BACKEND / "
                         "auto: pallas on TPU, reference elsewhere)")
    ap.add_argument("--conv-strategy", default=None,
                    choices=sorted(dispatch.CONV_STRATEGIES),
                    help="conv execution strategy (default: "
                         "REPRO_CONV_STRATEGY / auto VMEM heuristic)")
    ap.add_argument("--shard-batch", action="store_true",
                    help="shard the batch axis over local devices "
                         "(no-op on 1 device)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.batch < 1 or args.batches < 1:
        ap.error("--batch and --batches must be >= 1")
    if args.depth < 0:
        ap.error("--depth must be >= 0")

    options = Options(scheme=SCHEMES[args.scheme], fc_batch=args.batch,
                      backend=args.backend, conv_strategy=args.conv_strategy,
                      shard_batch=args.shard_batch)

    if args.pipeline is not None:
        from repro.imaging import PIPELINES, apply_float, psnr
        if args.pipeline not in PIPELINES:
            ap.error(f"unknown pipeline {args.pipeline!r}; "
                     f"choose from {sorted(PIPELINES)}")
        prog = PIPELINES[args.pipeline].program(args.size, args.size, 3)
        host_batches = [_imaging_frames(args.batch, args.size, args.seed + i)
                        for i in range(2)]
        name = f"pipeline={prog.name}"
    else:
        prog = vision_program(args.model, key=jax.random.PRNGKey(args.seed))
        h, w, c = prog.input_hwc
        rng = np.random.default_rng(args.seed + 1)
        host_batches = [rng.random((args.batch, h, w, c), np.float32)
                        for _ in range(2)]
        name = f"model={args.model}"

    t0 = time.perf_counter()
    exe = prog.compile(options)
    t_compile = time.perf_counter() - t0
    fps = stream(exe, host_batches, args.batches, depth=args.depth)

    r = exe.report
    print(f"[serve_vision] {name} batch={args.batch} depth={args.depth} "
          f"compile={t_compile * 1e3:.1f}ms")
    print(f"[serve_vision] options: {options.describe()}")
    if r.conv_strategy:
        strat = " ".join(
            f"{n}={v['kind']}" + (f"({v['n_strips']}x{v['strip_rows']}rows)"
                                  if v["kind"] == "strip" else "")
            for n, v in r.conv_strategy.items())
        print(f"[serve_vision] conv strategy: {strat}")
    print(f"[serve_vision] measured {fps:,.0f} frames/s on "
          f"{jax.default_backend()} | device model: "
          f"{r.fps:,.0f} FPS, {r.avg_power_w:.2f} W, "
          f"{r.kfps_per_w:.1f} kFPS/W")
    if args.pipeline is not None:
        frames = jnp.asarray(host_batches[0])
        out = exe.run(frames)
        ref = apply_float(prog.layers, prog.params, frames)
        print(f"[serve_vision] quantized-vs-float PSNR "
              f"{float(psnr(ref, out)):.2f} dB")
    return fps


if __name__ == "__main__":
    main()
