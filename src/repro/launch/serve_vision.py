"""Batched streaming vision driver over the compiled device pipeline.

    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model lenet --scheme mx43 --batch 8 --batches 50

Compiles the model once (``core.plan.compile_model``), then streams frame
batches through the single jitted execute pass — the deployment shape of the
paper's sensor->CA->OC pipeline: acquisition and compute fused, weights
resident, zero per-frame scheduling work. Reports the *measured* host
frames/s next to the power model's simulated device FPS and kFPS/W, so the
software pipeline and the architecture model can be compared at a glance.

NB: the CRC calibration scale is per-tensor (batch included) to stay
bit-identical with the reference interpreter, so logits depend mildly on
batch composition — evaluate accuracy at the batch size you serve at
(see core.plan.CompiledPlan).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.quant import W4A4, W3A4, W2A4, MX_43, MX_42
from repro.models.vision import MODEL_INPUT_HWC, VISION_MODELS, init_vision

SCHEMES = {"w4a4": W4A4, "w3a4": W3A4, "w2a4": W2A4,
           "mx43": MX_43, "mx42": MX_42}


def stream(plan: plan_mod.CompiledPlan, params, frames: jnp.ndarray,
           n_batches: int) -> float:
    """Feed ``frames`` through the plan ``n_batches`` times -> frames/s."""
    plan_mod.execute(plan, params, frames).block_until_ready()   # warmup/jit
    t0 = time.perf_counter()
    for _ in range(n_batches):
        logits = plan_mod.execute(plan, params, frames)
    logits.block_until_ready()
    dt = time.perf_counter() - t0
    return n_batches * frames.shape[0] / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    # choices = the IRs the executable device path supports (alexnet's IR
    # is schedule-only; see models.vision.MODEL_INPUT_HWC)
    ap.add_argument("--model", default="lenet",
                    choices=sorted(MODEL_INPUT_HWC))
    ap.add_argument("--scheme", default="mx43", choices=sorted(SCHEMES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.batch < 1 or args.batches < 1:
        ap.error("--batch and --batches must be >= 1")

    scheme = SCHEMES[args.scheme]
    h, w, c = MODEL_INPUT_HWC[args.model]
    layers = VISION_MODELS[args.model]()
    params = init_vision(jax.random.PRNGKey(args.seed), layers)
    frames = jax.random.uniform(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, h, w, c))

    t0 = time.perf_counter()
    plan = plan_mod.compile_model(tuple(layers), frames.shape, scheme)
    t_compile = time.perf_counter() - t0
    fps = stream(plan, params, frames, args.batches)

    r = plan.report
    print(f"[serve_vision] {args.model} {scheme.name} batch={args.batch} "
          f"compile={t_compile * 1e3:.1f}ms")
    print(f"[serve_vision] measured {fps:,.0f} frames/s on "
          f"{jax.default_backend()} | device model: "
          f"{r.fps:,.0f} FPS, {r.avg_power_w:.2f} W, "
          f"{r.kfps_per_w:.1f} kFPS/W")
    return fps


if __name__ == "__main__":
    main()
