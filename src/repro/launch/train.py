"""End-to-end training driver (example-scale on CPU, mesh-ready at scale).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features wired together here: synthetic data pipeline (deterministic per
step), AdamW + warmup-cosine, photonic-quantization QAT (--quant w4a4),
checkpoint/restart (RestartableLoop), straggler monitor, failure injection
drills (--fail-at), and mesh execution when >1 device is present.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config, smoke_variant
from repro.data.synthetic import modality_batch
from repro.distributed.fault_tolerance import (FailureInjector,
                                               RestartableLoop,
                                               StragglerMonitor)
from repro.launch.steps import make_train_step
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedule import linear_warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quant", default="none",
                    choices=["none", "w4a4", "w3a4", "w2a4"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (drill)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_variant(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, quant_scheme=args.quant,
                              max_seq=max(cfg.max_seq, args.seq))
    print(f"[train] arch={cfg.name} quant={cfg.quant_scheme} "
          f"params~{lm_mod.count_params(cfg)/1e6:.1f}M")

    key = jax.random.PRNGKey(args.seed)
    params = lm_mod.init_lm(key, cfg)
    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, opt_cfg)

    raw_step = make_train_step(cfg, opt_cfg, args.seq)
    jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

    from repro.data.synthetic import SyntheticTextConfig, synthetic_lm_batch
    text_cfg = SyntheticTextConfig(vocab=cfg.vocab, seq=args.seq,
                                   batch=args.batch, seed=args.seed)

    def batch_fn(step: int):
        if cfg.frontend == "none":
            b = synthetic_lm_batch(text_cfg, step)   # planted structure
        else:
            b = modality_batch(cfg, args.batch, args.seq,
                               seed=args.seed * 1_000_003 + step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def loop_step(state, batch):
        params, opt_state = state["params"], state["opt"]
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        return {"params": params, "opt": opt_state}, metrics

    ckpt = CheckpointManager(args.ckpt_dir or "/tmp/repro_ckpt",
                             keep=2, save_interval_steps=args.ckpt_every)
    monitor = StragglerMonitor(
        on_straggler=lambda ev: print(f"[straggler] step {ev.step}: "
                                      f"{ev.ratio:.1f}x EWMA"))
    loop = RestartableLoop(loop_step, batch_fn, ckpt,
                           injector=FailureInjector(args.fail_at),
                           monitor=monitor)

    state = {"params": params, "opt": opt_state}
    t0 = time.time()
    state, last_step, history = loop.run(state, 0, args.steps)
    dt = time.time() - t0
    losses = [float(m["loss"]) for m in history]
    print(f"[train] {last_step} steps in {dt:.1f}s "
          f"({dt/max(len(history),1)*1e3:.0f} ms/step)")
    if losses:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(min {min(losses):.4f})")
    return losses


if __name__ == "__main__":
    main()
