import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices form the production meshes; every
cell's step function must `.lower().compile()` under GSPMD, and the compiled
artifact yields memory_analysis (fits?) + cost_analysis (FLOPs/bytes) +
the collective schedule (parsed from HLO) for the roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] [--out experiments/dryrun]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_configs
from repro.distributed.sharding import use_rules
from repro.launch import mesh as mesh_mod
from repro.launch import shardings as sh
from repro.launch import specs as specs_mod
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim.adamw import AdamWConfig, adamw_init

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the SPMD module.

    HLO lines look like:  %all-reduce.5 = f32[512,1024] all-reduce(...)
    (tuple results: f32[..], f32[..]) all-gather(...). Bytes are per-device
    (post-partitioning shapes).
    """
    totals = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)(?:-(start|done))?\(",
                     line)
        if not m:
            continue
        shape_part, opname = m.group(1), m.group(2)
        if m.group(3) == "done":
            continue                      # avoid double-counting async pairs
        if opname not in COLLECTIVE_OPS:
            continue
        # shape_part may be "(f32[2,3]{...}, f32[4]{...})" for tuples
        bytes_ = sum(_shape_bytes(s) for s in
                     re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape_part))
        totals[opname] += bytes_
        counts[opname] += 1
    totals["total"] = sum(totals[k] for k in COLLECTIVE_OPS)
    counts["total"] = sum(counts[k] for k in COLLECTIVE_OPS)
    return {"bytes": totals, "counts": counts}


def lower_cell(arch: str, shape: str, multi_pod: bool,
               donate: bool = True):
    """Build + lower + compile one cell. Returns (compiled, lowered, meta)."""
    cfg = get_config(arch)
    cell = specs_mod.SHAPES[shape]
    skip = specs_mod.cell_status(arch, shape, cfg)
    if skip:
        return None, None, {"status": skip}

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    rules = sh.build_rules(cfg, mesh, serve=(cell.kind == "decode"))

    params_s = specs_mod.params_shape(cfg)
    p_shard = sh.tree_shardings(params_s, cfg, mesh, rules)
    inputs = specs_mod.input_specs(cfg, cell)

    with use_rules(mesh, rules):
        if cell.kind == "train":
            opt_cfg = AdamWConfig()
            opt_s = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_s)
            o_shard = sh.tree_shardings(opt_s, cfg, mesh, rules)
            b_shard = sh.batch_shardings(inputs, cfg, mesh, rules)
            step = make_train_step(cfg, opt_cfg, cell.seq,
                                   grad_shardings=p_shard)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_s, opt_s, inputs)
        elif cell.kind == "prefill":
            b_shard = sh.batch_shardings(inputs, cfg, mesh, rules)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=None)
            lowered = jitted.lower(params_s, inputs)
        else:  # decode
            c_shard = sh.cache_shardings(inputs["cache"], cfg, mesh, rules)
            t_shard = sh.batch_shardings({"token": inputs["token"]}, cfg,
                                         mesh, rules)["token"]
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, t_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_s, inputs["cache"], inputs["token"])
        compiled = lowered.compile()
    return compiled, lowered, {"status": "ok", "mesh": tuple(mesh.shape.values())}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    try:
        compiled, lowered, meta = lower_cell(arch, shape, multi_pod)
        rec.update(meta)
        if compiled is not None:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_size_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_size_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_size_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_size_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "generated_code_size_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            }
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            rec["cost"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and (
                               k in ("flops", "transcendentals")
                               or k.startswith("bytes accessed"))}
            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes_from_hlo(hlo)
            from repro.launch.hlo_analysis import weighted_collectives
            rec["collectives_weighted"] = weighted_collectives(hlo)
            rec["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # noqa: BLE001 — record compile failures
        rec["status"] = f"error: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
    path.write_text(json.dumps(rec, indent=1))
    status = rec.get("status", "?")
    print(f"[dryrun] {arch} x {shape} x {mesh_name}: {status} "
          f"({rec['wall_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(specs_mod.SHAPES) + [None])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(specs_mod.SHAPES) if (args.all or not args.shape) else [args.shape]

    ok = skipped = failed = 0
    for arch in archs:
        for shape in shapes:
            rec = run_cell(arch, shape, args.multipod, out_dir)
            s = rec.get("status", "")
            if s == "ok":
                ok += 1
            elif s.startswith("skip"):
                skipped += 1
            else:
                failed += 1
    print(f"[dryrun] done: {ok} ok, {skipped} skipped, {failed} failed")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
