"""Deprecated shim — the pre-``repro.serve`` LM serving stub.

This module predates the Program API and the serving runtime: it ran a
one-off prefill+decode loop with no queueing, batching, or metrics.
Serving now goes through ``repro.serve`` (async micro-batching server
over compiled Executables, driven by ``repro.launch.serve_vision``); the
photonic-quantized LM generation demo lives in
``examples/serve_quantized_lm.py`` on top of
``repro.models.lm.greedy_generate``.

Kept as a one-shot-``DeprecationWarning`` shim (the PR-4 convention):
``generate``/``main`` still work, bit-identically, by calling the moved
internals.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.plan import _warn_deprecated
from repro.models import lm as lm_mod


def generate(params, cfg, prompt: jnp.ndarray, steps: int):
    """Deprecated shim — use ``repro.models.lm.greedy_generate``."""
    _warn_deprecated("launch.serve.generate",
                     "repro.models.lm.greedy_generate",
                     doc="docs/serving.md")
    return lm_mod.greedy_generate(params, cfg, prompt, steps)


def main(argv=None):
    """Deprecated shim — the LM decode smoke, unchanged behaviour.

    For production serving (micro-batching, backpressure, latency
    metrics) use ``repro.serve`` / ``python -m repro.launch.serve_vision``.
    """
    _warn_deprecated(
        "launch.serve.main",
        "repro.serve (python -m repro.launch.serve_vision) for serving, "
        "examples/serve_quantized_lm.py for the LM demo",
        doc="docs/serving.md")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant", default="none",
                    choices=["none", "w4a4", "w3a4", "w2a4"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_variant(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, quant_scheme=args.quant)
    key = jax.random.PRNGKey(args.seed)
    params = lm_mod.init_lm(key, cfg)
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    t0 = time.time()
    toks = lm_mod.greedy_generate(params, cfg, prompt, args.gen)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[serve] arch={cfg.name} quant={cfg.quant_scheme} "
          f"generated {toks.shape} in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. prefill+compile)")
    assert bool(jnp.all(toks >= 0)) and bool(jnp.all(toks < cfg.vocab))
    return toks


if __name__ == "__main__":
    main()
