"""Batched serving driver: prefill + decode with the photonic-quantized path.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 32 --gen 16 --quant w4a4

Serving runs weights in photonic storage (int-carrier wq + scales) when
--quant is set — the Lightator deployment mode: weights live at w_bits
(4x smaller HBM footprint at w4), activations quantize through the CRC path.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import lm as lm_mod


def generate(params, cfg, prompt: jnp.ndarray, steps: int):
    """Greedy decode. prompt: [B, T0] -> tokens [B, T0+steps]."""
    b, t0 = prompt.shape
    cache = lm_mod.init_cache(cfg, b, t0 + steps + 1)
    step_fn = jax.jit(lambda p, c, t: lm_mod.decode_step(p, c, t, cfg))
    toks = prompt
    # prefill by stepping (simple; a production path uses batched prefill)
    logits = None
    for i in range(t0):
        logits, cache = step_fn(params, cache, toks[:, i:i + 1])
    for _ in range(steps):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], axis=1)
        logits, cache = step_fn(params, cache, nxt)
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant", default="none",
                    choices=["none", "w4a4", "w3a4", "w2a4"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_variant(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, quant_scheme=args.quant)
    key = jax.random.PRNGKey(args.seed)
    params = lm_mod.init_lm(key, cfg)
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    t0 = time.time()
    toks = generate(params, cfg, prompt, args.gen)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[serve] arch={cfg.name} quant={cfg.quant_scheme} "
          f"generated {toks.shape} in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. prefill+compile)")
    assert bool(jnp.all(toks >= 0)) and bool(jnp.all(toks < cfg.vocab))
    return toks


if __name__ == "__main__":
    main()
