"""Roofline analysis per (arch x shape x mesh) — deliverable (g).

Three terms, in seconds per step, per the assignment:

    compute    = FLOPs / (chips * 197e12)          [bf16 peak, v5e]
    memory     = HBM bytes / (chips * 819e9)
    collective = collective bytes / (chips * 50e9)  [ICI link BW]

Sources & caveats (documented in EXPERIMENTS.md):
  * FLOPs: analytic MODEL_FLOPS-style accounting (6*N_active*D for train,
    2*N_active*D + attention for inference). XLA's cost_analysis counts
    while-loop (scan) bodies ONCE, so compiled FLOPs undercount by ~L; the
    raw number is still recorded as hlo_flops for reference. The analytic
    number is also what MFU is conventionally measured against.
  * HBM bytes: analytic traffic model (params, optimizer state, activation
    residuals under the remat policy, KV caches). Per-layer transients that
    stay in VMEM on TPU are excluded.
  * collective bytes: parsed from the compiled SPMD module with while-loop
    trip-count weighting (launch.hlo_analysis) — per-device shape bytes;
    all-reduce counted at 2x (ring = reduce-scatter + all-gather).
  * MODEL_FLOPS / HLO_FLOPS ratio uses the per-layer-body HLO count scaled
    by the known trip structure where available; a ratio << 1 flags
    padding/redundant compute (e.g. yi-34b's 56 heads padded to 64).

Usage:
    python -m repro.launch.roofline [--dryrun-dir experiments/dryrun]
        [--out experiments/roofline.md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES, cell_status
from repro.models.lm import active_params, count_params, model_flops


def analytic_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cell.kind == "train":
        return model_flops(cfg, cell.seq, cell.global_batch, train=True)
    if cell.kind == "prefill":
        return model_flops(cfg, cell.seq, cell.global_batch, train=False)
    return model_flops(cfg, cell.seq, cell.global_batch, train=False,
                       decode=True)


def _param_bytes(cfg, dtype_bytes=2) -> int:
    return count_params(cfg) * dtype_bytes


def _cache_bytes(cfg, batch: int, seq: int) -> int:
    total = 0
    if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
        s = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
        total += (cfg.n_layers * batch * s * cfg.n_kv_heads * cfg.head_dim
                  * 2 * 2)                               # k+v, bf16
    if cfg.family in ("ssm", "hybrid"):
        total += cfg.n_layers * batch * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4                          # f32 state
        total += cfg.n_layers * batch * (cfg.conv_kernel - 1) * cfg.conv_dim * 2
    return total


def analytic_hbm_bytes(arch: str, shape: str) -> float:
    """Per-step global HBM traffic (see module docstring for the model)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    p_total = _param_bytes(cfg)                          # bf16
    n_params = count_params(cfg)
    d = cfg.d_model
    if cell.kind == "train":
        toks = cell.global_batch * cell.seq
        # params: fwd read + bwd(dgrad) read + bwd(wgrad) read
        traffic = 3 * p_total
        # grads f32 write+read, AdamW: mu,nu,master read+write (f32)
        traffic += n_params * (4 + 4) + n_params * 6 * 4
        traffic += p_total                               # new params write
        # activation residuals (remat=full): store+reread layer inputs,
        # recompute writes ~= 3x (B,T,D) bf16 per layer
        traffic += 3 * cfg.n_layers * toks * d * 2
        return float(traffic)
    if cell.kind == "prefill":
        toks = cell.global_batch * cell.seq
        traffic = p_total + 8 * cfg.n_layers * toks * d * 2
        return float(traffic)
    # decode: weight-streaming dominates; MoE reads only routed experts'
    # weights (top_k of n_experts) amortized over the batch, capped by total
    if cfg.family == "moe":
        frac = min(1.0, cell.global_batch * cfg.top_k / cfg.n_experts)
        expert_b = (count_params(cfg) - active_params(cfg)) \
            / max(cfg.n_experts - cfg.top_k, 1) * cfg.n_experts * 2
        nonexpert_b = p_total - expert_b
        traffic = nonexpert_b + expert_b * frac
    else:
        traffic = p_total
    traffic += _cache_bytes(cfg, cell.global_batch, cell.seq)  # read cache
    return float(traffic)


def roofline_terms(arch: str, shape: str, mesh: str,
                   dryrun_dir: Path) -> Optional[Dict]:
    cfg = get_config(arch)
    skip = cell_status(arch, shape, cfg)
    if skip:
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": skip}
    rec_path = dryrun_dir / f"{arch}__{shape}__{mesh}.json"
    rec = json.loads(rec_path.read_text()) if rec_path.exists() else {}
    chips = 512 if mesh == "2x16x16" else 256

    flops = analytic_flops(arch, shape)
    hbm = analytic_hbm_bytes(arch, shape)
    cw = rec.get("collectives_weighted", {}).get("bytes", {})
    # ring all-reduce moves ~2x payload; others ~1x of their shape bytes
    coll_bytes = (cw.get("total", 0) + cw.get("all-reduce", 0))

    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = hbm / (chips * HBM_BW)
    t_coll = coll_bytes / ICI_BW            # already per-device bytes
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    n_active = active_params(get_config(arch))
    hlo_flops = rec.get("cost", {}).get("flops", 0.0)
    out = {
        "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
        "chips": chips,
        "flops_global": flops,
        "hbm_bytes_global": hbm,
        "collective_bytes_per_dev": coll_bytes,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_compute / bound if bound else 0.0,
        "model_flops_6nd": 6.0 * n_active * SHAPES[shape].global_batch
        * (SHAPES[shape].seq if SHAPES[shape].kind != "decode" else 1),
        "hlo_flops_per_dev_unscaled": hlo_flops,
        "memory_per_dev_gib": rec.get("memory", {}).get(
            "temp_size_bytes", 0) / 2**30,
        "args_per_dev_gib": rec.get("memory", {}).get(
            "argument_size_bytes", 0) / 2**30,
    }
    return out


NOTES = {
    ("yi-34b", "train_4k"): "56 heads pad to 64 on 16-way TP (+14% attn "
    "compute); FSDP all-gathers dominate -> increase per-AG size/overlap",
    ("kimi-k2-1t-a32b", "train_4k"): "EP over model axis; sort-dispatch "
    "scatter crosses data<->model: all-to-all conversion is the lever",
    ("grok-1-314b", "train_4k"): "experts replicated over model (8<16), "
    "ffn TP instead; expert all-reduce is the lever",
}


def build_table(dryrun_dir: Path, meshes=("16x16", "2x16x16")) -> str:
    from repro.configs import list_configs
    lines = ["| arch | shape | mesh | compute s | memory s | collective s "
             "| dominant | roofline frac | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch in list_configs():
        for shape in SHAPES:
            for mesh in meshes:
                r = roofline_terms(arch, shape, mesh, dryrun_dir)
                if r["status"] != "ok":
                    if mesh == meshes[0]:
                        lines.append(f"| {arch} | {shape} | - | - | - | - | "
                                     f"- | - | {r['status']} |")
                    continue
                note = NOTES.get((arch, shape), "")
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {r['t_compute_s']:.3g} "
                    f"| {r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} "
                    f"| **{r['dominant']}** "
                    f"| {r['roofline_fraction']:.2f} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    dd = Path(args.dryrun_dir)
    table = build_table(dd)
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(table + "\n")
    # full records
    from repro.configs import list_configs
    recs = []
    for arch in list_configs():
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                recs.append(roofline_terms(arch, shape, mesh, dd))
    Path(args.json_out).write_text(json.dumps(recs, indent=1))
    print(table)


if __name__ == "__main__":
    main()
