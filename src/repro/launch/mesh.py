"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across ICI domains (DCN in real
deployments); gradient cross-pod traffic is the target of
optim.compression.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init; smoke tests
run on 1 CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """A (1, N) or (d, m) mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    d = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and cand <= n:
            d = cand
            break
    return jax.make_mesh((d, n // d), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-chip effective)
HBM_PER_CHIP = 16 * 1024**3      # v5e: 16 GiB
