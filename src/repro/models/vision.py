"""The paper's own CNNs as Lightator layer-IR + trainable JAX functions.

LeNet (MNIST) and VGG9 (CIFAR10/100) are the paper's evaluation models
(Table 1, Figs. 8/9); VGG16 and AlexNet appear in the execution-time
comparison (Fig. 10). Each model is expressed twice, consistently:

  * ``*_ir()``       — the LightatorDevice layer IR (drives mapping + power)
  * ``init_/apply_`` — trainable QAT forward (same quantized semantics via
                       nn.layers conv2d/dense fake-quant)

Pooling: max pools run electronically; avg pools run on CA banks with
pre-set weights (the paper's "pooling layers are implemented within CA
banks"), which the IR encodes for the power model.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.accelerator import CASpec, ConvSpec, DenseSpec, FlattenSpec, LayerIR
from repro.core.quant import WASpec, MixedPrecisionScheme, resolve_layer_specs
from repro.nn import layers as L
from repro.nn.module import KeyGen


# ---------------------------------------------------------------------------
# Layer IRs (architecture level)
# ---------------------------------------------------------------------------

def lenet_ir(in_hw: int = 28, n_classes: int = 10,
             use_ca: bool = False) -> List[LayerIR]:
    """LeNet-5 flavored for 28x28 grayscale (paper: MNIST on LeNET)."""
    layers: List[LayerIR] = []
    hw = in_hw
    c_in = 1
    if use_ca:
        layers.append(CASpec(pool=2, rgb_to_gray=False))
        hw //= 2
    layers += [
        ConvSpec("conv1", c_in, 6, kernel=5, padding="SAME", pool=("avg", 2)),
        ConvSpec("conv2", 6, 16, kernel=5, padding="VALID", pool=("avg", 2)),
        FlattenSpec(),
    ]
    hw = hw // 2                    # conv1 pool
    hw = (hw - 4) // 2              # conv2 VALID + pool
    layers += [
        DenseSpec("fc1", 16 * hw * hw, 120),
        DenseSpec("fc2", 120, 84),
        DenseSpec("fc3", 84, n_classes, act="none"),
    ]
    return layers


def vgg9_ir(in_hw: int = 32, n_classes: int = 100,
            use_ca: bool = True) -> List[LayerIR]:
    """VGG9: 6 conv (3x3) + 3 FC — the paper's CIFAR10/100 model.

    With use_ca (the Table-1 operating point), the CA fuses RGB->gray with
    2x2 mean pooling before conv1 (c_in=1, 16x16 input for CIFAR).
    """
    layers: List[LayerIR] = []
    hw = in_hw
    c_in = 3
    if use_ca:
        layers.append(CASpec(pool=2, rgb_to_gray=True))
        hw //= 2
        c_in = 1
    chans = [(c_in, 64), (64, 64), (64, 128), (128, 128), (256, 256)]
    chans = [(c_in, 64), (64, 64), (64, 128), (128, 128),
             (128, 256), (256, 256)]
    for i, (ci, co) in enumerate(chans):
        pool = ("max", 2) if i % 2 == 1 else None
        layers.append(ConvSpec(f"conv{i+1}", ci, co, kernel=3, pool=pool))
        if pool:
            hw //= 2
    layers.append(FlattenSpec())
    layers += [
        DenseSpec("fc1", 256 * hw * hw, 512),
        DenseSpec("fc2", 512, 512),
        DenseSpec("fc3", 512, n_classes, act="none"),
    ]
    return layers


def vgg16_ir(in_hw: int = 224, n_classes: int = 1000) -> List[LayerIR]:
    cfg = [(3, 64), (64, 64), "P", (64, 128), (128, 128), "P",
           (128, 256), (256, 256), (256, 256), "P",
           (256, 512), (512, 512), (512, 512), "P",
           (512, 512), (512, 512), (512, 512), "P"]
    layers: List[LayerIR] = []
    hw = in_hw
    idx = 0
    prev_pool: Optional[Tuple[str, int]] = None
    for item in cfg:
        if item == "P":
            # attach pooling to the previous conv
            prev = layers[-1]
            assert isinstance(prev, ConvSpec)
            layers[-1] = ConvSpec(prev.name, prev.c_in, prev.c_out,
                                  prev.kernel, prev.stride, prev.padding,
                                  prev.act, ("max", 2))
            hw //= 2
            continue
        idx += 1
        layers.append(ConvSpec(f"conv{idx}", item[0], item[1], kernel=3))
    layers.append(FlattenSpec())
    layers += [
        DenseSpec("fc1", 512 * hw * hw, 4096),
        DenseSpec("fc2", 4096, 4096),
        DenseSpec("fc3", 4096, n_classes, act="none"),
    ]
    return layers


def alexnet_ir(in_hw: int = 227, n_classes: int = 1000) -> List[LayerIR]:
    """AlexNet (Fig. 10 comparison). 11x11/5x5/3x3 kernels exercise the
    multi-arm mapping path (11x11 -> 14 arms -> multi-bank strides)."""
    return [
        ConvSpec("conv1", 3, 96, kernel=11, stride=4, padding="VALID",
                 pool=("max", 2)),
        ConvSpec("conv2", 96, 256, kernel=5, pool=("max", 2)),
        ConvSpec("conv3", 256, 384, kernel=3),
        ConvSpec("conv4", 384, 384, kernel=3),
        ConvSpec("conv5", 384, 256, kernel=3, pool=("max", 2)),
        FlattenSpec(),
        DenseSpec("fc1", 256 * 6 * 6, 4096),
        DenseSpec("fc2", 4096, 4096),
        DenseSpec("fc3", 4096, n_classes, act="none"),
    ]


VISION_MODELS = {
    "lenet": lenet_ir,
    "vgg9": vgg9_ir,
    "vgg16": vgg16_ir,
    "alexnet": alexnet_ir,
}

# Per-frame [H, W, C] each IR's default arguments expect — what the batched
# serving driver (launch.serve_vision) and pipeline benchmarks feed in.
# alexnet is deliberately absent: its IR is schedule-only (Fig. 10 cycle
# counts) — the 11x11/s4 conv yields odd pool inputs, so the executable
# device path rejects it.
MODEL_INPUT_HWC = {
    "lenet": (28, 28, 1),
    "vgg9": (32, 32, 3),
    "vgg16": (224, 224, 3),
}


def vision_program(name: str, key=None, params: Optional[Dict] = None):
    """A paper CNN as a ``repro.Program`` (the unified front door).

    ``params`` reuses trained weights; otherwise the model is initialized
    from ``key`` (default ``PRNGKey(0)``). Only the executable IRs appear —
    alexnet stays schedule-only (see ``MODEL_INPUT_HWC``).
    """
    from repro.core.program import Program
    if name not in MODEL_INPUT_HWC:
        raise ValueError(
            f"unknown or schedule-only model {name!r}; executable models: "
            f"{sorted(MODEL_INPUT_HWC)}")
    layers = tuple(VISION_MODELS[name]())
    if params is None:
        params = init_vision(key if key is not None else jax.random.PRNGKey(0),
                             layers)
    return Program(layers, params, MODEL_INPUT_HWC[name], name=name)


# ---------------------------------------------------------------------------
# Trainable QAT forward (application level)
# ---------------------------------------------------------------------------

def init_vision(key, layers: List[LayerIR], dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    params: Dict[str, Dict] = {}
    for layer in layers:
        if isinstance(layer, ConvSpec):
            params[layer.name] = L.init_conv2d(kg(), layer.kernel, layer.c_in,
                                               layer.c_out, dtype=dtype)
        elif isinstance(layer, DenseSpec):
            params[layer.name] = L.init_dense(kg(), layer.fan_in,
                                              layer.fan_out, bias=True,
                                              dtype=dtype)
    return params


def apply_vision(params, layers: List[LayerIR], x: jnp.ndarray,
                 scheme: WASpec | MixedPrecisionScheme | None = None
                 ) -> jnp.ndarray:
    """QAT forward: fake-quantized convs/denses (STE), float pooling.

    Numerically equivalent clipping/rounding to the LightatorDevice integer
    path; differentiable for the paper's 6-epoch quantization-aware tuning.
    """
    from repro.core.compressive import compressive_acquire
    compute = [l for l in layers if isinstance(l, (ConvSpec, DenseSpec))]
    specs = (resolve_layer_specs(len(compute), scheme)
             if scheme is not None else [None] * len(compute))
    it = iter(specs)
    for layer in layers:
        if isinstance(layer, CASpec):
            x = compressive_acquire(x, layer.pool, layer.rgb_to_gray)
            if x.ndim == 3:
                x = x[..., None]
        elif isinstance(layer, ConvSpec):
            spec = next(it)
            x = L.conv2d(params[layer.name], x, layer.stride, layer.padding,
                         quant=spec)
            x = jax.nn.relu(x) if layer.act == "relu" else x
            if layer.pool:
                kind, size = layer.pool
                x = L.max_pool2d(x, size) if kind == "max" else L.avg_pool2d(x, size)
        elif isinstance(layer, FlattenSpec):
            x = x.reshape(x.shape[0], -1)
        elif isinstance(layer, DenseSpec):
            spec = next(it)
            x = L.dense(params[layer.name], x, quant=spec)
            if layer.act == "relu":
                x = jax.nn.relu(x)
    return x


def vision_schedules(layers: List[LayerIR], in_hw: int):
    """Layer IR -> OCSchedules (what benchmarks feed the power model)."""
    from repro.core import optical_core as ocore
    from repro.core.plan import conv_out_hw
    scheds = []
    hw = in_hw
    c_last = None
    for layer in layers:
        if isinstance(layer, CASpec):
            hw //= layer.pool
            scheds.append(ocore.schedule_ca("CA", hw, hw, layer.pool, 3))
        elif isinstance(layer, ConvSpec):
            hw = conv_out_hw(hw, layer.kernel, layer.stride, layer.padding)
            scheds.append(ocore.schedule_conv(layer.name, hw, hw, layer.c_in,
                                              layer.c_out, layer.kernel))
            if layer.pool:
                hw //= layer.pool[1]
                if layer.pool[0] == "avg":
                    scheds.append(ocore.schedule_ca(
                        f"{layer.name}.pool", hw, hw, layer.pool[1], 1))
            c_last = layer.c_out
        elif isinstance(layer, DenseSpec):
            scheds.append(ocore.schedule_fc(layer.name, layer.fan_in,
                                            layer.fan_out))
    return scheds
