"""Unified LM covering every assigned family with one scan-over-layers core.

families:
  dense    — llama-style GQA decoder (yi-34b, smollm, tinyllama, stablelm)
  moe      — GQA decoder with MoE FFN (grok-1, kimi-k2)
  ssm      — mamba2 SSD blocks, attention-free (mamba2-1.3b)
  hybrid   — parallel attention + SSM heads per layer (hymba-1.5b)
  encoder  — bidirectional encoder (hubert-xlarge; no decode path)
  vlm      — decoder with a patch-embedding prefix (internvl2-26b)

Compile-time discipline: layer params are stacked on a leading [L] axis and
the layer body runs under ``jax.lax.scan`` — one layer body is compiled no
matter the depth, which keeps the 512-device dry-run tractable. ``remat``
wraps the body in ``jax.checkpoint``.

The Lightator photonic-quantization feature threads through every projection
via ``nn.layers.dense(quant=...)`` ([W{2,3,4}:A4] fake-quant for QAT, or
int-carrier weights for serving after ``quantize_lm_params``).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.compressive import sequence_ca
from repro.distributed.sharding import shard
from repro.nn import attention as attn_mod
from repro.nn import layers as L
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod
from repro.nn.module import KeyGen, normal_init, scaled_init

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn(kg: KeyGen, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": {"w": scaled_init(d)(kg(), (d, cfg.n_heads * hd), dtype)},
        "wk": {"w": scaled_init(d)(kg(), (d, cfg.n_kv_heads * hd), dtype)},
        "wv": {"w": scaled_init(d)(kg(), (d, cfg.n_kv_heads * hd), dtype)},
        "wo": {"w": scaled_init(cfg.n_heads * hd)(kg(), (cfg.n_heads * hd, d),
                                                  dtype)},
    }


def _init_mlp(kg: KeyGen, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    p = {"w_up": {"w": scaled_init(d)(kg(), (d, f), dtype)},
         "w_down": {"w": scaled_init(f)(kg(), (f, d), dtype)}}
    if cfg.ffn == "swiglu":
        p["w_gate"] = {"w": scaled_init(d)(kg(), (d, f), dtype)}
    return p


def _init_ssm(kg: KeyGen, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, h = cfg.d_inner, cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    in_dim = 2 * di + 2 * gn + h
    return {
        "in_proj": {"w": scaled_init(d)(kg(), (d, in_dim), dtype)},
        "conv_w": normal_init(0.1)(kg(), (cfg.conv_kernel, cfg.conv_dim),
                                   jnp.float32),
        "conv_b": jnp.zeros((cfg.conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": {"w": scaled_init(di)(kg(), (di, d), dtype)},
    }


def _init_norm(kg: KeyGen, cfg: ModelConfig, dtype):
    if cfg.norm == "layer":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def _apply_norm(p, x, cfg: ModelConfig):
    return L.layernorm(p, x) if cfg.norm == "layer" else L.rmsnorm(p, x)


def _layer_init(key, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    p: Dict[str, Any] = {"norm1": _init_norm(kg, cfg, dtype)}
    if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
        p["attn"] = _init_attn(kg, cfg, dtype)
    if cfg.family in ("ssm", "hybrid"):
        p["ssm"] = _init_ssm(kg, cfg, dtype)
        if cfg.family == "hybrid":
            p["mix_norm_a"] = {"scale": jnp.ones((cfg.d_model,), dtype)}
            p["mix_norm_s"] = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.family != "ssm":
        p["norm2"] = _init_norm(kg, cfg, dtype)
        if cfg.family == "moe":
            mcfg = moe_mod.MoEConfig(cfg.n_experts, cfg.top_k, cfg.d_model,
                                     cfg.d_ff, cfg.capacity_factor)
            p["moe"] = moe_mod.init_moe(kg(), mcfg, dtype)
        else:
            p["mlp"] = _init_mlp(kg, cfg, dtype)
    return p


def init_lm(key, cfg: ModelConfig) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    kg = KeyGen(key)
    params: Dict[str, Any] = {
        "embed": {"table": normal_init(0.02)(kg(), (cfg.vocab, cfg.d_model),
                                             dtype)},
    }
    if cfg.frontend != "none":
        params["frontend"] = L.init_dense(kg(), cfg.frontend_dim, cfg.d_model,
                                          bias=True, dtype=dtype)
    # stacked layers: init one layer per key, stack — but avoid materializing
    # L copies sequentially in python for big L: vmap the init over keys.
    keys = jax.random.split(kg(), cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: _layer_init(k, cfg, dtype))(keys)
    params["final_norm"] = _init_norm(kg, cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(kg(), cfg.d_model, cfg.vocab,
                                         dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_block(p, x, cfg: ModelConfig, positions, quant,
                cache: Optional[Dict] = None, pos_scalar=None):
    """x: [B,T,D] -> [B,T,D]; if cache given, T==1 decode step."""
    b, t, _ = x.shape
    hd = cfg.head_dim
    q = L.dense(p["wq"], x, quant).reshape(b, t, cfg.n_heads, hd)
    k = L.dense(p["wk"], x, quant).reshape(b, t, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], x, quant).reshape(b, t, cfg.n_kv_heads, hd)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv", None)
    if cache is None:
        q = attn_mod.apply_rope(q, positions, cfg.rope_theta)
        k = attn_mod.apply_rope(k, positions, cfg.rope_theta)
        out = attn_mod.attention(q, k, v, causal=cfg.causal,
                                 window=cfg.sliding_window)
        new_cache = None
    else:
        pos_b = jnp.broadcast_to(pos_scalar[None, None], (b, 1))
        q = attn_mod.apply_rope(q, pos_b, cfg.rope_theta)
        k = attn_mod.apply_rope(k, pos_b, cfg.rope_theta)
        ring = cfg.sliding_window is not None
        kv = attn_mod.KVCache(cache["k"], cache["v"], pos_scalar)
        kv = attn_mod.cache_update(kv, k, v, ring=ring)
        out = attn_mod.decode_attention(q, kv, window=cfg.sliding_window)
        new_cache = {"k": kv.k, "v": kv.v}
    out = out.reshape(b, t, cfg.n_heads * hd)
    y = L.dense(p["wo"], out, quant)
    return shard(y, "batch", None, "act_embed"), new_cache


def _mlp_block(p, x, cfg: ModelConfig, quant):
    up = L.dense(p["w_up"], x, quant)
    if cfg.ffn == "swiglu":
        gate = L.dense(p["w_gate"], x, quant)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "batch", None, "ffn")
    return L.dense(p["w_down"], h, quant)


def _ssm_block(p, x, cfg: ModelConfig, quant,
               cache: Optional[Dict] = None):
    """Mamba2 block. x: [B,T,D]. Returns (y, new_cache)."""
    b, t, _ = x.shape
    di, h, pdim = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    gn = cfg.ssm_groups * cfg.ssm_state
    proj = L.dense(p["in_proj"], x, quant)       # [B,T,2di+2gn+h]
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * gn], axis=-1)
    a = -jnp.exp(p["a_log"])                     # [H]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])          # [B,T,H]
    if cache is None:
        xbc = ssm_mod.causal_conv1d(xbc, p["conv_w"], p["conv_b"])
        xbc = jax.nn.silu(xbc)
        xs, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
        xs = xs.reshape(b, t, h, pdim)
        xs = shard(xs, "batch", None, "ssm_heads", None)
        bmat = bmat.reshape(b, t, cfg.ssm_groups, cfg.ssm_state)
        cmat = cmat.reshape(b, t, cfg.ssm_groups, cfg.ssm_state)
        y, _ = ssm_mod.ssd_chunked(xs, dt, a, bmat, cmat,
                                   chunk=min(cfg.ssd_chunk, t))
        new_cache = None
    else:
        xbc_new, conv_state = ssm_mod.causal_conv1d_step(
            cache["conv"], xbc[:, 0], p["conv_w"], p["conv_b"])
        xbc_new = jax.nn.silu(xbc_new)
        xs, bvec, cvec = jnp.split(xbc_new, [di, di + gn], axis=-1)
        xs = xs.reshape(b, h, pdim)
        bvec = bvec.reshape(b, cfg.ssm_groups, cfg.ssm_state)
        cvec = cvec.reshape(b, cfg.ssm_groups, cfg.ssm_state)
        y1, ssm_state = ssm_mod.ssd_decode_step(
            cache["ssm"], xs, dt[:, 0], a, bvec, cvec)
        y = y1[:, None]                           # [B,1,H,P]
        xs = xs[:, None]
        new_cache = {"conv": conv_state, "ssm": ssm_state}
        xs = xs.reshape(b, t, h, pdim)
    y = y + xs.reshape(y.shape) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z).astype(x.dtype))
    return L.dense(p["out_proj"], y, quant).astype(x.dtype), new_cache


def _layer(p, x, cfg: ModelConfig, positions, quant,
           cache: Optional[Dict] = None, pos_scalar=None):
    """One block. Returns (x_out, aux, new_cache)."""
    aux = {"balance": jnp.zeros((), jnp.float32),
           "z": jnp.zeros((), jnp.float32),
           "dropped": jnp.zeros((), jnp.float32)}
    new_cache: Dict[str, Any] = {}
    h = _apply_norm(p["norm1"], x, cfg)
    if cfg.family in ("dense", "moe", "encoder", "vlm"):
        y, kvc = _attn_block(p["attn"], h, cfg, positions, quant,
                             cache.get("kv") if cache else None, pos_scalar)
        if kvc is not None:
            new_cache["kv"] = kvc
        x = x + y
    elif cfg.family == "ssm":
        y, sc = _ssm_block(p["ssm"], h, cfg, quant,
                           cache.get("ssm_block") if cache else None)
        if sc is not None:
            new_cache["ssm_block"] = sc
        return x + y, aux, new_cache            # mamba block = mixer only
    elif cfg.family == "hybrid":
        ya, kvc = _attn_block(p["attn"], h, cfg, positions, quant,
                              cache.get("kv") if cache else None, pos_scalar)
        ys, sc = _ssm_block(p["ssm"], h, cfg, quant,
                            cache.get("ssm_block") if cache else None)
        if kvc is not None:
            new_cache["kv"] = kvc
        if sc is not None:
            new_cache["ssm_block"] = sc
        y = 0.5 * (L.rmsnorm(p["mix_norm_a"], ya)
                   + L.rmsnorm(p["mix_norm_s"], ys))
        x = x + y
    # FFN ------------------------------------------------------------------
    h2 = _apply_norm(p["norm2"], x, cfg)
    if cfg.family == "moe":
        mcfg = moe_mod.MoEConfig(cfg.n_experts, cfg.top_k, cfg.d_model,
                                 cfg.d_ff, cfg.capacity_factor)
        if cfg.moe_dispatch == "grouped":
            cdt = (None if cfg.moe_combine_dtype == "none"
                   else jnp.dtype(cfg.moe_combine_dtype))
            out = moe_mod.moe_ffn_grouped(p["moe"], h2, mcfg, quant,
                                          combine_dtype=cdt)
        else:
            out = moe_mod.moe_ffn(p["moe"], h2, mcfg, quant)
        aux = {"balance": out.balance_loss, "z": out.z_loss,
               "dropped": out.dropped_fraction}
        x = x + out.y
    else:
        x = x + _mlp_block(p["mlp"], h2, cfg, quant)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Embedding / frontend
# ---------------------------------------------------------------------------

def embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """Token ids and/or modality embeddings -> [B, T, D] hidden states.

    batch keys: "tokens" [B,T_text] int32, and for audio/vlm "frames" /
    "patches" [B,T_m,frontend_dim] (precomputed stub embeddings).
    """
    parts = []
    if cfg.frontend != "none":
        key = "frames" if cfg.frontend == "audio" else "patches"
        m = batch[key]
        if cfg.ca_factor > 1:
            # compressive acquisition at the sensor interface (paper step 2)
            m = sequence_ca(m, cfg.ca_factor)
        parts.append(L.dense(params["frontend"], m))
    if "tokens" in batch and batch["tokens"] is not None:
        parts.append(L.embedding_lookup(params["embed"], batch["tokens"]))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return shard(x.astype(jnp.dtype(cfg.dtype)), "batch", None, "act_embed")


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def lm_forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
               return_hidden: bool = False):
    """-> (logits [B,T,V] | hidden, aux dict). Scan over stacked layers."""
    x = embed_inputs(params, batch, cfg)
    b, t, _ = x.shape
    positions = jnp.arange(t)[None, :]
    quant = cfg.quant_spec()

    def body(carry, lp):
        h, bal, z, drp = carry
        h2, aux, _ = _layer(lp, h, cfg, positions, quant)
        return (h2, bal + aux["balance"], z + aux["z"],
                drp + aux["dropped"]), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    zero = jnp.zeros((), jnp.float32)
    (x, bal, z, drp), _ = jax.lax.scan(body, (x, zero, zero, zero),
                                       params["layers"])
    x = _apply_norm(params["final_norm"], x, cfg)
    aux = {"balance": bal / cfg.n_layers, "z": z / cfg.n_layers,
           "dropped": drp / cfg.n_layers}
    if return_hidden:
        return x, aux
    logits = _lm_logits(params, x, cfg)
    return logits, aux


def _lm_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = L.embedding_logits(params["embed"], x)
    else:
        logits = L.dense(params["lm_head"], x)
    return shard(logits, "batch", None, "vocab")


def lm_loss(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            vocab_chunk: int = 0):
    """Mean CE over labeled positions (+ MoE aux). batch["labels"] [B,T_l],
    batch["loss_mask"] optional. For big-vocab archs, ``vocab_chunk``>0
    computes CE from hidden states in sequence chunks so [B,T,V] logits are
    never materialized at once.
    """
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    hidden, aux = lm_forward(params, batch, cfg, return_hidden=True)
    # align hidden to labels (vlm: labels only cover the text tail)
    t_l = labels.shape[1]
    h = hidden[:, -t_l:, :]

    if vocab_chunk and t_l > vocab_chunk:
        n_chunks = t_l // vocab_chunk

        def ce_chunk(carry, idx):
            hs = jax.lax.dynamic_slice_in_dim(h, idx * vocab_chunk,
                                              vocab_chunk, axis=1)
            ls = jax.lax.dynamic_slice_in_dim(labels, idx * vocab_chunk,
                                              vocab_chunk, axis=1)
            lg = _lm_logits(params, hs, cfg).astype(jnp.float32)
            ce = _ce(lg, ls)
            if mask is not None:
                ms = jax.lax.dynamic_slice_in_dim(mask, idx * vocab_chunk,
                                                  vocab_chunk, axis=1)
                return (carry[0] + (ce * ms).sum(), carry[1] + ms.sum()), None
            return (carry[0] + ce.sum(), carry[1] + ce.size), None

        (tot, cnt), _ = jax.lax.scan(
            ce_chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(n_chunks))
        loss = tot / jnp.maximum(cnt, 1.0)
    else:
        logits = _lm_logits(params, h, cfg).astype(jnp.float32)
        ce = _ce(logits, labels)
        if mask is not None:
            loss = (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        else:
            loss = ce.mean()
    total = loss + aux["balance"] + aux["z"]
    metrics = {"ce": loss, **aux}
    return total, metrics


def _ce(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    """Stacked per-layer caches [L, ...] + a shared position scalar."""
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    lcache: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
        s = max_len if cfg.sliding_window is None else min(
            max_len, cfg.sliding_window)
        z = jnp.zeros((cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim),
                      dtype)
        lcache["kv"] = {"k": z, "v": jnp.zeros_like(z)}
    if cfg.family in ("ssm", "hybrid"):
        lcache["ssm_block"] = {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1,
                               cfg.conv_dim), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        }
    cache["layers"] = lcache
    return cache


def decode_step(params, cache: Dict, token: jnp.ndarray, cfg: ModelConfig):
    """One serving step: token [B,1] int32 -> (logits [B,V], new cache)."""
    x = L.embedding_lookup(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", None, "act_embed")
    pos = cache["pos"]
    quant = cfg.quant_spec()

    def body(carry, xs):
        h = carry
        lp, lc = xs
        h2, _, new_lc = _layer(lp, h, cfg, None, quant, cache=lc,
                               pos_scalar=pos)
        return h2, new_lc

    x, new_layer_cache = jax.lax.scan(
        body, x, (params["layers"], cache["layers"]))
    x = _apply_norm(params["final_norm"], x, cfg)
    logits = _lm_logits(params, x, cfg)[:, 0]
    return logits, {"pos": pos + 1, "layers": new_layer_cache}


def greedy_generate(params, cfg: ModelConfig, prompt: jnp.ndarray,
                    steps: int) -> jnp.ndarray:
    """Greedy decode. prompt: [B, T0] -> tokens [B, T0+steps].

    The batched prefill+decode demo loop (previously ``launch.serve``,
    now retired onto the ``repro.serve`` runtime for the vision/imaging
    side — see examples/serve_quantized_lm.py for the photonic-quantized
    LM deployment mode this helper drives).
    """
    b, t0 = prompt.shape
    cache = init_cache(cfg, b, t0 + steps + 1)
    step_fn = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    toks = prompt
    # prefill by stepping (simple; a production path uses batched prefill)
    logits = None
    for i in range(t0):
        logits, cache = step_fn(params, cache, toks[:, i:i + 1])
    for _ in range(steps):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], axis=1)
        logits, cache = step_fn(params, cache, nxt)
    return toks


# ---------------------------------------------------------------------------
# Photonic serving storage (the Lightator deployment mode)
# ---------------------------------------------------------------------------

def quantize_lm_params(params, cfg: ModelConfig, spec,
                       carrier=jnp.int4) -> PyTree:
    """fp params -> MR storage: every projection becomes {wq, ws}.

    ``carrier``: jnp.int4 for [4:*] (2 weights/byte — the true MR density),
    int8 otherwise. Norms, embeddings and SSM conv/dt params stay fp
    (they live in the electronic part of the architecture).
    """
    def mr_quantize(w):
        """Per-(layer/expert, out-channel) symmetric quant: reduce only the
        contraction axis (-2), so stacked [L, ...] structure is preserved."""
        w32 = w.astype(jnp.float32)
        amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)
        s = jnp.maximum(amax, 1e-8) / spec.w_qmax
        q = jnp.clip(jnp.round(w32 / s), -spec.w_qmax, spec.w_qmax)
        return q.astype(carrier), s.astype(jnp.float32)

    def transform(node, path=()):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w" and path and path[-1] in (
                        "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                        "in_proj", "out_proj"):
                    q, s = mr_quantize(v)
                    out["wq"] = q
                    out["ws"] = s
                else:
                    out[k] = transform(v, path + (k,))
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(transform(v, path + (str(i),))
                              for i, v in enumerate(node))
        # MoE stacked expert weights are raw arrays named w_gate/w_up/w_down
        if path and path[-1] in ("w_gate", "w_up", "w_down") \
                and hasattr(node, "ndim") and node.ndim >= 3:
            q, s = mr_quantize(node)
            return {"wq": q, "ws": s}
        return node

    return transform(params)


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (no allocation)."""
    d, v = cfg.d_model, cfg.vocab
    n = v * d                                   # embedding
    if cfg.frontend != "none":
        n += cfg.frontend_dim * d + d
    per = d                                     # norm1
    if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
        per += d * cfg.attn_dim + 2 * d * cfg.kv_dim + cfg.attn_dim * d
    if cfg.family in ("ssm", "hybrid"):
        di, h = cfg.d_inner, cfg.ssm_heads
        gn = cfg.ssm_groups * cfg.ssm_state
        per += d * (2 * di + 2 * gn + h)        # in_proj
        per += cfg.conv_kernel * cfg.conv_dim + cfg.conv_dim
        per += 3 * h + di + di * d              # dt/a/D, norm, out_proj
        if cfg.family == "hybrid":
            per += 2 * d
    if cfg.family != "ssm":
        per += d                                # norm2
        if cfg.family == "moe":
            per += d * cfg.n_experts
            per += cfg.n_experts * (3 * d * cfg.d_ff)
        else:
            n_mats = 3 if cfg.ffn == "swiglu" else 2
            per += n_mats * d * cfg.d_ff
    n += cfg.n_layers * per + d                 # final norm
    if not cfg.tie_embeddings:
        n += d * v
    return n


def active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: only top_k experts)."""
    if cfg.family != "moe":
        return count_params(cfg)
    total = count_params(cfg)
    expert = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert
    return total - inactive


def model_flops(cfg: ModelConfig, seq: int, batch: int,
                train: bool = True, decode: bool = False) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (inference) + attention."""
    n_active = active_params(cfg) - cfg.vocab * cfg.d_model * (
        0 if cfg.tie_embeddings else 0)
    tokens = batch * (1 if decode else seq)
    mult = 6 if train else 2
    flops = mult * n_active * tokens
    # attention scores/values term: 2 * 2 * T * S * H * dh per token pair set
    if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
        s_ctx = seq
        if cfg.sliding_window is not None:
            s_ctx = min(seq, cfg.sliding_window)
        att = 4 * cfg.n_heads * cfg.head_dim * s_ctx * tokens * cfg.n_layers
        flops += att * (3 if train else 1)
    return float(flops)
