"""models — unified LM (all assigned families) + the paper's vision CNNs."""

from repro.models.lm import (init_lm, lm_forward, lm_loss, init_cache,
                             decode_step, count_params, model_flops)
