"""Pallas kernel for the Compressive Acquisitor (paper Sec. 3.2, eq. (1)).

One CA bank computes, in a single optical cycle per output pixel group,
    P_out[i,j] = sum_{di,dj,c} coeff[di,dj,c] * P_in[p*i+di, p*j+dj, c]
with pre-set coefficients (RGB->gray x mean-pool). On TPU this is a fused
strided weighted reduction: each grid step loads a [th*p, W, C] input strip
into VMEM and emits the [th, W/p] compressed strip — input pixels are read
exactly once (the "acquisition" pass), never materializing an intermediate
grayscale or pooled tensor in HBM.

Grid: (B, H_out / th). The p*p*C coefficient loop is static (<= 48 taps for
p=4, C=3), unrolled into shifted strided loads — the TPU analogue of the
CA bank's parallel wavelength taps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ca_kernel(img_ref, coef_ref, out_ref, *, pool: int, c: int, th: int,
               w_out: int):
    """img_ref: [1, th*p, w_out*p, C]; coef_ref: [p, p, C] (SMEM-ish small);
    out_ref: [1, th, w_out]."""
    img = img_ref[0]                                    # [th*p, w*p, C]
    acc = jnp.zeros((th, w_out), jnp.float32)
    for di in range(pool):
        for dj in range(pool):
            for ch in range(c):
                tap = jax.lax.slice(img, (di, dj, ch),
                                    (img.shape[0], img.shape[1], ch + 1),
                                    (pool, pool, 1))[..., 0]
                acc = acc + tap.astype(jnp.float32) * coef_ref[di, dj, ch]
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pool", "th", "interpret"))
def ca_pool_kernel(img: jnp.ndarray, coeffs: jnp.ndarray, pool: int = 2,
                   th: int = 8, interpret: bool = True) -> jnp.ndarray:
    """img [B, H, W, C] -> [B, H/pool, W/pool] fused weighted acquisition."""
    b, h, w, c = img.shape
    assert h % pool == 0 and w % pool == 0
    h_out, w_out = h // pool, w // pool
    th = min(th, h_out)
    while h_out % th:
        th -= 1
    grid = (b, h_out // th)
    return pl.pallas_call(
        functools.partial(_ca_kernel, pool=pool, c=c, th=th, w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, th * pool, w, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((pool, pool, c), lambda i, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, w_out), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out), img.dtype),
        interpret=interpret,
    )(img, coeffs)
