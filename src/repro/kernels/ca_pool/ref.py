"""Oracle for ca_pool: core.compressive.compressive_acquire is the reference."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.compressive import ca_coefficients, compressive_acquire


def ca_pool_ref(img: jnp.ndarray, pool: int = 2,
                rgb_to_gray: bool | None = None) -> jnp.ndarray:
    out = compressive_acquire(img, pool, rgb_to_gray)
    if out.ndim == 4:                       # per-channel pooling: reduce too
        raise ValueError("ca_pool kernel covers the fused gray path; "
                         "use rgb_to_gray semantics")
    return out


def ca_pool_ref_generic(img: jnp.ndarray, coeffs: jnp.ndarray,
                        pool: int) -> jnp.ndarray:
    """Arbitrary pre-set coefficients (pure einsum oracle)."""
    *lead, h, w, c = img.shape
    x = img.reshape(*lead, h // pool, pool, w // pool, pool, c)
    return jnp.einsum("...hpwqc,pqc->...hw", x, coeffs)
