"""Public wrapper for the Compressive Acquisitor kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.compressive import ca_coefficients
from repro.kernels.ca_pool import kernel as K
from repro.kernels.dispatch import default_interpret


def ca_pool(img: jnp.ndarray, pool: int = 2,
            rgb_to_gray: bool | None = None,
            coeffs: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fused RGB->gray + pool x pool mean pooling. img [B,H,W,C] -> [B,H',W'].

    ``coeffs`` overrides the pre-set CA weights (the paper's "configurable"
    compression: any strided weighted acquisition).
    """
    c = img.shape[-1]
    if coeffs is None:
        if rgb_to_gray is None:
            rgb_to_gray = (c == 3)
        coeffs = (ca_coefficients(pool, c) if rgb_to_gray
                  else jnp.ones((pool, pool, c), jnp.float32) / (pool * pool * c))
    return K.ca_pool_kernel(img, coeffs.astype(jnp.float32), pool=pool,
                            interpret=default_interpret())
