from repro.kernels.ca_pool.ops import ca_pool
