"""Backend dispatch for the Lightator compute kernels.

One place decides *how* the photonic integer math actually runs:

  pallas     — the Pallas TPU kernels (photonic_mvm / conv_bank / ca_pool).
               On a real TPU they compile to MXU code; elsewhere they run in
               interpret mode, which is a correctness tool, not a perf path.
  reference  — the pure-jnp oracles (ref.py modules / core.compressive).
               Bit-identical to the Pallas kernels for the integer MVM path
               (both accumulate exact integers), and fast under XLA on CPU.

Selection order:

  1. ``set_backend("pallas"|"reference"|None)`` — programmatic override
     (``repro.Options(backend=...)`` routes through here for the duration
     of an ``Executable.run``).
  2. ``REPRO_KERNEL_BACKEND`` env var.
  3. default: ``pallas`` on TPU, ``reference`` everywhere else.

``default_interpret()`` is the single source of truth for the Pallas
``interpret=`` flag (previously three duplicated ``_INTERPRET`` module
globals): interpret off on TPU, on elsewhere, overridable for debugging with
``REPRO_FORCE_INTERPRET=1|0``.

Conv strategy (pallas backend only — the reference backend is always
``lax.conv_general_dilated``):

  resident   — im2col into the photonic MVM kernel: the whole patch matrix
               is materialized (k*k x the input), right for the paper's
               <=32x32 evaluation frames where everything fits on-chip.
  strip      — the strip-mined conv_bank kernel: output rows are tiled into
               strips, each input strip + (k-1)-row halo is DMA'd into VMEM
               once and reused across output-channel blocks; no patch matrix
               ever exists. The large-frame path (VGG16/AlexNet layers,
               >=256x256 sensor frames) and the native depthwise path.
  auto       — per-conv VMEM-budget heuristic (``select_conv_strategy``):
               strip when the per-frame im2col patch matrix would blow the
               budget, and always for depthwise (the strip kernel replaces
               the grouped per-channel im2col loop outright).

``REPRO_CONV_STRATEGY=auto|resident|strip|fused`` forces the choice
globally; ``REPRO_CONV_VMEM_BUDGET`` (bytes) resizes the heuristic's budget.

Chain fusion (the megakernel path):

  fused      — runs of chainable convs execute as ONE kernel launch per
               segment: every intermediate stays in VMEM (pallas) or inside
               one fused XLA computation (reference), with the requant +
               activation epilogue fused after each stage instead of
               round-tripping through HBM between layers.
               ``select_fused_segments`` picks the runs; ``conv_chain``
               executes one. The inter-stage CRC requant scale is a
               *whole-frame* max, so a fused segment processes whole frames
               stage-by-stage inside the launch (a stage barrier, not a
               halo-grown strip pyramid) — which is also why the fused path
               only runs under per-frame calibration or batch 1: per-tensor
               calibration at batch > 1 couples frames through the
               batch-wide max, and the executor falls back to the unfused
               per-layer path (bit-identical by construction).
               ``REPRO_CONV_STRATEGY=fused`` (or ``Options(fuse="on")``)
               forces every legal run to fuse; ``auto`` fuses only runs
               whose stages are small enough that the tap-loop formulation
               also wins on the reference/CPU backend.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import obs

BACKENDS = ("pallas", "reference")
CONV_STRATEGIES = ("auto", "resident", "strip", "fused")
FUSE_MODES = ("auto", "on", "off")

# Heuristic budget: what we let one conv's working set claim of the ~16 MB
# VMEM. Half goes to the strip (input rows + halo), the rest covers the
# weight block, accumulator and pipelining headroom.
DEFAULT_CONV_VMEM_BUDGET = 4 << 20

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# Programmatic overrides are *thread-local*: an Executable pinning its
# backend/interpret for the duration of a run must not leak the pin into
# (or have it clobbered by) a concurrently-running Executable on another
# thread of a threaded server.
_overrides = threading.local()


def default_interpret() -> bool:
    """Pallas ``interpret=`` flag: False on real TPU, True elsewhere.

    Resolution order: ``set_interpret`` / ``use_interpret`` programmatic
    override (what ``repro.Options(interpret=...)`` maps to; per-thread),
    then the ``REPRO_FORCE_INTERPRET`` env var (``1`` forces interpret mode
    even on TPU for debugging, ``0`` forces compiled mode), then the
    platform.
    """
    override = getattr(_overrides, "interpret", None)
    if override is not None:
        return override
    env = os.environ.get("REPRO_FORCE_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    return jax.default_backend() != "tpu"


def set_interpret(value: Optional[bool]) -> None:
    """Force the Pallas interpret flag; ``None`` restores auto-selection."""
    _overrides.interpret = value


@contextlib.contextmanager
def use_interpret(value: bool) -> Iterator[None]:
    """Context manager form of :func:`set_interpret` (per-thread)."""
    prev = getattr(_overrides, "interpret", None)
    set_interpret(value)
    try:
        yield
    finally:
        set_interpret(prev)


def get_backend() -> str:
    """Resolve the active kernel backend (see module docstring)."""
    override = getattr(_overrides, "backend", None)
    if override is not None:
        return override
    env = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}; expected one of {BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def set_backend(name: Optional[str]) -> None:
    """Force a backend programmatically; ``None`` restores auto-selection."""
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected {BACKENDS}")
    _overrides.backend = name


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[None]:
    """Context manager form of :func:`set_backend` (per-thread)."""
    prev = getattr(_overrides, "backend", None)
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


# ---------------------------------------------------------------------------
# Conv strategy selection (resident vs strip-mined)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvStrategy:
    """A resolved conv execution strategy + its strip geometry.

    ``strip_rows`` is output rows per strip; ``n_strips`` tiles the output
    height (the last strip may be padding, sliced off after the kernel).
    Both are 0 for the resident strategy.
    """

    kind: str                     # "resident" | "strip"
    strip_rows: int = 0
    n_strips: int = 0


def conv_strategy_mode() -> str:
    """The forced/auto strategy mode: ``REPRO_CONV_STRATEGY`` or ``auto``."""
    env = os.environ.get("REPRO_CONV_STRATEGY", "").strip().lower()
    if not env:
        return "auto"
    if env not in CONV_STRATEGIES:
        raise ValueError(
            f"REPRO_CONV_STRATEGY={env!r}; expected one of {CONV_STRATEGIES}")
    return env


def conv_vmem_budget() -> int:
    """Heuristic VMEM budget in bytes (``REPRO_CONV_VMEM_BUDGET`` override)."""
    env = os.environ.get("REPRO_CONV_VMEM_BUDGET", "").strip()
    if env:
        try:
            budget = int(env)
        except ValueError:
            raise ValueError(
                f"REPRO_CONV_VMEM_BUDGET={env!r} is not an integer; "
                f"expected a byte count like 4194304") from None
        if budget <= 0:
            raise ValueError(f"REPRO_CONV_VMEM_BUDGET={env!r} must be > 0")
        return budget
    return DEFAULT_CONV_VMEM_BUDGET


def _strip_geometry(h_out: int, w_out: int, c_in: int, kernel: int,
                    stride: int, budget: int) -> ConvStrategy:
    """Largest strip (output rows) whose input strip + halo fits budget/2."""
    wp = (w_out - 1) * stride + kernel        # padded input width
    row_bytes = wp * c_in * 4                 # f32-carried codes
    # input rows needed for r output rows: (r-1)*stride + kernel
    rows = (budget // 2 // max(row_bytes, 1) - kernel) // stride + 1
    rows = max(1, min(int(rows), h_out))
    if rows >= 8:
        rows -= rows % 8                      # f32 sublane-friendly strips
    n_strips = -(-h_out // rows)
    return ConvStrategy("strip", rows, n_strips)


def select_conv_strategy(h_out: int, w_out: int, c_in: int, c_out: int,
                         kernel: int, stride: int = 1, groups: int = 1,
                         mode: Optional[str] = None,
                         budget: Optional[int] = None) -> ConvStrategy:
    """Resolve the conv strategy for one layer's geometry.

    ``h_out``/``w_out`` are the conv's own output dims (pre-pooling);
    ``c_in`` counts *all* input channels (also for depthwise, where the
    whole channel stack rides in each strip). Resolution order: explicit
    ``mode`` arg > ``REPRO_CONV_STRATEGY`` > VMEM-budget heuristic. The
    heuristic sends a conv to the strip path when its per-frame im2col
    patch matrix (h_out*w_out*k*k*c_in f32) would not sit in the budget,
    and sends depthwise convs there unconditionally — the strip kernel's
    per-tap VPU accumulate replaces the per-channel im2col loop.
    """
    mode = mode if mode is not None else conv_strategy_mode()
    if mode not in CONV_STRATEGIES:
        raise ValueError(
            f"unknown conv strategy {mode!r}; expected {CONV_STRATEGIES}")
    budget = budget if budget is not None else conv_vmem_budget()
    if mode == "resident":
        return ConvStrategy("resident")
    if mode in ("auto", "fused"):
        # "fused" is a *chain* mode (select_fused_segments); the per-conv
        # fallback strategy — what runs when a conv is outside every fused
        # segment, or fusion is disabled at runtime — resolves as auto
        depthwise = groups > 1 and groups == c_in
        patch_bytes = h_out * w_out * kernel * kernel * c_in * 4
        if not depthwise and patch_bytes <= budget:
            return ConvStrategy("resident")
    return _strip_geometry(h_out, w_out, c_in, kernel, stride, budget)


# ---------------------------------------------------------------------------
# Chain fusion: segment selection (the megakernel path)
# ---------------------------------------------------------------------------

# Auto-fusion channel cap: the fused tap-loop formulation (k*k shifted
# slice-matmul accumulates) beats the per-layer conv for the small channel
# counts of imaging chains and early CNN layers on every backend, but loses
# to a tuned dense conv once both channel dims are large. Measured on CPU
# XLA, the crossover sits near c_in*c_out ~ 1-2K; past it, auto leaves the
# run unfused ("on" ignores the cap — the caller asked for one launch).
FUSED_AUTO_CHANNEL_CAP = 2048

# Activations the fused epilogue supports. tanh is excluded: the fused and
# unfused paths must stay bit-identical, and a transcendental evaluated
# inside a Pallas kernel is not guaranteed to match XLA's lowering bit for
# bit the way the piecewise relu/abs/sign are.
FUSABLE_ACTS = ("relu", "abs", "sign", "none")


@dataclasses.dataclass(frozen=True)
class ChainGeom:
    """One conv stage's static geometry, as seen by the fusion pass.

    ``h_in``/``w_in`` are the stage's *input* dims (pre-padding);
    ``pads`` is the resolved ((lo, hi), (lo, hi)) spatial padding; ``pool``
    is the post-activation pool spec (kind, size) or None — all exactly what
    the plan's ``ConvStep`` carries, so the compile pass and the eager
    interpreter resolve identical segments from identical walks.
    """

    name: str
    h_in: int
    w_in: int
    c_in: int
    c_out: int
    kernel: int
    stride: int
    pads: Tuple[Tuple[int, int], Tuple[int, int]]
    groups: int = 1
    act: str = "relu"
    pool: Optional[Tuple[str, int]] = None

    @property
    def depthwise(self) -> bool:
        return self.groups > 1 and self.groups == self.c_in \
            and self.c_out == self.groups

    def out_hw(self) -> Tuple[int, int]:
        (plo, phi), (qlo, qhi) = self.pads
        h = (self.h_in + plo + phi - self.kernel) // self.stride + 1
        w = (self.w_in + qlo + qhi - self.kernel) // self.stride + 1
        if self.pool is not None:
            h, w = h // self.pool[1], w // self.pool[1]
        return h, w

    def stage_bytes(self) -> int:
        """f32 working set of this stage inside the megakernel: padded
        input frame + output frame + weight block."""
        (plo, phi), (qlo, qhi) = self.pads
        in_b = (self.h_in + plo + phi) * (self.w_in + qlo + qhi) \
            * self.c_in * 4
        h_out = (self.h_in + plo + phi - self.kernel) // self.stride + 1
        w_out = (self.w_in + qlo + qhi - self.kernel) // self.stride + 1
        out_b = h_out * w_out * self.c_out * 4
        w_b = self.kernel * self.kernel * (self.c_in // self.groups) \
            * self.c_out * 4
        return in_b + out_b + w_b


@dataclasses.dataclass(frozen=True)
class FusedSegmentSpec:
    """A resolved fused run: ``length`` consecutive conv steps starting at
    plan-step index ``start`` execute as one kernel launch.

    ``halo_rows`` is the chain's input-halo growth: the extra input rows one
    output row needs through every stage (the strip formulation's per-strip
    overfetch — sum of (k-1) per stride-1 stage, compounded by strides and
    pools). ``vmem_bytes`` is the peak per-stage f32 working set.
    """

    start: int
    names: Tuple[str, ...]
    halo_rows: int
    vmem_bytes: int

    @property
    def length(self) -> int:
        return len(self.names)


def conv_fuse_mode(strategy_mode: Optional[str] = None) -> str:
    """Resolve the chain-fusion mode from the conv strategy mode.

    ``fused`` forces fusion on ("on": every legal run, any length);
    ``resident``/``strip`` force it off (the caller pinned a per-conv
    execution plan — honoring it means no cross-conv fusion); ``auto``
    defers to the heuristic (runs of >= 2 cheap stages fuse).
    """
    mode = (strategy_mode if strategy_mode is not None
            else conv_strategy_mode())
    if mode == "fused":
        return "on"
    if mode in ("resident", "strip"):
        return "off"
    return "auto"


def _chain_halo_rows(geoms: Sequence[ChainGeom]) -> int:
    """Input rows one output row needs through the chain, minus one.

    Back-substitution of the per-stage row recurrence
    ``rows_in = (rows_out - 1) * stride + kernel`` (pool expands
    ``rows_out`` by its window first) from the last stage to the first.
    """
    rows = 1
    for g in reversed(tuple(geoms)):
        if g.pool is not None:
            rows *= g.pool[1]
        rows = (rows - 1) * g.stride + g.kernel
    return rows - 1


def _fusable(g: ChainGeom, budget: int, auto: bool) -> bool:
    if g.groups != 1 and not g.depthwise:
        return False                       # general grouped convs: unfused
    if g.act not in FUSABLE_ACTS:
        return False
    if g.pool is not None and g.pool[0] not in ("max", "avg"):
        return False
    if auto:
        if not g.depthwise and g.c_in * g.c_out > FUSED_AUTO_CHANNEL_CAP:
            return False
        if g.stage_bytes() > budget:
            return False
    return True


def select_fused_segments(geoms: Sequence[Optional[ChainGeom]],
                          mode: Optional[str] = None,
                          budget: Optional[int] = None
                          ) -> Tuple[FusedSegmentSpec, ...]:
    """Segment a plan's step list into fusable conv runs.

    ``geoms`` is aligned with the plan's steps: a :class:`ChainGeom` for
    every conv step, ``None`` for everything else (CA, upsample, flatten,
    dense — all of which break a run). ``mode`` is a fuse mode ("auto" |
    "on" | "off"; default :func:`conv_fuse_mode` from the environment):
    auto fuses maximal runs of >= 2 stages that pass the channel cap and
    VMEM budget; "on" fuses every legal run including singletons (the
    epilogue still fuses into the single launch); "off" returns no
    segments.
    """
    mode = mode if mode is not None else conv_fuse_mode()
    if mode not in FUSE_MODES:
        raise ValueError(f"unknown fuse mode {mode!r}; expected {FUSE_MODES}")
    if mode == "off":
        return ()
    budget = budget if budget is not None else conv_vmem_budget()
    auto = mode == "auto"
    min_len = 2 if auto else 1
    segments, run_start, run = [], 0, []
    def _flush():
        if len(run) >= min_len:
            segments.append(FusedSegmentSpec(
                run_start, tuple(g.name for g in run),
                _chain_halo_rows(run),
                max(g.stage_bytes() for g in run)))
        run.clear()
    for i, g in enumerate(geoms):
        if g is not None and _fusable(g, budget, auto):
            if not run:
                run_start = i
            run.append(g)
        else:
            _flush()
    _flush()
    return tuple(segments)


# ---------------------------------------------------------------------------
# Dispatch entry points
# ---------------------------------------------------------------------------

def matmul_int(a_codes: jnp.ndarray, wq: jnp.ndarray) -> jnp.ndarray:
    """Integer-exact MAC: [M, K] codes x [K, N] weight levels -> f32 [M, N].

    This is the raw OC accumulate (arm dots + BPD + summation tree) with NO
    dequant — both backends return the exact integer sum carried in f32, so
    callers can apply scale factors in whatever association order their
    reference semantics demand. Exactness envelope: with the device's CRC
    codes (<= 15) and MR levels (|wq| <= 7, i.e. w_bits <= 4) every partial
    sum stays below 105 * K; for K up to ~160K that is under 2^24, exact in
    f32 and int32 alike. Callers pushing w_bits to 8 (|wq| <= 127, bound
    15 * 127 * K) must keep K below ~8.8K themselves.
    """
    if get_backend() == "pallas":
        from repro.kernels.photonic_mvm.ops import photonic_mvm_prequant
        ones = jnp.ones((wq.shape[-1],), jnp.float32)
        return photonic_mvm_prequant(a_codes.astype(jnp.int8),
                                     wq.astype(jnp.int8), ones, act_scale=1.0)
    from repro.kernels.photonic_mvm.ref import mvm_int_ref
    ones = jnp.ones((wq.shape[-1],), jnp.float32)
    return mvm_int_ref(a_codes.astype(jnp.int32), wq.astype(jnp.int32), ones)


def conv_int(codes: jnp.ndarray, wq: jnp.ndarray, stride: int,
             pads, groups: int = 1,
             strategy: Optional[ConvStrategy] = None) -> jnp.ndarray:
    """Integer-exact conv accumulate: [B,H,W,Cin] codes x [k,k,Cin/g,Cout]
    weight levels -> f32 [B,H',W',Cout], NO dequant (see matmul_int).

    ``groups`` is the feature-group count (1 = dense conv; groups == Cin with
    [k,k,1,Cin] weights = depthwise — the imaging pipelines' per-channel
    fixed-function filters: each channel is an independent single-channel
    kernel on the OC banks).

    ``strategy`` picks the pallas execution plan (see module docstring):
    ``None`` resolves per call via :func:`select_conv_strategy` (env /
    VMEM-budget heuristic); ``core.plan`` passes the strategy it resolved
    and recorded at compile time. The reference backend ignores it —
    ``lax.conv_general_dilated`` on the float-carried codes is the exact op
    the eager interpreter runs. Both pallas strategies accumulate the same
    exact integers, so strategy choice can never change the results.
    """
    k, _, cg, c_out = wq.shape
    if c_out % groups or codes.shape[-1] != cg * groups:
        raise ValueError(
            f"conv_int: groups={groups} must divide c_out={c_out} and "
            f"match c_in={codes.shape[-1]} against weight slice {cg}")
    if get_backend() == "pallas":
        (plo, phi), (qlo, qhi) = pads
        h_out = (codes.shape[1] + plo + phi - k) // stride + 1
        w_out = (codes.shape[2] + qlo + qhi - k) // stride + 1
        if strategy is None:
            strategy = select_conv_strategy(h_out, w_out, codes.shape[-1],
                                            c_out, k, stride, groups)
        # dispatch.conv.* counters tick at jit-TRACE time: they count how
        # many conv layers each strategy was chosen for (per compiled
        # trace), not per-batch executions — see docs/observability.md
        obs.counter(f"dispatch.conv.{strategy.kind}").inc()
        if strategy.kind == "strip":
            return _conv_int_strip(codes, wq, stride, pads, groups, strategy,
                                   h_out)
        b = codes.shape[0]
        if groups == 1:
            patches, h_out, w_out = _im2col(codes, k, stride, pads)
            acc = matmul_int(patches, wq.reshape(k * k * cg, c_out))
            return acc.reshape(b, h_out, w_out, c_out)
        og = c_out // groups
        outs = []
        for g in range(groups):
            patches, h_out, w_out = _im2col(
                codes[..., g * cg:(g + 1) * cg], k, stride, pads)
            acc = matmul_int(patches,
                             wq[..., g * og:(g + 1) * og].reshape(
                                 k * k * cg, og))
            outs.append(acc.reshape(b, h_out, w_out, og))
        return jnp.concatenate(outs, axis=-1)
    obs.counter("dispatch.conv.reference").inc()
    return jax.lax.conv_general_dilated(
        codes.astype(jnp.float32), wq.astype(jnp.float32),
        window_strides=(stride, stride), padding=tuple(pads),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _conv_int_strip(codes: jnp.ndarray, wq: jnp.ndarray, stride: int, pads,
                    groups: int, strat: ConvStrategy,
                    h_out: int) -> jnp.ndarray:
    """Raw integer accumulate through the strip-mined conv_bank kernels.

    Pads the rows so ``n_strips`` strips tile exactly (zero rows contribute
    zero partials; the surplus output rows are sliced off), then routes:
    dense -> MXU strip kernel; depthwise -> VPU strip kernel; general
    grouped -> one dense strip call per group slice.
    """
    from repro.kernels.conv_bank import strip_kernel as SK
    k, _, cg, c_out = wq.shape
    (plo, phi), (qlo, qhi) = pads
    xp = SK.pad_rows_for_strips(
        jnp.pad(codes, ((0, 0), (plo, phi), (qlo, qhi), (0, 0))),
        k, stride, strat.strip_rows, strat.n_strips)
    interp = default_interpret()
    kw = dict(kk=k, stride=stride, strip_h=strat.strip_rows,
              quantized=False, interpret=interp)
    if groups == 1:
        ones = jnp.ones((c_out,), jnp.float32)
        out = SK.conv_strip_kernel(xp, wq.astype(jnp.float32), ones, **kw)
    elif cg == 1 and groups == codes.shape[-1] and c_out == groups:
        # plain depthwise (multiplier 1) — the VPU tap-accumulate kernel;
        # channel-multiplier depthwise (c_out = m*groups) falls through to
        # the per-group loop below (each group is a 1-in m-out dense conv)
        ones = jnp.ones((c_out,), jnp.float32)
        out = SK.conv_strip_depthwise_kernel(
            xp, wq.reshape(k * k, c_out).astype(jnp.float32), ones, **kw)
    else:
        og = c_out // groups
        ones = jnp.ones((og,), jnp.float32)
        out = jnp.concatenate([
            SK.conv_strip_kernel(
                xp[..., g * cg:(g + 1) * cg],
                wq[..., g * og:(g + 1) * og].astype(jnp.float32), ones, **kw)
            for g in range(groups)], axis=-1)
    return out[:, :h_out]


def _im2col(codes: jnp.ndarray, k: int, stride: int, pads):
    """[B,H,W,Cin] -> ([B*H'*W', k*k*Cin], H', W').

    Tap order (di, dj, cin) matches ``wq.reshape(k*k*cin, cout)`` so the
    patch @ weight matmul reproduces the conv accumulate exactly.
    """
    (plo, phi), (qlo, qhi) = pads
    xp = jnp.pad(codes, ((0, 0), (plo, phi), (qlo, qhi), (0, 0)))
    h_out = (xp.shape[1] - k) // stride + 1
    w_out = (xp.shape[2] - k) // stride + 1
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(xp[:, di:di + (h_out - 1) * stride + 1:stride,
                           dj:dj + (w_out - 1) * stride + 1:stride, :])
    patches = jnp.concatenate(cols, axis=-1)
    return patches.reshape(-1, k * k * codes.shape[-1]), h_out, w_out


def conv_chain(codes: jnp.ndarray, act_scale: jnp.ndarray, stages: Sequence,
               a_qmax, per_frame: bool):
    """Execute one fused conv segment as a single launch: quantized input
    codes -> (codes, act_scale) after the last stage's CRC requant.

    ``stages`` is a sequence of ``(geom, wq, ws, bias)`` tuples — the
    :class:`ChainGeom` static geometry plus the stage's quantized weight
    levels, weight scale and optional bias. Each stage runs the complete
    per-layer recipe (integer tap-loop conv accumulate -> dequant -> bias ->
    activation -> pool -> CRC requant) with expressions matching the
    unfused ``core.plan._execute_steps`` epilogue term for term, so the
    fused output is bit-identical to running the stages as separate steps.

    The inter-stage requant scale is a whole-frame max, which is why the
    caller must guarantee frame-independent calibration: ``per_frame=True``
    (any batch) or batch 1 (where per-tensor and per-frame calibration are
    the same reduction). Returns ``act_scale`` shaped [B, 1, 1, 1] when
    ``per_frame`` else a 0-d scalar — matching the unfused path's scale
    shapes exactly so downstream traced expressions are unchanged.
    """
    if not per_frame and codes.shape[0] != 1:
        raise ValueError(
            "conv_chain: per-tensor calibration fuses only at batch 1 "
            f"(got batch {codes.shape[0]}); the executor should have "
            "fallen back to the unfused path")
    # one tick per conv stage executed through the fused megakernel path
    # (trace time, like dispatch.conv.resident/strip above)
    obs.counter("dispatch.conv.fused").inc(len(stages))
    if get_backend() == "pallas":
        from repro.kernels.conv_bank.fused_kernel import conv_chain_kernel
        out, scale = conv_chain_kernel(codes, act_scale, stages, a_qmax,
                                       interpret=default_interpret())
    else:
        from repro.kernels.conv_bank.ref import conv_chain_ref
        out, scale = conv_chain_ref(codes, act_scale, stages, a_qmax)
    if not per_frame:
        scale = scale[0, 0, 0, 0]          # 0-d, like jnp.max over the tensor
    return out, scale


def ca_acquire(img: jnp.ndarray, pool: int,
               rgb_to_gray: bool | None = None) -> jnp.ndarray:
    """Compressive Acquisitor dispatch. img [B, H, W, C].

    Returns [B, H', W'] (fused gray) or [B, H', W', C] (per-channel pooling),
    matching ``core.compressive.compressive_acquire``. The Pallas kernel only
    implements the fused single-output modes (rgb_to_gray or C == 1); the
    per-channel multi-channel mode always uses the reference.

    NB: unlike matmul_int/conv_int this is *float* math — the kernel's tap
    summation order differs from the reference einsum by ~1 ulp, so the two
    backends agree only up to downstream CRC requant.
    """
    c = img.shape[-1]
    if rgb_to_gray is None:
        rgb_to_gray = (c == 3)
    if get_backend() == "pallas" and (rgb_to_gray or c == 1):
        from repro.kernels.ca_pool.ops import ca_pool
        out = ca_pool(img, pool=pool, rgb_to_gray=rgb_to_gray)
        return out if rgb_to_gray else out[..., None]
    from repro.core.compressive import compressive_acquire
    return compressive_acquire(img, pool, rgb_to_gray)
