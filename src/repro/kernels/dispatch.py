"""Backend dispatch for the Lightator compute kernels.

One place decides *how* the photonic integer math actually runs:

  pallas     — the Pallas TPU kernels (photonic_mvm / conv_bank / ca_pool).
               On a real TPU they compile to MXU code; elsewhere they run in
               interpret mode, which is a correctness tool, not a perf path.
  reference  — the pure-jnp oracles (ref.py modules / core.compressive).
               Bit-identical to the Pallas kernels for the integer MVM path
               (both accumulate exact integers), and fast under XLA on CPU.

Selection order:

  1. ``set_backend("pallas"|"reference"|None)`` — programmatic override.
  2. ``REPRO_KERNEL_BACKEND`` env var.
  3. default: ``pallas`` on TPU, ``reference`` everywhere else.

``default_interpret()`` is the single source of truth for the Pallas
``interpret=`` flag (previously three duplicated ``_INTERPRET`` module
globals): interpret off on TPU, on elsewhere, overridable for debugging with
``REPRO_FORCE_INTERPRET=1|0``.
"""

from __future__ import annotations

import contextlib
import os
from typing import Iterator, Optional

import jax
import jax.numpy as jnp

BACKENDS = ("pallas", "reference")

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

_backend_override: Optional[str] = None


def default_interpret() -> bool:
    """Pallas ``interpret=`` flag: False on real TPU, True elsewhere.

    ``REPRO_FORCE_INTERPRET=1`` forces interpret mode even on TPU (debugging);
    ``REPRO_FORCE_INTERPRET=0`` forces compiled mode.
    """
    env = os.environ.get("REPRO_FORCE_INTERPRET", "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    return jax.default_backend() != "tpu"


def get_backend() -> str:
    """Resolve the active kernel backend (see module docstring)."""
    if _backend_override is not None:
        return _backend_override
    env = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}; expected one of {BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def set_backend(name: Optional[str]) -> None:
    """Force a backend programmatically; ``None`` restores auto-selection."""
    global _backend_override
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected {BACKENDS}")
    _backend_override = name


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[None]:
    """Context manager form of :func:`set_backend`."""
    prev = _backend_override
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


# ---------------------------------------------------------------------------
# Dispatch entry points
# ---------------------------------------------------------------------------

def matmul_int(a_codes: jnp.ndarray, wq: jnp.ndarray) -> jnp.ndarray:
    """Integer-exact MAC: [M, K] codes x [K, N] weight levels -> f32 [M, N].

    This is the raw OC accumulate (arm dots + BPD + summation tree) with NO
    dequant — both backends return the exact integer sum carried in f32, so
    callers can apply scale factors in whatever association order their
    reference semantics demand. Exactness envelope: with the device's CRC
    codes (<= 15) and MR levels (|wq| <= 7, i.e. w_bits <= 4) every partial
    sum stays below 105 * K; for K up to ~160K that is under 2^24, exact in
    f32 and int32 alike. Callers pushing w_bits to 8 (|wq| <= 127, bound
    15 * 127 * K) must keep K below ~8.8K themselves.
    """
    if get_backend() == "pallas":
        from repro.kernels.photonic_mvm.ops import photonic_mvm_prequant
        ones = jnp.ones((wq.shape[-1],), jnp.float32)
        return photonic_mvm_prequant(a_codes.astype(jnp.int8),
                                     wq.astype(jnp.int8), ones, act_scale=1.0)
    from repro.kernels.photonic_mvm.ref import mvm_int_ref
    ones = jnp.ones((wq.shape[-1],), jnp.float32)
    return mvm_int_ref(a_codes.astype(jnp.int32), wq.astype(jnp.int32), ones)


def conv_int(codes: jnp.ndarray, wq: jnp.ndarray, stride: int,
             pads, groups: int = 1) -> jnp.ndarray:
    """Integer-exact conv accumulate: [B,H,W,Cin] codes x [k,k,Cin/g,Cout]
    weight levels -> f32 [B,H',W',Cout], NO dequant (see matmul_int).

    ``groups`` is the feature-group count (1 = dense conv; groups == Cin with
    [k,k,1,Cin] weights = depthwise — the imaging pipelines' per-channel
    fixed-function filters: each channel is an independent single-channel
    kernel on the OC banks).

    pallas: im2col into the photonic MVM kernel (one OC weight mapping per
    VMEM-resident tile); grouped convs run one im2col matmul per group over
    that channel slice. reference: ``lax.conv_general_dilated`` on the
    float-carried codes — the exact op the eager interpreter runs, so no
    patch matrix is ever materialized (at 224x224 frames the im2col patches
    would be ~100x the input).
    """
    k, _, cg, c_out = wq.shape
    if c_out % groups or codes.shape[-1] != cg * groups:
        raise ValueError(
            f"conv_int: groups={groups} must divide c_out={c_out} and "
            f"match c_in={codes.shape[-1]} against weight slice {cg}")
    if get_backend() == "pallas":
        b = codes.shape[0]
        if groups == 1:
            patches, h_out, w_out = _im2col(codes, k, stride, pads)
            acc = matmul_int(patches, wq.reshape(k * k * cg, c_out))
            return acc.reshape(b, h_out, w_out, c_out)
        og = c_out // groups
        outs = []
        for g in range(groups):
            patches, h_out, w_out = _im2col(
                codes[..., g * cg:(g + 1) * cg], k, stride, pads)
            acc = matmul_int(patches,
                             wq[..., g * og:(g + 1) * og].reshape(
                                 k * k * cg, og))
            outs.append(acc.reshape(b, h_out, w_out, og))
        return jnp.concatenate(outs, axis=-1)
    return jax.lax.conv_general_dilated(
        codes.astype(jnp.float32), wq.astype(jnp.float32),
        window_strides=(stride, stride), padding=tuple(pads),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _im2col(codes: jnp.ndarray, k: int, stride: int, pads):
    """[B,H,W,Cin] -> ([B*H'*W', k*k*Cin], H', W').

    Tap order (di, dj, cin) matches ``wq.reshape(k*k*cin, cout)`` so the
    patch @ weight matmul reproduces the conv accumulate exactly.
    """
    (plo, phi), (qlo, qhi) = pads
    xp = jnp.pad(codes, ((0, 0), (plo, phi), (qlo, qhi), (0, 0)))
    h_out = (xp.shape[1] - k) // stride + 1
    w_out = (xp.shape[2] - k) // stride + 1
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(xp[:, di:di + (h_out - 1) * stride + 1:stride,
                           dj:dj + (w_out - 1) * stride + 1:stride, :])
    patches = jnp.concatenate(cols, axis=-1)
    return patches.reshape(-1, k * k * codes.shape[-1]), h_out, w_out


def ca_acquire(img: jnp.ndarray, pool: int,
               rgb_to_gray: bool | None = None) -> jnp.ndarray:
    """Compressive Acquisitor dispatch. img [B, H, W, C].

    Returns [B, H', W'] (fused gray) or [B, H', W', C] (per-channel pooling),
    matching ``core.compressive.compressive_acquire``. The Pallas kernel only
    implements the fused single-output modes (rgb_to_gray or C == 1); the
    per-channel multi-channel mode always uses the reference.

    NB: unlike matmul_int/conv_int this is *float* math — the kernel's tap
    summation order differs from the reference einsum by ~1 ulp, so the two
    backends agree only up to downstream CRC requant.
    """
    c = img.shape[-1]
    if rgb_to_gray is None:
        rgb_to_gray = (c == 3)
    if get_backend() == "pallas" and (rgb_to_gray or c == 1):
        from repro.kernels.ca_pool.ops import ca_pool
        out = ca_pool(img, pool=pool, rgb_to_gray=rgb_to_gray)
        return out if rgb_to_gray else out[..., None]
    from repro.core.compressive import compressive_acquire
    return compressive_acquire(img, pool, rgb_to_gray)
