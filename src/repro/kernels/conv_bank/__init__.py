from repro.kernels.conv_bank.ops import conv_bank
from repro.kernels.conv_bank.strip_kernel import (conv_strip_kernel,
                                                 conv_strip_depthwise_kernel)

__all__ = ["conv_bank", "conv_strip_kernel", "conv_strip_depthwise_kernel"]
