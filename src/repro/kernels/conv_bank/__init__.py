from repro.kernels.conv_bank.ops import conv_bank
