"""Public wrapper for the bank-mapped convolution kernel."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.quant import WASpec, quantize_weight
from repro.kernels.conv_bank import kernel as K
from repro.kernels.dispatch import default_interpret


def conv_bank(x: jnp.ndarray, w: jnp.ndarray,
              spec: Optional[WASpec] = None,
              act_scale: float = 1.0 / 15.0,
              padding: str = "SAME", bn: int = 64) -> jnp.ndarray:
    """kxk conv through the OC mapping. x [B,H,W,Cin]; w [k,k,Cin,Cout].

    With ``spec`` the integer photonic path runs (uint4 codes x int-w
    weights); without it, a float conv with the same tap-dot structure.
    """
    kk = w.shape[0]
    pad = kk // 2 if padding == "SAME" else 0
    if spec is not None:
        codes = jnp.clip(jnp.round(x.astype(jnp.float32) / act_scale), 0,
                         spec.a_qmax)
        wq, ws = quantize_weight(w.astype(jnp.float32), spec, axis=-1)
        xin = jnp.pad(codes, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        return K.conv_bank_kernel(xin, wq.astype(jnp.float32),
                                  ws.reshape(-1), kk=kk, bn=bn,
                                  act_scale=act_scale, quantized=True,
                                  interpret=default_interpret())
    xin = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ws_dummy = jnp.ones((w.shape[-1],), jnp.float32)
    return K.conv_bank_kernel(xin.astype(jnp.float32),
                              w.astype(jnp.float32), ws_dummy, kk=kk, bn=bn,
                              quantized=False, interpret=default_interpret())
