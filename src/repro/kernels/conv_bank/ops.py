"""Public wrapper for the bank-mapped convolution kernels.

``conv_bank`` runs the OC conv mapping end to end (quantize -> pad ->
kernel -> dequant) and picks between the two Pallas implementations:

  resident — ``kernel.conv_bank_kernel``: whole padded image as one VMEM
             block (the paper's <=32x32 evaluation frames);
  strip    — ``strip_kernel.conv_strip_kernel``: output rows tiled into
             strips, each input strip + halo DMA'd into VMEM (large frames).

``strategy`` resolves like ``kernels.dispatch``: explicit arg, then the
``REPRO_CONV_STRATEGY`` env var, then the VMEM-budget heuristic. Both
strategies accumulate identical exact integers, so they are bit-identical
on the quantized path.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.quant import WASpec, quantize_weight
from repro.kernels.conv_bank import kernel as K
from repro.kernels.conv_bank import strip_kernel as SK
from repro.kernels.dispatch import (default_interpret, select_conv_strategy)


def conv_bank(x: jnp.ndarray, w: jnp.ndarray,
              spec: Optional[WASpec] = None,
              act_scale: float = 1.0 / 15.0,
              padding: str = "SAME", bn: int = 64,
              strategy: Optional[str] = None,
              act: str = "none",
              bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """kxk conv through the OC mapping. x [B,H,W,Cin]; w [k,k,Cin,Cout].

    With ``spec`` the integer photonic path runs (uint4 codes x int-w
    weights); without it, a float conv with the same tap-dot structure.
    ``strategy`` ("resident" | "strip" | "auto" | None=auto) selects the
    resident or strip-mined kernel (see module docstring). On the quantized
    path ``act``/``bias`` fuse the per-layer epilogue (dequant -> bias ->
    activation) into the kernel instead of separate XLA ops — bit-identical
    either way (``strip_kernel._epilogue``).
    """
    kk = w.shape[0]
    pad = kk // 2 if padding == "SAME" else 0
    h_out = x.shape[1] + 2 * pad - kk + 1
    w_out = x.shape[2] + 2 * pad - kk + 1
    strat = select_conv_strategy(h_out, w_out, x.shape[-1], w.shape[-1],
                                 kk, stride=1, mode=strategy)
    if spec is not None:
        codes = jnp.clip(jnp.round(x.astype(jnp.float32) / act_scale), 0,
                         spec.a_qmax)
        wq, ws = quantize_weight(w.astype(jnp.float32), spec, axis=-1)
        xin = jnp.pad(codes, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        wf, wsf = wq.astype(jnp.float32), ws.reshape(-1)
        quantized = True
    else:
        xin = jnp.pad(x.astype(jnp.float32),
                      ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        wf, wsf = w.astype(jnp.float32), jnp.ones((w.shape[-1],), jnp.float32)
        quantized, act_scale = False, 1.0
    fuse_act = act if quantized else "none"
    fuse_bias = bias if quantized else None
    if strat.kind == "strip":
        xin = SK.pad_rows_for_strips(xin, kk, 1, strat.strip_rows,
                                     strat.n_strips)
        out = SK.conv_strip_kernel(xin, wf, wsf, kk=kk, stride=1,
                                   strip_h=strat.strip_rows, bn=bn,
                                   act_scale=act_scale, quantized=quantized,
                                   act=fuse_act, bias=fuse_bias,
                                   interpret=default_interpret())
        return out[:, :h_out]
    return K.conv_bank_kernel(xin, wf, wsf, kk=kk, bn=bn,
                              act_scale=act_scale, quantized=quantized,
                              act=fuse_act, bias=fuse_bias,
                              interpret=default_interpret())
