"""Strip-mined Pallas conv kernels for frames past the VMEM-resident budget.

``kernel.py`` maps the whole SAME-padded image as one VMEM block — right for
the paper's <=32x32 evaluation models, wrong for full sensor frames and the
VGG16/AlexNet layers of Fig. 10 where the image (let alone its im2col patch
matrix) no longer fits on-chip. This module is the large-frame path:

  * the output spatial rows are tiled into strips of ``strip_h`` rows;
  * the input stays off-chip (``memory_space=ANY``) and each strip's input
    rows plus (k-1)-row halo are DMA'd into a VMEM scratch slot
    (``pltpu.make_async_copy``) — fetched once per strip and reused across
    every output-channel block;
  * the halo DMA is **double-buffered**: the scratch holds two strip slots
    with a DMA semaphore each, and while strip s's tap loop computes out of
    slot s%2, the DMA for strip s+1 is already in flight into the other
    slot — the copy latency hides behind the k*k matmul loop instead of
    serializing in front of it (the strip for s=0 is the only cold fetch).
  * the tap loop then runs unchanged on the VMEM strip: k*k shifted
    [strip_h*W, C_in] x [C_in, bn] MXU matmuls accumulated in f32, the same
    arm-granular structure as the resident kernel, so the integer-exactness
    envelope (|sum| < 2^24) is identical.

Grid: (batch, strip, out-channel block) — the channel block innermost so one
halo DMA serves ``C_out / bn`` compute steps (input-stationary).

On the quantized path the kernels can also fuse the per-layer epilogue
(dequant -> bias -> activation) behind the accumulate via ``act=`` /
``bias=`` — the expressions mirror ``core.plan._execute_steps`` (including
the ``nextafter`` FMA guard), so the fused epilogue stays bit-identical to
the separate XLA ops it replaces. The CRC *requant* cannot fuse here: its
scale is a whole-frame max and a strip only sees its own rows — whole-frame
requant fusion lives in ``fused_kernel.conv_chain_kernel``.

The depthwise variant keeps the strip/halo structure but replaces the MXU
matmul with a VPU multiply-accumulate per tap (each output channel sees one
input channel), eliminating the per-channel im2col the grouped resident path
used to do. Strategy selection / geometry lives in ``kernels.dispatch``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def pad_rows_for_strips(xp: jnp.ndarray, kk: int, stride: int,
                        strip_rows: int, n_strips: int) -> jnp.ndarray:
    """Zero-pad the bottom rows of a spatially-padded input so ``n_strips``
    strips of ``strip_rows`` output rows tile exactly (the kernels' geometry
    contract). The single home of the row-padding recipe for every caller
    (dispatch strip path, ops wrapper): the padded height is
    ``(n_strips*strip_rows - 1)*stride + kk``. When the input already has
    surplus trailing rows (strided VALID convs drop up to stride-1 rows),
    nothing is added — the kernels' floor division ignores the surplus."""
    extra = (n_strips * strip_rows - 1) * stride + kk - xp.shape[1]
    if extra <= 0:
        return xp
    return jnp.pad(xp, ((0, 0), (0, extra), (0, 0), (0, 0)))


def _tap_patch(x: jnp.ndarray, di: int, dj: int, strip_h: int, w_out: int,
               stride: int, c: int) -> jnp.ndarray:
    """The (di, dj) tap's strided window of a VMEM strip -> [strip_h, w_out, c]."""
    return jax.lax.slice(
        x, (di, dj, 0),
        (di + (strip_h - 1) * stride + 1, dj + (w_out - 1) * stride + 1, c),
        (stride, stride, 1))


def _epilogue(acc: jnp.ndarray, act_scale: float, ws, b, act: str):
    """The fused quantized epilogue: dequant -> bias -> activation.

    Expression-for-expression the unfused ``plan._execute_steps`` recipe
    (``nextafter(x, x)`` is its FMA guard) so fusing it into the kernel
    cannot change a bit.
    """
    acc = acc * act_scale * ws
    if b is not None:
        acc = jnp.nextafter(acc, acc) + b
    if act != "none":
        from repro.core.accelerator import _activation
        acc = _activation(acc, act)
    return acc


def _strip_dma(x_hbm, xs_ref, sems, b, s, *, stride: int, strip_h: int,
               rows_in: int, n_strips: int):
    """Double-buffered halo DMA for strip ``s`` of batch ``b``.

    Waits for slot s%2 (strip s's rows + halo, started by the previous
    strip's prefetch — or right here for the cold first strip of a batch),
    then starts the DMA for strip s+1 into the other slot so it lands
    while the caller's tap loop runs. Returns the ready slot index.
    """
    def _copy(strip, slot):
        return pltpu.make_async_copy(
            x_hbm.at[b, pl.ds(strip * (strip_h * stride), rows_in)],
            xs_ref.at[slot], sems.at[slot])

    slot = jax.lax.rem(s, 2)

    @pl.when(s == 0)
    def _cold_fetch():
        _copy(0, 0).start()

    _copy(s, slot).wait()

    @pl.when(s + 1 < n_strips)
    def _prefetch_next():
        _copy(s + 1, jax.lax.rem(s + 1, 2)).start()

    return slot


def _conv_strip_kernel(x_hbm, w_ref, ws_ref, *rest, kk: int, stride: int,
                       strip_h: int, w_out: int, c_in: int, rows_in: int,
                       n_strips: int, act_scale: float, quantized: bool,
                       act: str, has_bias: bool):
    """One (strip, out-channel block) output tile.

    x_hbm:  [B, Hp, Wp, c_in] in ANY/HBM — never blocked into VMEM whole
    w_ref:  [kk, kk, c_in, bn] VMEM        ws_ref: [1, bn]
    xs_ref: [2, rows_in, Wp, c_in] VMEM scratch (two strip+halo slots,
            double-buffered; persists across the innermost grid dim);
    sems:   one DMA completion semaphore per slot
    out_ref: [1, strip_h, w_out, bn]
    """
    b_ref = rest[0] if has_bias else None
    out_ref, xs_ref, sems = rest[-3], rest[-2], rest[-1]
    b = pl.program_id(0)
    s = pl.program_id(1)
    n_blk = pl.program_id(2)

    @pl.when(n_blk == 0)
    def _fetch_strip():
        _strip_dma(x_hbm, xs_ref, sems, b, s, stride=stride, strip_h=strip_h,
                   rows_in=rows_in, n_strips=n_strips)

    x = xs_ref[jax.lax.rem(s, 2)]
    bn = out_ref.shape[-1]
    acc = jnp.zeros((strip_h * w_out, bn), jnp.float32)
    for di in range(kk):
        for dj in range(kk):
            patch = _tap_patch(x, di, dj, strip_h, w_out, stride, c_in)
            pf = patch.reshape(strip_h * w_out, c_in).astype(jnp.float32)
            wf = w_ref[di, dj].astype(jnp.float32)       # [c_in, bn]
            acc = acc + jax.lax.dot_general(
                pf, wf, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    if quantized:
        acc = _epilogue(acc, act_scale, ws_ref[...],
                        b_ref[...] if has_bias else None, act)
    out_ref[0] = acc.reshape(strip_h, w_out, bn).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kk", "stride", "strip_h", "bn",
                                             "act_scale", "quantized", "act",
                                             "interpret"))
def conv_strip_kernel(x_padded: jnp.ndarray, w: jnp.ndarray, ws: jnp.ndarray,
                      kk: int, stride: int = 1, strip_h: int = 8,
                      bn: int = 64, act_scale: float = 1.0,
                      quantized: bool = False, act: str = "none",
                      bias: jnp.ndarray | None = None,
                      interpret: bool = True) -> jnp.ndarray:
    """x_padded [B, Hp, Wp, Cin]; w [kk,kk,Cin,Cout] -> [B, H_out, W_out, Cout].

    Geometry contract (enforced): the caller pads the rows so the strips
    tile exactly — ``Hp == (n_strips*strip_h - 1)*stride + kk`` — i.e. the
    last strip's halo DMA ends exactly at the padded bottom edge. Output
    rows past the true h_out are the caller's padding to slice off.

    On the quantized path ``act``/``bias`` fuse the per-layer epilogue
    (dequant -> bias -> activation) into the kernel — see ``_epilogue``.
    """
    b, hp, wp, c_in = x_padded.shape
    w_out = (wp - kk) // stride + 1
    n_rows = (hp - kk) // stride + 1
    if strip_h < 1:
        raise ValueError(f"conv_strip_kernel: strip_h={strip_h} must be >= 1 "
                         f"(use dispatch.select_conv_strategy for geometry)")
    if n_rows % strip_h:
        raise ValueError(
            f"conv_strip_kernel: padded rows {hp} give {n_rows} output rows, "
            f"not a multiple of strip_h={strip_h}")
    n_strips = n_rows // strip_h
    rows_in = (strip_h - 1) * stride + kk
    c_out = w.shape[-1]
    bn = min(bn, c_out)
    while c_out % bn:
        bn -= 1
    ws2 = ws.reshape(1, c_out).astype(jnp.float32)
    has_bias = bias is not None
    operands = [x_padded.astype(jnp.float32), w.astype(jnp.float32), ws2]
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((kk, kk, c_in, bn), lambda i, s, n: (0, 0, 0, n)),
        pl.BlockSpec((1, bn), lambda i, s, n: (0, n)),
    ]
    if has_bias:
        operands.append(jnp.asarray(bias, jnp.float32).reshape(1, c_out))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, s, n: (0, n)))
    return pl.pallas_call(
        functools.partial(_conv_strip_kernel, kk=kk, stride=stride,
                          strip_h=strip_h, w_out=w_out, c_in=c_in,
                          rows_in=rows_in, n_strips=n_strips,
                          act_scale=act_scale, quantized=quantized,
                          act=act, has_bias=has_bias),
        grid=(b, n_strips, c_out // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, strip_h, w_out, bn),
                               lambda i, s, n: (i, s, 0, n)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows, w_out, c_out),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, rows_in, wp, c_in), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(*operands)


def _conv_strip_dw_kernel(x_hbm, w_ref, ws_ref, *rest, kk: int, stride: int,
                          strip_h: int, w_out: int, c: int, rows_in: int,
                          n_strips: int, act_scale: float, quantized: bool,
                          act: str, has_bias: bool):
    """Depthwise strip: every channel convolves with its own kk x kk filter.

    w_ref: [kk*kk, c] (tap-major) — the tap loop is a VPU multiply-accumulate
    over all channels at once; no im2col, no per-channel kernel launches.
    Same double-buffered halo DMA as the dense strip kernel.
    """
    b_ref = rest[0] if has_bias else None
    out_ref, xs_ref, sems = rest[-3], rest[-2], rest[-1]
    b = pl.program_id(0)
    s = pl.program_id(1)
    slot = _strip_dma(x_hbm, xs_ref, sems, b, s, stride=stride,
                      strip_h=strip_h, rows_in=rows_in, n_strips=n_strips)

    x = xs_ref[slot]
    acc = jnp.zeros((strip_h, w_out, c), jnp.float32)
    for di in range(kk):
        for dj in range(kk):
            patch = _tap_patch(x, di, dj, strip_h, w_out, stride, c)
            acc = acc + patch.astype(jnp.float32) * w_ref[di * kk + dj]
    if quantized:
        acc = _epilogue(acc, act_scale, ws_ref[0],
                        b_ref[0] if has_bias else None, act)
    out_ref[0] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kk", "stride", "strip_h",
                                             "act_scale", "quantized", "act",
                                             "interpret"))
def conv_strip_depthwise_kernel(x_padded: jnp.ndarray, w_taps: jnp.ndarray,
                                ws: jnp.ndarray, kk: int, stride: int = 1,
                                strip_h: int = 8, act_scale: float = 1.0,
                                quantized: bool = False, act: str = "none",
                                bias: jnp.ndarray | None = None,
                                interpret: bool = True) -> jnp.ndarray:
    """x_padded [B, Hp, Wp, C]; w_taps [kk*kk, C] -> [B, H_out, W_out, C].

    Same row-padding contract as :func:`conv_strip_kernel`.
    """
    b, hp, wp, c = x_padded.shape
    w_out = (wp - kk) // stride + 1
    n_rows = (hp - kk) // stride + 1
    if strip_h < 1:
        raise ValueError(f"conv_strip_depthwise_kernel: strip_h={strip_h} "
                         f"must be >= 1")
    if n_rows % strip_h:
        raise ValueError(
            f"conv_strip_depthwise_kernel: padded rows {hp} give {n_rows} "
            f"output rows, not a multiple of strip_h={strip_h}")
    n_strips = n_rows // strip_h
    rows_in = (strip_h - 1) * stride + kk
    ws2 = ws.reshape(1, c).astype(jnp.float32)
    has_bias = bias is not None
    operands = [x_padded.astype(jnp.float32), w_taps.astype(jnp.float32), ws2]
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((kk * kk, c), lambda i, s: (0, 0)),
        pl.BlockSpec((1, c), lambda i, s: (0, 0)),
    ]
    if has_bias:
        operands.append(jnp.asarray(bias, jnp.float32).reshape(1, c))
        in_specs.append(pl.BlockSpec((1, c), lambda i, s: (0, 0)))
    return pl.pallas_call(
        functools.partial(_conv_strip_dw_kernel, kk=kk, stride=stride,
                          strip_h=strip_h, w_out=w_out, c=c, rows_in=rows_in,
                          n_strips=n_strips, act_scale=act_scale,
                          quantized=quantized, act=act, has_bias=has_bias),
        grid=(b, n_strips),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, strip_h, w_out, c),
                               lambda i, s: (i, s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_rows, w_out, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, rows_in, wp, c), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(*operands)
