"""Oracles for conv_bank: XLA's conv_general_dilated on the same operands,
plus the fused-chain reference (``conv_chain_ref``) — the bit-exact oracle
for the megakernel path (``fused_kernel.conv_chain_kernel``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import ACT_BITS, WASpec, quantize_weight


def conv_bank_ref(x: jnp.ndarray, w: jnp.ndarray, padding: str = "SAME"
                  ) -> jnp.ndarray:
    """Float conv oracle. x [B,H,W,Cin]; w [k,k,Cin,Cout]."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (1, 1), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv_bank_quant_ref(x: jnp.ndarray, w: jnp.ndarray, spec: WASpec,
                        act_scale: float = 1.0 / 15.0,
                        padding: str = "SAME") -> jnp.ndarray:
    """Quantized conv oracle — the LightatorDevice integer semantics."""
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / act_scale), 0,
                     spec.a_qmax)
    wq, ws = quantize_weight(w.astype(jnp.float32), spec, axis=-1)
    acc = jax.lax.conv_general_dilated(
        codes, wq.astype(jnp.float32), (1, 1), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return acc * act_scale * ws.reshape(1, 1, 1, -1)


# ---------------------------------------------------------------------------
# Fused chain reference
# ---------------------------------------------------------------------------

def conv_taps_int(x: jnp.ndarray, wq: jnp.ndarray, kernel: int, stride: int,
                  pads, depthwise: bool = False) -> jnp.ndarray:
    """Integer-exact conv accumulate as a k*k tap loop of shifted windows.

    Bit-identical to ``lax.conv_general_dilated`` on the same quantized
    operands: every partial product is an exact small integer carried in
    f32 (|sum| < 2^24), so the summation order cannot matter. The tap-loop
    formulation is what the fused megakernel runs per stage — and on CPU it
    is also dramatically faster than the general conv lowering for the
    small channel counts the fusion heuristic admits.
    """
    k, s = kernel, stride
    (plo, phi), (qlo, qhi) = pads
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (plo, phi), (qlo, qhi),
                                         (0, 0)))
    b, hp, wp, c_in = xp.shape
    h_out = (hp - k) // s + 1
    w_out = (wp - k) // s + 1
    wf = wq.astype(jnp.float32)
    c_out = wf.shape[-1]
    acc = jnp.zeros((b, h_out, w_out, c_out), jnp.float32)
    for di in range(k):
        for dj in range(k):
            patch = jax.lax.slice(
                xp, (0, di, dj, 0),
                (b, di + (h_out - 1) * s + 1, dj + (w_out - 1) * s + 1, c_in),
                (1, s, s, 1))
            if depthwise:
                acc = acc + patch * wf[di, dj, 0]
            else:
                acc = acc + jnp.einsum("bhwc,cn->bhwn", patch, wf[di, dj])
    return acc


def conv_chain_ref(codes: jnp.ndarray, act_scale, stages, a_qmax):
    """The fused conv-chain oracle: whole frames through every stage inside
    one traced computation, epilogue expressions matching the unfused
    ``core.plan._execute_steps`` term for term.

    ``stages``: sequence of ``(geom: dispatch.ChainGeom, wq, ws, bias)``.
    Returns ``(codes, act_scale)`` after the last stage's CRC requant, with
    the scale reduced per frame ([B, 1, 1, 1]) — at batch 1 the same
    reduction as per-tensor calibration, bit for bit.
    """
    from repro.core.accelerator import _activation
    x, scale = codes, act_scale
    for geom, wq, ws, bias in stages:
        acc = conv_taps_int(x, wq, geom.kernel, geom.stride, geom.pads,
                            depthwise=geom.depthwise)
        out = acc * (scale * ws.reshape(1, 1, 1, -1))
        if bias is not None:
            # nextafter(x, x): the unfused path's exact-identity FMA guard
            out = jnp.nextafter(out, out) + bias
        y = _activation(out, geom.act)
        if geom.pool is not None:
            kind, size = geom.pool
            b_, h_, w_, c_ = y.shape
            yr = y.reshape(b_, h_ // size, size, w_ // size, size, c_)
            y = yr.max(axis=(2, 4)) if kind == "max" else yr.mean(axis=(2, 4))
        y = jnp.maximum(y, 0.0)
        amax = jnp.max(y, axis=(1, 2, 3), keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / a_qmax
        x = jnp.clip(jnp.round(y / scale), 0, (1 << ACT_BITS) - 1)
    return x, scale
