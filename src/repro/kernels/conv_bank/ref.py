"""Oracle for conv_bank: XLA's conv_general_dilated on the same operands."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import WASpec, quantize_weight


def conv_bank_ref(x: jnp.ndarray, w: jnp.ndarray, padding: str = "SAME"
                  ) -> jnp.ndarray:
    """Float conv oracle. x [B,H,W,Cin]; w [k,k,Cin,Cout]."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (1, 1), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv_bank_quant_ref(x: jnp.ndarray, w: jnp.ndarray, spec: WASpec,
                        act_scale: float = 1.0 / 15.0,
                        padding: str = "SAME") -> jnp.ndarray:
    """Quantized conv oracle — the LightatorDevice integer semantics."""
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / act_scale), 0,
                     spec.a_qmax)
    wq, ws = quantize_weight(w.astype(jnp.float32), spec, axis=-1)
    acc = jax.lax.conv_general_dilated(
        codes, wq.astype(jnp.float32), (1, 1), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return acc * act_scale * ws.reshape(1, 1, 1, -1)
