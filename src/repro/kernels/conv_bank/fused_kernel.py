"""The fused conv-chain Pallas megakernel: one launch per segment.

The per-layer kernels (``kernel.py``, ``strip_kernel.py``) each run one
conv's integer accumulate and hand the epilogue (dequant -> bias ->
activation -> pool -> CRC requant) back to XLA — so an N-stage imaging
chain pays N kernel launches plus N HBM round trips for intermediate
frames. This module executes a whole *fused segment* (a run of chainable
convs picked by ``dispatch.select_fused_segments``) as ONE ``pallas_call``:

  * grid = (batch,): each grid step owns one frame end to end, so the
    input DMA for frame b+1 overlaps frame b's compute via the Pallas
    pipeline emitter (automatic double buffering of the block operands);
  * the stage loop is unrolled in Python at trace time from the segment's
    static ``ChainGeom``s — every stage keeps its intermediate frame in
    VMEM, runs the k*k tap-loop accumulate (exact integers, the same
    arm-granular structure as the strip kernel), then the complete fused
    epilogue *in-kernel*: dequant, bias (behind the ``nextafter`` FMA
    guard), activation, pooling, and CRC requantization;
  * the inter-stage CRC scale is a whole-frame max — a stage barrier
    inside the launch. That is deliberate: requant calibration is a global
    reduction, so a halo-grown strip pyramid could only approximate it.
    Whole frames in VMEM keep the math bit-identical to the unfused path,
    which is the correctness bar (``ref.conv_chain_ref`` is the oracle;
    the VMEM budget check in ``dispatch.select_fused_segments`` keeps
    segments inside what this layout can hold).

Because each grid step reduces over its own frame only, the kernel
computes *per-frame* calibration natively; per-tensor calibration fuses
only at batch 1 (the same reduction), which ``dispatch.conv_chain``
enforces.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import ACT_BITS


def _stage_compute(x, w, ws, b, scale, aq, geom):
    """One fused stage on a single frame held in VMEM.

    x [H, W, C_in] codes; w [k, k, C_in/g, C_out]; ws [C_out]; b [C_out]
    or None; scale/aq scalars. Returns (codes [H', W', C_out], scale').
    Every expression mirrors the unfused ``plan._execute_steps`` epilogue
    (and ``ref.conv_chain_ref``) term for term — bit-identity depends on it.
    """
    from repro.core.accelerator import _activation
    k, s = geom.kernel, geom.stride
    (plo, phi), (qlo, qhi) = geom.pads
    xp = jnp.pad(x, ((plo, phi), (qlo, qhi), (0, 0)))
    hp, wp, c_in = xp.shape
    h_out = (hp - k) // s + 1
    w_out = (wp - k) // s + 1
    c_out = w.shape[-1]
    if geom.depthwise:
        acc = jnp.zeros((h_out, w_out, c_out), jnp.float32)
        for di in range(k):
            for dj in range(k):
                patch = jax.lax.slice(
                    xp, (di, dj, 0),
                    (di + (h_out - 1) * s + 1, dj + (w_out - 1) * s + 1,
                     c_in), (s, s, 1))
                acc = acc + patch * w[di, dj, 0]
    else:
        acc = jnp.zeros((h_out * w_out, c_out), jnp.float32)
        for di in range(k):
            for dj in range(k):
                patch = jax.lax.slice(
                    xp, (di, dj, 0),
                    (di + (h_out - 1) * s + 1, dj + (w_out - 1) * s + 1,
                     c_in), (s, s, 1))
                pf = patch.reshape(h_out * w_out, c_in)
                acc = acc + jax.lax.dot_general(
                    pf, w[di, dj], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
        acc = acc.reshape(h_out, w_out, c_out)
    out = acc * (scale * ws)
    if b is not None:
        out = jnp.nextafter(out, out) + b
    y = _activation(out, geom.act)
    if geom.pool is not None:
        kind, size = geom.pool
        h_, w_, c_ = y.shape
        yr = y.reshape(h_ // size, size, w_ // size, size, c_)
        y = yr.max(axis=(1, 3)) if kind == "max" else yr.mean(axis=(1, 3))
    y = jnp.maximum(y, 0.0)
    amax = jnp.max(y)
    new_scale = jnp.maximum(amax, 1e-8) / aq
    codes = jnp.clip(jnp.round(y / new_scale), 0, (1 << ACT_BITS) - 1)
    return codes, new_scale


def _chain_kernel(x_ref, s_ref, aq_ref, *rest, geoms, has_bias):
    """One frame through every fused stage (grid = (batch,))."""
    out_ref, scale_ref = rest[-2], rest[-1]
    stage_refs = rest[:-2]
    x = x_ref[0]
    scale = s_ref[0, 0]
    aq = aq_ref[0, 0]
    r = 0
    for i, geom in enumerate(geoms):
        w = stage_refs[r][...]
        ws = stage_refs[r + 1][0]
        r += 2
        b = None
        if has_bias[i]:
            b = stage_refs[r][0]
            r += 1
        x, scale = _stage_compute(x, w, ws, b, scale, aq, geom)
    out_ref[0] = x.astype(out_ref.dtype)
    scale_ref[0, 0] = scale


def conv_chain_kernel(codes: jnp.ndarray, act_scale, stages: Sequence,
                      a_qmax, interpret: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused segment as one ``pallas_call``. codes [B, H, W, C_in].

    ``stages``: sequence of ``(geom: dispatch.ChainGeom, wq, ws, bias)``
    (static geometry + traced operands). ``act_scale`` is the incoming CRC
    scale — 0-d (per-tensor, batch 1) or [B, 1, 1, 1] (per-frame).
    Returns ``(codes [B, H', W', C_out], scale [B, 1, 1, 1])`` after the
    last stage's requant — bit-identical to ``ref.conv_chain_ref``.
    """
    b = codes.shape[0]
    geoms = tuple(g for g, _, _, _ in stages)
    has_bias = tuple(bias is not None for _, _, _, bias in stages)
    s2 = jnp.asarray(act_scale, jnp.float32).reshape(-1, 1)
    if s2.shape[0] != b:
        s2 = jnp.broadcast_to(s2, (b, 1))
    aq = jnp.asarray(a_qmax, jnp.float32).reshape(1, 1)

    operands = [codes.astype(jnp.float32), s2, aq]
    in_specs = [
        pl.BlockSpec((1,) + codes.shape[1:], lambda i: (i, 0, 0, 0)),
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
    ]

    def _whole(shape):
        nd = len(shape)
        return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)

    for geom, wq, ws, bias in stages:
        c_out = geom.c_out
        wf = wq.astype(jnp.float32)
        operands.append(wf)
        in_specs.append(_whole(wf.shape))
        # per-tensor weight specs give a size-1 ws — broadcast to the
        # channel row the kernel expects (same f32 value, same multiply)
        operands.append(jnp.broadcast_to(
            ws.astype(jnp.float32).reshape(1, -1), (1, c_out)))
        in_specs.append(_whole((1, c_out)))
        if bias is not None:
            operands.append(jnp.asarray(bias, jnp.float32).reshape(1, c_out))
            in_specs.append(_whole((1, c_out)))

    h_out, w_out = geoms[-1].out_hw()
    c_out = geoms[-1].c_out

    out, scale = pl.pallas_call(
        functools.partial(_chain_kernel, geoms=geoms, has_bias=has_bias),
        grid=(b,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, h_out, w_out, c_out), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h_out, w_out, c_out), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out, scale.reshape(b, 1, 1, 1)
