"""Pallas kernel for the All-in-One Convolver's conv mapping (paper Fig. 6).

The OC computes a kxk conv as k*k tap-position dot products accumulated by
the BPD + summation tree. The TPU translation keeps that structure: each of
the k*k taps is a shifted [H*W, C_in] x [C_in, bn] MXU matmul, accumulated
in f32 — the tap loop is static (9/25/49, the paper's arm-granular
segmentation), and each grid step emits the output tile for one block of
output channels (one "round" of mapped kernels, exactly the weight-remap
round of core.optical_core.schedule_conv).

Quantized variant: int8 carriers (uint4 CRC codes x signed w-bit MR levels),
integer-exact accumulation in f32 (|sum| < 2^24), dequant at the end —
matching LightatorDevice's conv semantics. The per-layer epilogue
(dequant -> bias -> activation) can fuse behind the accumulate via
``act=`` / ``bias=`` with the same bit-identity guarantee as the strip
kernels (shared ``strip_kernel._epilogue`` expressions).

Grid: (B, C_out / bn); the SAME-padded input image is one VMEM block
(the paper's models are <= 32x32 — a 64x64x256 f32 strip is ~4 MB; larger
frames would move to a strip-mined variant with halo DMA).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv_bank.strip_kernel import _epilogue


def _conv_kernel(x_ref, w_ref, ws_ref, *rest, kk: int, h_out: int,
                 w_out: int, c_in: int, act_scale: float, quantized: bool,
                 act: str, has_bias: bool):
    """x_ref: [1, H+k-1, W+k-1, c_in]; w_ref: [k, k, c_in, bn];
    ws_ref: [1, bn]; out_ref: [1, H, W, bn]."""
    b_ref = rest[0] if has_bias else None
    out_ref = rest[-1]
    x = x_ref[0]
    bn = out_ref.shape[-1]
    acc = jnp.zeros((h_out * w_out, bn), jnp.float32)
    for di in range(kk):
        for dj in range(kk):
            patch = jax.lax.slice(
                x, (di, dj, 0), (di + h_out, dj + w_out, c_in))
            pf = patch.reshape(h_out * w_out, c_in).astype(jnp.float32)
            wf = w_ref[di, dj].astype(jnp.float32)       # [c_in, bn]
            acc = acc + jax.lax.dot_general(
                pf, wf, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    if quantized:
        acc = _epilogue(acc, act_scale, ws_ref[...],
                        b_ref[...] if has_bias else None, act)
    out_ref[0] = acc.reshape(h_out, w_out, bn).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kk", "bn", "act_scale",
                                             "quantized", "act", "interpret"))
def conv_bank_kernel(x_padded: jnp.ndarray, w: jnp.ndarray, ws: jnp.ndarray,
                     kk: int = 3, bn: int = 64,
                     act_scale: float = 1.0, quantized: bool = False,
                     act: str = "none", bias: jnp.ndarray | None = None,
                     interpret: bool = True) -> jnp.ndarray:
    """x_padded [B, H+k-1, W+k-1, Cin]; w [k,k,Cin,Cout] -> [B, H, W, Cout]."""
    b, hp, wp, c_in = x_padded.shape
    h_out, w_out = hp - kk + 1, wp - kk + 1
    c_out = w.shape[-1]
    bn = min(bn, c_out)
    while c_out % bn:
        bn -= 1
    grid = (b, c_out // bn)
    ws2 = ws.reshape(1, c_out).astype(jnp.float32)
    has_bias = bias is not None
    operands = [x_padded, w, ws2]
    in_specs = [
        pl.BlockSpec((1, hp, wp, c_in), lambda i, n: (i, 0, 0, 0)),
        pl.BlockSpec((kk, kk, c_in, bn), lambda i, n: (0, 0, 0, n)),
        pl.BlockSpec((1, bn), lambda i, n: (0, n)),
    ]
    if has_bias:
        operands.append(jnp.asarray(bias, jnp.float32).reshape(1, c_out))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, n: (0, n)))
    return pl.pallas_call(
        functools.partial(_conv_kernel, kk=kk, h_out=h_out, w_out=w_out,
                          c_in=c_in, act_scale=act_scale, quantized=quantized,
                          act=act, has_bias=has_bias),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h_out, w_out, bn),
                               lambda i, n: (i, 0, 0, n)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, c_out), jnp.float32),
        interpret=interpret,
    )(*operands)
