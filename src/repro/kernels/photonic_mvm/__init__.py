from repro.kernels.photonic_mvm.ops import photonic_mvm, photonic_mvm_prequant
