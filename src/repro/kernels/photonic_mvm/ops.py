"""jit'd public wrapper for the photonic MVM kernel.

Handles: float->code quantization (CRC + MR imprinting), signed activations
via the two-rail BPD trick (sign * |code|), block padding, leading dims,
and the interpret switch (True on CPU — this container; False on real TPU).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import WASpec, quantize_weight
from repro.kernels.dispatch import default_interpret
from repro.kernels.photonic_mvm import kernel as K


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


def photonic_mvm_prequant(a_signed_codes: jnp.ndarray, wq: jnp.ndarray,
                          ws: jnp.ndarray, act_scale: float = 1.0,
                          bm: int = K.DEFAULT_BM, bn: int = K.DEFAULT_BN,
                          bk: int = K.DEFAULT_BK,
                          out_dtype=jnp.float32) -> jnp.ndarray:
    """Already-quantized operands (int8 carriers) -> dequantized output.

    a_signed_codes: [..., K] int8 in [-15, 15]; wq: [K, N] int8; ws: [N].
    """
    *lead, kdim = a_signed_codes.shape
    n = wq.shape[-1]
    a2 = a_signed_codes.reshape(-1, kdim)
    m = a2.shape[0]
    a2 = _pad_to(_pad_to(a2, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(wq, bk, 0), bn, 1)
    wsp = _pad_to(ws.reshape(-1), bn, 0)
    out = K.mvm_int_kernel(a2, wp, wsp, act_scale=act_scale, bm=bm, bn=bn,
                           bk=bk, out_dtype=out_dtype,
                           interpret=default_interpret())
    return out[:m, :n].reshape(*lead, n)


def photonic_mvm(x: jnp.ndarray, w: jnp.ndarray, spec: WASpec,
                 act_scale: float = 1.0 / 15.0,
                 bm: int = K.DEFAULT_BM, bn: int = K.DEFAULT_BN,
                 bk: int = K.DEFAULT_BK) -> jnp.ndarray:
    """Float API: x [..., K] @ w [K, N] under [W:A] ``spec``.

    Quantizes both operands the way the sensor/OC would, then runs the
    integer kernel. Matches ref.photonic_mvm_ref bit-exactly.
    """
    *lead, kdim = x.shape
    xf = x.reshape(-1, kdim).astype(jnp.float32)
    sgn = jnp.sign(xf)
    codes = jnp.clip(jnp.round(jnp.abs(xf) / act_scale), 0, spec.a_qmax)
    a = (sgn * codes).astype(jnp.int8)
    wq, ws = quantize_weight(w.astype(jnp.float32), spec, axis=-1)
    y = photonic_mvm_prequant(a, wq, ws.reshape(-1), act_scale=act_scale,
                              bm=bm, bn=bn, bk=bk)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
