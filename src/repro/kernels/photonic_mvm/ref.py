"""Pure-jnp oracle for the photonic MVM kernel.

Must match the integer semantics of the optical core exactly:
CRC-coded uint4 activations x MR-held signed w-bit weights, integer
accumulate, dequant by act_scale * per-channel weight scale.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import WASpec, quantize_weight


def mvm_int_ref(a_codes: jnp.ndarray, wq: jnp.ndarray, ws: jnp.ndarray,
                act_scale: float = 1.0, out_dtype=jnp.float32) -> jnp.ndarray:
    """Same contract as kernel.mvm_int_kernel, computed with one jnp matmul."""
    acc = jnp.matmul(a_codes.astype(jnp.int32), wq.astype(jnp.int32))
    return (acc.astype(jnp.float32) * act_scale
            * ws.reshape(1, -1).astype(jnp.float32)).astype(out_dtype)


def photonic_mvm_ref(x: jnp.ndarray, w: jnp.ndarray, spec: WASpec,
                     act_scale: float = 1.0 / 15.0) -> jnp.ndarray:
    """Float-in/float-out oracle incl. quantization of both operands.

    Signed activations are carried on two rails (BPD differential): the
    magnitude is CRC-quantized, the sign reapplied — identical semantics to
    nn.layers.dense(mode="fake") at inference (round, no STE needed).
    """
    *lead, kdim = x.shape
    xf = x.reshape(-1, kdim).astype(jnp.float32)
    sgn = jnp.sign(xf)
    codes = jnp.clip(jnp.round(jnp.abs(xf) / act_scale), 0, spec.a_qmax)
    wq, ws = quantize_weight(w.astype(jnp.float32), spec, axis=-1)
    acc = jnp.matmul(sgn * codes, wq.astype(jnp.float32))
    y = acc * act_scale * ws.reshape(1, -1)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
