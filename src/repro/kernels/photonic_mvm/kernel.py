"""Pallas TPU kernel for the Optical Core's quantized MVM.

The hardware being emulated (paper Secs. 3-4): activations arrive as uint4
CRC codes on VCSEL wavelengths; weights sit on MRs as signed w-bit integers;
each arm computes a 9-tap integer dot (BPD accumulate), the summation tree
adds arm partials, and the electronic back-end applies the dequant scales.

TPU adaptation (DESIGN.md §2): the 9-MR arm becomes the 128-lane MXU row;
one OC weight mapping becomes one VMEM-resident weight tile. Integer MACs
run on the MXU via int8 carriers with ``preferred_element_type=int32`` —
bit-exact with the photonic integer math. The K-block loop in the grid IS
the summation tree: partial sums accumulate in an int32 VMEM scratch
across K steps (stage-1/stage-2 adds), and the final step applies
``act_scale * w_scale[col]`` (the transmitter's dequant) and writes bf16/f32.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation). Weight
blocks only change with (n, k) — Pallas keeps the block resident in VMEM
across the M loop, exactly the weight-stationary reuse the paper's DMVA
enables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _mvm_kernel(a_ref, w_ref, ws_ref, out_ref, acc_ref, *, n_k: int,
                act_scale: float):
    """One (bm, bn) output tile; accumulates over the K grid dimension.

    a_ref:  [bm, bk] int8  — CRC activation codes (0..15)
    w_ref:  [bk, bn] int8  — MR weight levels (signed, |q| <= 7)
    ws_ref: [1, bn] f32    — per-output-channel weight scales
    acc_ref:[bm, bn] int32 — summation-tree accumulator (VMEM scratch)
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # arm dots + BPD accumulate: integer MAC on the MXU
    a = a_ref[...]
    w = w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _dequant():
        # transmitter: dequantize with act & per-channel weight scales
        out_ref[...] = (acc_ref[...].astype(jnp.float32)
                        * act_scale * ws_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "act_scale",
                                             "out_dtype", "interpret"))
def mvm_int_kernel(a_codes: jnp.ndarray, wq: jnp.ndarray, ws: jnp.ndarray,
                   act_scale: float = 1.0, bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                   out_dtype=jnp.float32, interpret: bool = True):
    """a_codes [M,K] int8, wq [K,N] int8, ws [N] f32 -> [M,N] out_dtype.

    M, K, N are padded to block multiples by the caller (ops.py).
    """
    m, k = a_codes.shape
    _, n = wq.shape
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    ws2 = ws.reshape(1, n).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_mvm_kernel, n_k=n_k, act_scale=act_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_codes, wq, ws2)
