# Pallas TPU kernels for the Lightator compute hot-spots:
#   photonic_mvm — the Optical Core's quantized MVM (arm/bank -> MXU tiles)
#   ca_pool      — Compressive Acquisitor (fused RGB->gray + mean pool)
#   conv_bank    — Fig. 6 conv mapping (tap-position dots = arms)
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle). Validated on CPU with interpret=True.
