# Pallas TPU kernels for the Lightator compute hot-spots:
#   photonic_mvm — the Optical Core's quantized MVM (arm/bank -> MXU tiles)
#   ca_pool      — Compressive Acquisitor (fused RGB->gray + mean pool)
#   conv_bank    — Fig. 6 conv mapping (tap-position dots = arms); resident
#                  (kernel.py) + strip-mined halo-DMA (strip_kernel.py) paths
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle). Validated on CPU with interpret=True.
# dispatch.py picks the backend (pallas on TPU, reference elsewhere; env
# overrides REPRO_KERNEL_BACKEND / REPRO_FORCE_INTERPRET), the conv strategy
# (resident vs strip; REPRO_CONV_STRATEGY + VMEM-budget heuristic) and is the
# single source of the Pallas interpret flag (default_interpret()).
