#!/usr/bin/env bash
# Tier-1 CI: the fast test suite + an end-to-end serving smoke on CPU.
#   bash scripts/ci.sh          # what the driver runs
#   bash scripts/ci.sh --slow   # also include the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# tuned CPU launch env (same knobs benchmarks/run.py documents): quiet the
# XLA/TF C++ banner noise, and when tcmalloc is installed preload it —
# XLA's host allocator churn is measurably faster under it — with the
# large-alloc report threshold pushed up so it never spams the log.
export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}
TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -e "$TCMALLOC" && -z "${LD_PRELOAD:-}" ]]; then
    export LD_PRELOAD="$TCMALLOC"
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi

# repo hygiene: bytecode caches must never be tracked (.gitignore covers
# them, but files committed before the ignore rule — or force-added —
# slip through silently)
if git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'; then
    echo "ci.sh: FAIL — git-tracked __pycache__/*.pyc above; git rm them" >&2
    exit 1
fi

if [[ "${1:-}" == "--slow" ]]; then
    python -m pytest -x -q -m ""
else
    python -m pytest -x -q
fi

# docs gate: every docs/*.md referenced from README, no dead relative links
python scripts/check_docs.py

# static-analysis gates: (1) the plan verifier must prove the kernel
# invariants (|acc| < 2^24, shape legality, VMEM/fusion audit) for every
# registered model and imaging pipeline; (2) the concurrency lint must
# find no unlocked shared mutation / unjoined thread / raw future settle
# in the serving + observability runtime — directory-scoped, so the
# flight recorder, SLO engine and admin endpoint are gated automatically
python scripts/verify_plan.py --all
python -m repro.analysis.lint src/repro/serve src/repro/obs

# bench gate: committed BENCH_*.json must keep their invariants (fused
# megakernel >= 1.5x and bitwise-exact, oracle errors at float epsilon)
# and stay inside the timing tolerance band vs the previous commit
python scripts/check_bench.py

# conv kernels again with the strip-mined strategy forced (large-frame path)
REPRO_CONV_STRATEGY=strip python -m pytest tests/test_kernels_conv_bank.py -q

# and with megakernel fusion forced: every conv run that can legally fuse
# executes as a single pass, and the fused-chain property suite re-runs
# under the forced strategy (bit-identity is the bar)
REPRO_CONV_STRATEGY=fused python -m pytest \
    tests/test_kernels_conv_bank.py tests/test_fused_chain.py -q

# end-to-end serving smoke: imaging pipeline + CNN through the repro.serve
# micro-batching runtime, exercising the Options-mapped CLI flags
python -m repro.launch.serve_vision --pipeline edge_detect --batch 2 \
    --batches 2 --size 32 --backend reference --conv-strategy auto
python -m repro.launch.serve_vision --model lenet --batch 2 --batches 2

# serve-runtime smoke: ~32 async Poisson requests through the scheduler;
# serve_vision asserts every request is accounted for (served + shed +
# rejected) before printing the latency percentiles. Traced: the exported
# Chrome-trace must contain device spans and at least one request whose
# queue-wait -> batch-assembly -> device -> split timeline is complete
# and in order (scripts/check_trace.py)
python -m repro.launch.serve_vision --model lenet --load 200 --requests 32 \
    --batch 4 --backend reference --trace /tmp/repro_serve_trace.json
python scripts/check_trace.py /tmp/repro_serve_trace.json

# virtual-device leg: the device-pool property/fault suite on 4 virtual
# CPU devices (the count is fixed at jax init, hence the env-scoped
# subprocesses), then the pooled Poisson smoke — its trace must show the
# pool actually spreading work across >= 2 device lanes
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest tests/test_serve_pool.py -q
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m repro.launch.serve_vision --model lenet --load 200 \
    --requests 32 --batch 4 --devices 4 --backend reference \
    --trace /tmp/repro_pool_trace.json
python scripts/check_trace.py /tmp/repro_pool_trace.json --min-devices 2

# ops-endpoint smoke: a pooled server with the admin surface on an
# ephemeral port — /healthz /readyz answer, /metrics parses as
# Prometheus exposition with the right counters, /statusz keeps the
# empty-window {"count": 0} shape, and the saved /tracez flight dump
# passes the ring-integrity validator
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python scripts/admin_smoke.py --devices 2 \
    --out /tmp/repro_admin_tracez.json
python scripts/check_trace.py /tmp/repro_admin_tracez.json --flight

# multi-device batch sharding (pre-pool path): runs its own subprocess
# with its own XLA_FLAGS, so no outer env here
python -m pytest \
    "tests/test_program_api.py::test_shard_batch_multi_device_bit_identical" -q

# example smoke: the Program/Options/Executable walkthroughs must keep
# running as written in the docs
python examples/quickstart.py
python examples/imaging_demo.py --quick

echo "ci.sh: OK"
