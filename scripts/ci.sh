#!/usr/bin/env bash
# Tier-1 CI: the fast test suite + an end-to-end serving smoke on CPU.
#   bash scripts/ci.sh          # what the driver runs
#   bash scripts/ci.sh --slow   # also include the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${1:-}" == "--slow" ]]; then
    python -m pytest -x -q -m ""
else
    python -m pytest -x -q
fi

# docs gate: every docs/*.md referenced from README, no dead relative links
python scripts/check_docs.py

# conv kernels again with the strip-mined strategy forced (large-frame path)
REPRO_CONV_STRATEGY=strip python -m pytest tests/test_kernels_conv_bank.py -q

# end-to-end serving smoke (2 batches each): imaging pipeline + CNN,
# exercising the Options-mapped CLI flags
python -m repro.launch.serve_vision --pipeline edge_detect --batch 2 \
    --batches 2 --size 32 --backend reference --conv-strategy auto
python -m repro.launch.serve_vision --model lenet --batch 2 --batches 2

# example smoke: the Program/Options/Executable walkthroughs must keep
# running as written in the docs
python examples/quickstart.py
python examples/imaging_demo.py --quick

echo "ci.sh: OK"
