"""CI smoke for the serving ops endpoint (run by scripts/ci.sh).

Boots a (optionally pooled) ``repro.serve.Server`` with the admin
endpoint on an ephemeral port, pushes a little traffic, then exercises
every route the way a fleet scheduler would — over HTTP, not by calling
Python internals:

  * ``/healthz`` and ``/readyz`` answer 200 with the check breakdown;
  * ``/metrics`` parses as Prometheus text exposition (``# HELP`` +
    ``# TYPE`` per metric, every sample line name-legal) and contains
    the served-requests counter with the right value;
  * ``/statusz`` round-trips JSON, reports the served program's stats,
    and keeps the traffic-less program's latency summary at
    ``{"count": 0}`` — the empty-window shape must survive the whole
    stack, not become NaN percentiles;
  * ``/tracez`` returns a flight-recorder dump, which is saved to
    ``--out`` for ``scripts/check_trace.py --flight`` to validate.

Usage: ``python scripts/admin_smoke.py [--devices N] [--out PATH]``.
Exit 0 on success; raises (non-zero exit) on any violation.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import urllib.request
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

_SAMPLE_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")


def _get(url: str) -> bytes:
    with urllib.request.urlopen(url, timeout=30) as r:
        assert r.status == 200, f"{url}: HTTP {r.status}"
        return r.read()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--out", default="/tmp/repro_admin_tracez.json",
                    help="where to save the /tracez flight dump")
    args = ap.parse_args(argv)

    import numpy as np
    import repro
    from repro import obs, serve

    options = repro.Options(backend="reference")
    server = serve.Server(serve.ServeConfig(
        max_batch=4, max_wait_ms=2.0, devices=args.devices, admin_port=0))
    server.register("edge", repro.Program.from_pipeline("edge_detect",
                                                        32, 32, 3),
                    options, slo=obs.SLO(p99_ms=60_000.0))
    server.register("idle", repro.Program.from_pipeline("sharpen", 32, 32, 3),
                    options)
    server.start(warm=True)
    url = server.admin.url
    print(f"admin_smoke: endpoint at {url} (devices={args.devices})")
    try:
        frames = np.random.default_rng(0).random((32, 32, 3), np.float32)
        futs = [server.submit("edge", frames) for _ in range(args.requests)]
        for f in futs:
            f.result(timeout=120)

        health = json.loads(_get(url + "/healthz"))
        assert health["healthy"], f"unhealthy under no faults: {health}"
        ready = json.loads(_get(url + "/readyz"))
        assert ready["ready"] and ready["checks"]["warmed"], ready

        metrics = _get(url + "/metrics").decode()
        helped, typed = set(), set()
        for line in metrics.splitlines():
            if not line:
                continue
            if line.startswith("# HELP "):
                helped.add(line.split()[2])
            elif line.startswith("# TYPE "):
                typed.add(line.split()[2])
            elif not line.startswith("#"):
                name = line.split("{")[0].split()[0]
                assert _SAMPLE_RE.fullmatch(name), f"illegal name: {line!r}"
                float(line.rsplit(" ", 1)[1])     # value parses
        assert typed and typed == helped, \
            f"HELP/TYPE mismatch: {typed ^ helped}"
        served = [ln for ln in metrics.splitlines()
                  if ln.startswith("serve_edge_served ")]
        assert served and float(served[0].split()[1]) == args.requests, \
            f"served counter wrong: {served}"

        status = json.loads(_get(url + "/statusz"))
        edge = status["programs"]["edge"]
        assert edge["requests"]["served"] == args.requests, edge["requests"]
        assert edge["slo"]["objectives"]["p99_ms"]["limit"] == 60_000.0
        assert status["programs"]["idle"]["latency_ms"] == {"count": 0}, \
            "empty-window latency summary corrupted through /statusz"
        if args.devices > 1:
            assert status["pool"]["devices"] == args.devices

        dump = _get(url + "/tracez")
        Path(args.out).write_bytes(dump)
        n = len(json.loads(dump)["traceEvents"])
        print(f"admin_smoke: OK ({args.requests} served, {n} flight "
              f"records -> {args.out})")
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
