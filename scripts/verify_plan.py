#!/usr/bin/env python
"""Plan-verifier CLI: prove the kernel invariants for registered workloads.

Compiles every requested model / imaging pipeline and runs
``repro.analysis.verify_plan`` over the resulting ``CompiledPlan``:
the ``|acc| < 2^24`` integer-exactness proof (with per-step headroom in
bits), the shape-legality re-walk, and the strip/fusion VMEM audit
(docs/analysis.md has the code glossary).

CI usage (a ``scripts/ci.sh`` gate)::

    python scripts/verify_plan.py --all          # every model + pipeline

Exit code 1 if any target produces an error-severity diagnostic (or
fails to compile); warnings are printed but do not fail the gate.
Ad-hoc::

    python scripts/verify_plan.py --model vgg16 -v
    python scripts/verify_plan.py --pipeline edge_detect --size 128
"""

from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")


def _verify_one(name: str, program, verbose: bool) -> int:
    """Compile + verify one program; returns the number of errors."""
    import repro
    from repro import analysis

    try:
        # verify="off" here: we run the verifier ourselves to get the
        # info-level headroom report, and we want ALL findings printed
        # rather than the first compile raising
        exe = program.compile(repro.Options(verify="off"))
    except Exception as e:                      # compile itself failed
        print(f"verify_plan: {name}: COMPILE FAILED — {e}")
        return 1
    diags = analysis.verify_plan(exe.plan)
    errs = analysis.errors(diags)
    warns = [d for d in diags if d.severity == "warning"]
    infos = [d for d in diags if d.severity == "info"]
    headrooms = []
    for d in infos:
        if d.code == "LTR003" and "headroom" in d.message:
            headrooms.append(
                float(d.message.split("headroom ")[1].split(" bits")[0]))
    status = "FAIL" if errs else "OK"
    hr = (f", min headroom {min(headrooms):.2f} bits"
          if headrooms else "")
    print(f"verify_plan: {name}: {status} ({len(diags)} finding(s), "
          f"{len(errs)} error(s), {len(warns)} warning(s){hr})")
    shown = diags if verbose else [d for d in diags
                                   if d.severity != "info"]
    for d in shown:
        print(f"  {d}")
    return len(errs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="verify every registered model and pipeline")
    ap.add_argument("--model", action="append", default=[],
                    help="a registered CNN (lenet/vgg9/vgg16); repeatable")
    ap.add_argument("--pipeline", action="append", default=[],
                    help="a registered imaging pipeline; repeatable")
    ap.add_argument("--size", type=int, default=64,
                    help="imaging pipeline frame size (default 64)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print info-level findings (per-step headroom)")
    args = ap.parse_args(argv)

    import repro
    from repro.imaging import PIPELINES
    from repro.models.vision import MODEL_INPUT_HWC

    models = list(args.model)
    pipelines = list(args.pipeline)
    if args.all:
        models = sorted(MODEL_INPUT_HWC)
        pipelines = sorted(PIPELINES)
    if not models and not pipelines:
        ap.error("nothing to verify: pass --all, --model or --pipeline")

    errors = 0
    for name in models:
        # params are irrelevant to the static pass — compile schedule-only
        errors += _verify_one(
            name, repro.Program.from_model(name, params={}), args.verbose)
    for name in pipelines:
        errors += _verify_one(
            name, repro.Program.from_pipeline(name, args.size, args.size, 3),
            args.verbose)

    n = len(models) + len(pipelines)
    if errors:
        print(f"verify_plan: FAIL — {errors} error(s) across {n} target(s)",
              file=sys.stderr)
        return 1
    print(f"verify_plan: OK ({n} target(s) proved |acc| < 2^24, shapes "
          f"legal, VMEM audit clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
