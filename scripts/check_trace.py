"""Validate an exported Chrome-trace JSON from ``serve_vision --trace``.

The CI serving smoke (scripts/ci.sh) runs a traced Poisson load and then
asserts the artifact is actually useful, not just parseable:

  1. the file round-trips ``json.loads`` and has the Trace Event Format
     shape (``traceEvents`` list; every event carries name/ph/pid/tid/ts,
     duration events carry ``dur``);
  2. there is at least one ``serve.request.device`` span — a trace with
     zero device spans means the instrumentation hooks silently died;
  3. at least one request has a COMPLETE timeline: all four
     ``serve.request.*`` phases (queue_wait -> batch_assembly -> device ->
     split) sharing one ``trace_id``, contiguous and in order — the
     acceptance criterion's "decompose one request's latency" artifact;
  4. with ``--min-devices N``, the pool actually spread work: at least N
     distinct device lanes appear among the ``serve.device.execute``
     spans (each pool worker records its executions on a ``device<i>``
     lane) — the CI pool smoke's "the fan-out happened" check.

``--flight`` switches to validating a **flight-recorder dump**
(``FlightRecorder.dump()`` / the server's automatic incident dumps /
``GET /tracez``) instead of a request-timeline trace:

  1. same Trace Event Format schema checks, and at least one record;
  2. ring integrity: every record carries ``args.seq``/``args.ring``,
     and per ring the retained sequence numbers are *contiguous* —
     overwrite-oldest may drop history from the front, but can never
     leave a gap inside what is retained;
  3. monotonic time: within one ring, records grouped by display lane
     end in non-decreasing timestamp order (file order = ring order);
  4. a *triggered* dump (one containing a ``flight.trigger`` instant —
     the server records it immediately before dumping) must retain at
     least one span that ended at-or-before the earliest trigger: the
     black box actually captured history from *before* the incident
     (``--require-trigger`` makes a missing trigger an error).

Usage: ``python scripts/check_trace.py out.json [--min-device-spans N]
[--min-devices N] [--flight [--require-trigger]]``. Exit 0 on success;
prints every violation otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys

PHASES = ("serve.request.queue_wait", "serve.request.batch_assembly",
          "serve.request.device", "serve.request.split")


def check(path: str, min_device_spans: int = 1, min_devices: int = 0) -> list:
    errors = []
    try:
        data = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable trace JSON: {e}"]
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents list"]

    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                errors.append(f"event[{i}]: missing {k!r}")
        if ev.get("ph") in ("X", "i") and "ts" not in ev:
            errors.append(f"event[{i}] ({ev.get('name')}): missing ts")
        if ev.get("ph") == "X" and "dur" not in ev:
            errors.append(f"event[{i}] ({ev.get('name')}): X without dur")
        if errors and len(errors) > 10:
            errors.append("... (further schema violations suppressed)")
            break

    device = [e for e in events
              if e.get("name") == "serve.request.device" and e.get("ph") == "X"]
    if len(device) < min_device_spans:
        errors.append(f"{len(device)} device spans < required "
                      f"{min_device_spans}")

    if min_devices > 0:
        # pool fan-out: distinct devices among the per-device execute
        # lanes (fall back to the device attr the request spans carry)
        lanes = {e["args"]["device"] for e in events
                 if e.get("ph") == "X"
                 and e.get("name") == "serve.device.execute"
                 and "device" in e.get("args", {})}
        lanes |= {e["args"]["device"] for e in device
                  if "device" in e.get("args", {})}
        if len(lanes) < min_devices:
            errors.append(
                f"{len(lanes)} distinct device lane(s) {sorted(lanes)} < "
                f"required {min_devices}: the pool never spread work")

    # per-request timelines: group the serve.request.* spans by trace_id
    timelines = {}
    for e in events:
        if e.get("ph") != "X" or e.get("name") not in PHASES:
            continue
        tid = e.get("args", {}).get("trace_id")
        if tid is not None:
            timelines.setdefault(tid, []).append(e)
    complete = 0
    for tid, spans in timelines.items():
        by_name = {s["name"]: s for s in spans}
        if set(by_name) != set(PHASES):
            continue
        ordered = [by_name[p] for p in PHASES]
        ok = all(ordered[j]["ts"] + ordered[j]["dur"]
                 <= ordered[j + 1]["ts"] + 1.0          # 1us slack
                 for j in range(len(ordered) - 1))
        if ok:
            complete += 1
    if not complete:
        errors.append(
            f"no complete per-request timeline: of {len(timelines)} "
            f"trace_ids none has all four phases in order {PHASES}")
    return errors


def flight_check(path: str, require_trigger: bool = False) -> list:
    """Validate a flight-recorder dump (see module docstring, --flight)."""
    errors = []
    try:
        data = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable flight JSON: {e}"]
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents list"]

    records = []
    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                errors.append(f"event[{i}]: missing {k!r}")
        if ev.get("ph") == "M":
            continue
        if ev.get("ph") not in ("X", "i"):
            errors.append(f"event[{i}] ({ev.get('name')}): unexpected "
                          f"ph {ev.get('ph')!r} in a flight dump")
            continue
        if "ts" not in ev:
            errors.append(f"event[{i}] ({ev.get('name')}): missing ts")
            continue
        if ev["ph"] == "X" and "dur" not in ev:
            errors.append(f"event[{i}] ({ev.get('name')}): X without dur")
            continue
        args = ev.get("args", {})
        if "seq" not in args or "ring" not in args:
            errors.append(f"event[{i}] ({ev.get('name')}): flight record "
                          f"missing args.seq/args.ring")
            continue
        records.append(ev)
        if len(errors) > 10:
            errors.append("... (further schema violations suppressed)")
            break
    if not records:
        errors.append("no flight records (X/i events with args.seq)")
        return errors

    # ring integrity: per ring, retained seqs are contiguous — the ring
    # overwrites from the *front* of history, never punches holes in it
    rings = {}
    for ev in records:
        rings.setdefault(ev["args"]["ring"], []).append(ev)
    for ring, evs in sorted(rings.items()):
        seqs = sorted(e["args"]["seq"] for e in evs)
        if len(set(seqs)) != len(seqs):
            errors.append(f"ring {ring}: duplicate seq numbers")
        elif seqs != list(range(seqs[0], seqs[0] + len(seqs))):
            missing = sorted(set(range(seqs[0], seqs[-1] + 1)) - set(seqs))
            errors.append(
                f"ring {ring}: gap inside retained history — seqs "
                f"{seqs[0]}..{seqs[-1]} missing {missing[:8]}"
                f"{'...' if len(missing) > 8 else ''}")
        # monotonic time per (ring, lane), in retained (file) order: a
        # ring records strictly forward in time, so within one display
        # lane each record must END no earlier than its predecessor
        # (1us slack for rounding)
        by_lane = {}
        for e in evs:
            by_lane.setdefault(e["tid"], []).append(e)
        for lane, les in by_lane.items():
            last_end = None
            for e in les:
                end = e["ts"] + e.get("dur", 0.0)
                if last_end is not None and end + 1.0 < last_end:
                    errors.append(
                        f"ring {ring} lane {lane}: non-monotonic "
                        f"timestamps ({e['name']} ends {end:.1f}us after "
                        f"a record ending {last_end:.1f}us)")
                    break
                last_end = end

    # triggered dump: the black box must hold history from BEFORE the
    # trigger, or it dumped too late to explain the incident
    triggers = [e for e in records if e["name"] == "flight.trigger"]
    if require_trigger and not triggers:
        errors.append("no flight.trigger event (--require-trigger)")
    if triggers:
        t_trigger = min(e["ts"] for e in triggers)
        pre = [e for e in records if e["ph"] == "X"
               and e["ts"] + e.get("dur", 0.0) <= t_trigger + 1.0]
        if not pre:
            errors.append(
                f"triggered dump ({data.get('otherData', {}).get('reason')}) "
                f"retains no span ending at-or-before the trigger at "
                f"{t_trigger:.1f}us — no pre-incident history")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON to validate")
    ap.add_argument("--min-device-spans", type=int, default=1)
    ap.add_argument("--min-devices", type=int, default=0,
                    help="require >= N distinct pool device lanes")
    ap.add_argument("--flight", action="store_true",
                    help="validate a flight-recorder dump instead of a "
                         "request-timeline trace")
    ap.add_argument("--require-trigger", action="store_true",
                    help="with --flight: a missing flight.trigger event "
                         "is an error (for automatic incident dumps)")
    args = ap.parse_args(argv)
    if args.flight:
        errors = flight_check(args.trace, args.require_trigger)
    else:
        errors = check(args.trace, args.min_device_spans, args.min_devices)
    if errors:
        for e in errors:
            print(f"check_trace: FAIL — {e}", file=sys.stderr)
        return 1
    data = json.loads(open(args.trace).read())
    n = len(data["traceEvents"])
    kind = "flight dump" if args.flight else "trace"
    print(f"check_trace: OK ({args.trace}: {n} events, {kind})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
