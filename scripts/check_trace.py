"""Validate an exported Chrome-trace JSON from ``serve_vision --trace``.

The CI serving smoke (scripts/ci.sh) runs a traced Poisson load and then
asserts the artifact is actually useful, not just parseable:

  1. the file round-trips ``json.loads`` and has the Trace Event Format
     shape (``traceEvents`` list; every event carries name/ph/pid/tid/ts,
     duration events carry ``dur``);
  2. there is at least one ``serve.request.device`` span — a trace with
     zero device spans means the instrumentation hooks silently died;
  3. at least one request has a COMPLETE timeline: all four
     ``serve.request.*`` phases (queue_wait -> batch_assembly -> device ->
     split) sharing one ``trace_id``, contiguous and in order — the
     acceptance criterion's "decompose one request's latency" artifact;
  4. with ``--min-devices N``, the pool actually spread work: at least N
     distinct device lanes appear among the ``serve.device.execute``
     spans (each pool worker records its executions on a ``device<i>``
     lane) — the CI pool smoke's "the fan-out happened" check.

Usage: ``python scripts/check_trace.py out.json [--min-device-spans N]
[--min-devices N]``. Exit 0 on success; prints every violation otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys

PHASES = ("serve.request.queue_wait", "serve.request.batch_assembly",
          "serve.request.device", "serve.request.split")


def check(path: str, min_device_spans: int = 1, min_devices: int = 0) -> list:
    errors = []
    try:
        data = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable trace JSON: {e}"]
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents list"]

    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                errors.append(f"event[{i}]: missing {k!r}")
        if ev.get("ph") in ("X", "i") and "ts" not in ev:
            errors.append(f"event[{i}] ({ev.get('name')}): missing ts")
        if ev.get("ph") == "X" and "dur" not in ev:
            errors.append(f"event[{i}] ({ev.get('name')}): X without dur")
        if errors and len(errors) > 10:
            errors.append("... (further schema violations suppressed)")
            break

    device = [e for e in events
              if e.get("name") == "serve.request.device" and e.get("ph") == "X"]
    if len(device) < min_device_spans:
        errors.append(f"{len(device)} device spans < required "
                      f"{min_device_spans}")

    if min_devices > 0:
        # pool fan-out: distinct devices among the per-device execute
        # lanes (fall back to the device attr the request spans carry)
        lanes = {e["args"]["device"] for e in events
                 if e.get("ph") == "X"
                 and e.get("name") == "serve.device.execute"
                 and "device" in e.get("args", {})}
        lanes |= {e["args"]["device"] for e in device
                  if "device" in e.get("args", {})}
        if len(lanes) < min_devices:
            errors.append(
                f"{len(lanes)} distinct device lane(s) {sorted(lanes)} < "
                f"required {min_devices}: the pool never spread work")

    # per-request timelines: group the serve.request.* spans by trace_id
    timelines = {}
    for e in events:
        if e.get("ph") != "X" or e.get("name") not in PHASES:
            continue
        tid = e.get("args", {}).get("trace_id")
        if tid is not None:
            timelines.setdefault(tid, []).append(e)
    complete = 0
    for tid, spans in timelines.items():
        by_name = {s["name"]: s for s in spans}
        if set(by_name) != set(PHASES):
            continue
        ordered = [by_name[p] for p in PHASES]
        ok = all(ordered[j]["ts"] + ordered[j]["dur"]
                 <= ordered[j + 1]["ts"] + 1.0          # 1us slack
                 for j in range(len(ordered) - 1))
        if ok:
            complete += 1
    if not complete:
        errors.append(
            f"no complete per-request timeline: of {len(timelines)} "
            f"trace_ids none has all four phases in order {PHASES}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON to validate")
    ap.add_argument("--min-device-spans", type=int, default=1)
    ap.add_argument("--min-devices", type=int, default=0,
                    help="require >= N distinct pool device lanes")
    args = ap.parse_args(argv)
    errors = check(args.trace, args.min_device_spans, args.min_devices)
    if errors:
        for e in errors:
            print(f"check_trace: FAIL — {e}", file=sys.stderr)
        return 1
    data = json.loads(open(args.trace).read())
    n = len(data["traceEvents"])
    print(f"check_trace: OK ({args.trace}: {n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
