"""Benchmark regression gate (run by scripts/ci.sh).

Two layers of checking over the committed ``benchmarks/BENCH_*.json``
artifacts, so a PR that regenerates them cannot silently regress the
numbers they exist to pin:

  1. **Invariants** — absolute properties of the *current* files that must
     hold regardless of machine speed: the megakernel fusion ablation is
     bitwise-exact and at least ``FUSED_MIN_SPEEDUP``x faster than the
     per-conv path at 256x256; kernel-vs-oracle errors stay at float
     epsilon; the depthwise raw accumulate is exactly 0 error; serving
     micro-batching sustains ``SERVE_MIN_SPEEDUP``x request-at-a-time;
     the 4-virtual-device pool scales >= ``POOL_MIN_SCALING``x over one
     device on the emulated-device axis (serving schema >= 2);
     disabled-path obs overhead stays under ``OBS_MAX_OVERHEAD_PCT``;
     the always-on flight recorder costs < ``FLIGHT_MAX_OVERHEAD_PCT``
     of serving throughput (obs schema >= 2).
     Every numeric leaf in every file must additionally be *finite* — a
     NaN or inf scalar is always an artifact bug (empty-reservoir
     percentile, zero-window rate), never a measurement.
  2. **Regression band** — every timing (``*_us``) and throughput
     (``fps*``) scalar is compared against the same file at a baseline git
     ref (default ``HEAD``, override with ``--base``). Timings may not be
     more than ``tolerance``x slower and throughputs not more than
     ``tolerance``x lower (default 2.0 — CPU CI timing is noisy; override
     with ``--tolerance`` or ``REPRO_BENCH_TOLERANCE``). Improvements are
     never flagged.

Both layers are **schema-version-aware**: when ``schema_version`` differs
between the working tree and the baseline (a schema migration PR, like the
one that introduced ``fused_chain``), the regression band is skipped for
that file — there is nothing comparable to diff against — but the
invariants still run. A file missing at the baseline ref is treated the
same way.

Exit code 0 on success; prints every violation otherwise.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BENCH_DIR = ROOT / "benchmarks"
FILES = ("BENCH_kernels.json", "BENCH_imaging.json", "BENCH_serving.json",
         "BENCH_obs.json", "BENCH_analysis.json")
FUSED_MIN_SPEEDUP = 1.5   # acceptance bar for the 256x256 chain ablation
SERVE_MIN_SPEEDUP = 2.0   # micro-batching vs request-at-a-time at saturation
POOL_MIN_SCALING = 1.5    # 4-device pool vs 1 device, emulated device time
ORACLE_ERR_MAX = 1e-5     # dequant float epsilon, not a kernel bug
OBS_MAX_OVERHEAD_PCT = 2.0  # disabled-path obs cost on the 3-stage chain
FLIGHT_MAX_OVERHEAD_PCT = 5.0  # always-on flight recorder, serving fps axis
VERIFY_MAX_OVERHEAD_PCT = 5.0  # plan verification riding the compile pass


def _baseline(name: str, ref: str):
    """The committed version of benchmarks/<name> at ``ref`` (None if new)."""
    proc = subprocess.run(
        ["git", "show", f"{ref}:benchmarks/{name}"],
        cwd=ROOT, capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def _scalars(obj, prefix=""):
    """Flatten to {dotted.path: float} for every numeric leaf."""
    flat = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            flat.update(_scalars(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            flat.update(_scalars(v, f"{prefix}{i}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        flat[prefix[:-1]] = float(obj)
    return flat


def check_finite(name: str, data: dict, errors: list) -> None:
    """No NaN/inf scalar anywhere in a BENCH file (every file).

    A NaN percentile (e.g. from an empty latency reservoir) or an inf
    speedup (zero-window rate) is always an artifact bug, never a real
    measurement — and it silently poisons the regression band.
    """
    import math
    for path, v in _scalars(data).items():
        if not math.isfinite(v):
            errors.append(f"{name}: {path} is {v} — non-finite scalar")


def check_invariants(name: str, data: dict, errors: list) -> None:
    def bad(msg):
        errors.append(f"{name}: {msg}")

    if name == "BENCH_kernels.json":
        fused = data.get("fused_chain", {})
        if not fused:
            bad("fused_chain section missing (schema_version >= 2)")
        for hw, e in fused.items():
            if not e.get("bitwise_equal"):
                bad(f"fused_chain.{hw}: fused output not bitwise-identical")
            if e.get("speedup", 0.0) < FUSED_MIN_SPEEDUP:
                bad(f"fused_chain.{hw}: speedup {e.get('speedup'):.2f}x "
                    f"< required {FUSED_MIN_SPEEDUP}x")
        for sec in ("micro", "conv_strategy_sweep"):
            for key, e in data.get(sec, {}).items():
                for k, v in e.items():
                    if k.endswith("max_abs_err") and v > ORACLE_ERR_MAX:
                        bad(f"{sec}.{key}.{k}: {v:.2e} > {ORACLE_ERR_MAX}")
        dw = {k: v for k, v in data.get("conv_strategy_sweep", {}).items()
              if k.startswith("depthwise_")}
        for key, e in dw.items():
            if e.get("max_abs_err", 1.0) != 0.0:
                bad(f"conv_strategy_sweep.{key}: raw accumulate err "
                    f"{e['max_abs_err']} != 0")

    elif name == "BENCH_imaging.json":
        for pipe, e in data.get("pipelines", {}).items():
            for sname, s in e.get("schemes", {}).items():
                if s.get("fps", 0.0) <= 0:
                    bad(f"{pipe}.{sname}: non-positive fps")
            abl = e.get("fused_ablation")
            if abl is not None:
                if abl.get("fps_fused", 0.0) <= 0 \
                        or abl.get("fps_unfused", 0.0) <= 0:
                    bad(f"{pipe}.fused_ablation: non-positive fps")
                if not abl.get("segments"):
                    bad(f"{pipe}.fused_ablation: empty segment list")

    elif name == "BENCH_serving.json":
        abl = data.get("ablation", {})
        if abl.get("speedup", 0.0) < SERVE_MIN_SPEEDUP:
            bad(f"ablation: micro-batching speedup {abl.get('speedup')} "
                f"< required {SERVE_MIN_SPEEDUP}x")
        if data.get("schema_version", 1) >= 2:
            pool = data.get("pool_ablation")
            if not pool:
                bad("pool_ablation section missing (schema_version >= 2)")
            elif "skipped" not in pool:
                # the gated axis is the EMULATED-device scaling: it
                # measures the host runtime feeding 4 devices, which must
                # scale even on a 1-core CI box (the sleeps overlap).
                # xla.speedup is reported but not gated — real virtual
                # devices share the host's cores.
                em = pool.get("emulated", {})
                if em.get("speedup", 0.0) < POOL_MIN_SCALING:
                    bad(f"pool_ablation.emulated: 4-device scaling "
                        f"{em.get('speedup', 0.0):.2f}x < required "
                        f"{POOL_MIN_SCALING}x")

    elif name == "BENCH_obs.json":
        chain = data.get("chain", {})
        if "overhead_disabled_pct" not in chain:
            bad("chain.overhead_disabled_pct missing")
        elif chain["overhead_disabled_pct"] >= OBS_MAX_OVERHEAD_PCT:
            bad(f"chain.overhead_disabled_pct "
                f"{chain['overhead_disabled_pct']:.2f}% >= "
                f"{OBS_MAX_OVERHEAD_PCT}% — disabled tracing must be free")
        if chain.get("frame_us_raw", 0.0) <= 0:
            bad("chain.frame_us_raw must be > 0")
        if data.get("schema_version", 1) >= 2:
            fl = data.get("flight")
            if not fl:
                bad("flight section missing (schema_version >= 2)")
            else:
                if fl.get("fps_flight_on", 0.0) <= 0:
                    bad("flight.fps_flight_on must be > 0")
                if "overhead_pct" not in fl:
                    bad("flight.overhead_pct missing")
                elif fl["overhead_pct"] >= FLIGHT_MAX_OVERHEAD_PCT:
                    bad(f"flight.overhead_pct {fl['overhead_pct']:.2f}% >= "
                        f"{FLIGHT_MAX_OVERHEAD_PCT}% — the flight recorder "
                        f"is always on, it must stay near-free")

    elif name == "BENCH_analysis.json":
        v = data.get("verify", {})
        if "overhead_pct" not in v:
            bad("verify.overhead_pct missing")
        elif v["overhead_pct"] >= VERIFY_MAX_OVERHEAD_PCT:
            bad(f"verify.overhead_pct {v['overhead_pct']:.2f}% >= "
                f"{VERIFY_MAX_OVERHEAD_PCT}% — Options(verify=\"auto\") "
                f"rides every first compile, it must be ~free")
        if v.get("verify_us", 0.0) <= 0:
            bad("verify.verify_us must be > 0")
        lint = data.get("lint", {})
        if lint.get("errors", 1) != 0:
            bad(f"lint.errors = {lint.get('errors')} — the serve/obs tree "
                f"must be lint-clean when the artifact is regenerated")


def check_regression(name: str, data: dict, base: dict, tolerance: float,
                     errors: list, notes: list) -> None:
    if base is None:
        notes.append(f"{name}: no baseline at ref — regression band skipped")
        return
    if base.get("schema_version") != data.get("schema_version"):
        notes.append(
            f"{name}: schema_version {base.get('schema_version')} -> "
            f"{data.get('schema_version')} — regression band skipped")
        return
    cur, old = _scalars(data), _scalars(base)
    for path in sorted(set(cur) & set(old)):
        leaf = path.rsplit(".", 1)[-1]
        a, b = old[path], cur[path]
        if a <= 0 or b <= 0:
            continue
        if leaf.endswith("_us") and b / a > tolerance:
            errors.append(f"{name}: {path} slowed {b / a:.2f}x "
                          f"({a:.0f}us -> {b:.0f}us, tolerance "
                          f"{tolerance}x)")
        elif "fps" in path and a / b > tolerance:
            errors.append(f"{name}: {path} throughput dropped "
                          f"{a / b:.2f}x ({a:.0f} -> {b:.0f} fps, "
                          f"tolerance {tolerance}x)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base", default="HEAD",
                    help="git ref to diff the JSONs against (default HEAD)")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("REPRO_BENCH_TOLERANCE",
                                                 "2.0")),
                    help="allowed slowdown factor before failing")
    args = ap.parse_args(argv)

    errors, notes = [], []
    for name in FILES:
        path = BENCH_DIR / name
        if not path.exists():
            errors.append(f"{name}: missing from benchmarks/")
            continue
        data = json.loads(path.read_text())
        check_finite(name, data, errors)
        check_invariants(name, data, errors)
        check_regression(name, data, _baseline(name, args.base),
                         args.tolerance, errors, notes)

    for n in notes:
        print(f"check_bench: note — {n}")
    if errors:
        for e in errors:
            print(f"check_bench: FAIL — {e}", file=sys.stderr)
        return 1
    print(f"check_bench: OK ({len(FILES)} files, "
          f"tolerance {args.tolerance}x vs {args.base})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
