"""Regenerate the golden imaging arrays under tests/golden/.

One ``<pipeline>.npz`` per ``imaging.PIPELINES`` entry, holding the float
reference output and the quantized device output (W4A4, reference backend)
for a fixed deterministic input batch. ``tests/test_imaging_golden.py``
recomputes both and asserts a close match — any numerics change to the
filters, the plan runtime, or the quantization path trips it.

Run (only) when an intentional numerics change invalidates the arrays:

    PYTHONPATH=src python scripts/gen_golden.py
"""

from __future__ import annotations

from pathlib import Path

import jax.numpy as jnp
import numpy as np

import repro
from repro.core.quant import W4A4
from repro.data.synthetic import synthetic_textures
from repro.imaging import PIPELINES, apply_float

GOLDEN_DIR = Path(__file__).resolve().parent.parent / "tests" / "golden"
BATCH, HW, SEED = 2, 32, 0


def golden_frames() -> jnp.ndarray:
    imgs, _ = synthetic_textures(BATCH, hw=HW, seed=SEED)
    return jnp.asarray(imgs)


def main() -> None:
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    frames = golden_frames()
    # pin the backend: goldens describe the reference numerics (the pallas
    # path is regression-tested bit-identical to it elsewhere)
    options = repro.Options(scheme=W4A4, backend="reference")
    for name, pipe in sorted(PIPELINES.items()):
        prog = pipe.program(HW, HW, 3)
        float_out = np.asarray(apply_float(prog.layers, prog.params, frames),
                               np.float32)
        quant_out = np.asarray(prog.compile(options).run(frames), np.float32)
        # the input frames ride along so the goldens are self-contained
        # (the test needs no access to the generator's input recipe)
        path = GOLDEN_DIR / f"{name}.npz"
        np.savez_compressed(path, frames=np.asarray(frames, np.float32),
                            float_out=float_out, quant_out=quant_out,
                            batch=BATCH, hw=HW, seed=SEED, scheme="w4a4")
        print(f"wrote {path} float{float_out.shape} "
              f"quant{quant_out.shape}")


if __name__ == "__main__":
    main()
