"""Docs hygiene gate (run by scripts/ci.sh).

Checks:
  1. every ``docs/*.md`` file is referenced from README.md — docs that
     nothing links to rot silently;
  2. no dead relative links: every ``[text](relative/path)`` in README.md
     and docs/*.md must resolve to an existing file (anchors stripped;
     http(s) links ignored).

Exit code 0 on success; prints every violation otherwise.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def relative_links(md: Path):
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


def main() -> int:
    errors = []
    readme = ROOT / "README.md"
    readme_text = readme.read_text()

    docs = sorted((ROOT / "docs").glob("*.md"))
    if not docs:
        errors.append("docs/: no markdown files found")
    for doc in docs:
        rel = doc.relative_to(ROOT).as_posix()
        if rel not in readme_text:
            errors.append(f"README.md does not reference {rel}")

    for md in [readme, *docs]:
        for target in relative_links(md):
            if not (md.parent / target).exists():
                errors.append(
                    f"{md.relative_to(ROOT)}: dead link -> {target}")

    if errors:
        print("\n".join(f"check_docs: {e}" for e in errors))
        return 1
    print(f"check_docs: OK ({len(docs)} docs, all referenced, no dead links)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
