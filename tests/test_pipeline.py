"""Pipeline parallelism: GPipe staging == sequential scan (4-device sim).

Multi-device PP needs >1 device, so the equivalence check runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4 (the
main test session keeps its single-device view).
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == 0.0
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(2, 14) == pytest.approx(1 / 15)


PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_forward

    mesh = jax.make_mesh((4,), ("stage",))
    L, B, T, D = 8, 8, 4, 16
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {"w": 0.3 * jax.random.normal(k1, (L, D, D)),
              "b": 0.01 * jax.random.normal(k2, (L, D))}
    x = jax.random.normal(k3, (B, T, D))

    def body(lp, h):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    # sequential reference
    def seq(x):
        def step(c, lp):
            return body(lp, c), None
        out, _ = jax.lax.scan(step, x, params)
        return out

    want = seq(x)
    got = pipeline_forward(params, x, body, mesh, "stage",
                           n_microbatches=4)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, f"pipeline != sequential: {err}"
    # also exercise M != S
    got2 = pipeline_forward(params, x, body, mesh, "stage",
                            n_microbatches=8)
    err2 = float(jnp.max(jnp.abs(got2 - want)))
    assert err2 < 1e-5, err2
    print("PIPELINE-OK", err, err2)
""")


def test_gpipe_matches_sequential_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", PROG], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE-OK" in r.stdout, r.stdout + r.stderr
