"""repro.serve — the serving runtime's contracts.

The load-bearing one: the micro-batcher's pad -> bucket -> split round
trip is **bit-identical** to direct per-request ``Executable.run`` across
odd batch sizes, mixed programs and both kernel backends. That identity
rests on per-frame CRC calibration (``Executable.run_per_frame``), which
is itself pinned here: per-frame results are independent of batch
composition and equal to batch-1 runs bit-for-bit, while the seed
per-tensor path demonstrably couples batch neighbours (the reason the
batcher cannot coalesce on the default executor).

Plus the scheduler semantics: admission control / backpressure, deadline
shedding, multi-program routing, drain/stop, stats sanity, and the
open-loop Poisson load generator's accounting.
"""

import threading
import time

import jax
import numpy as np
import pytest

import repro
from repro import serve
from repro.core.quant import W4A4
from repro.serve import batcher

REFERENCE = repro.Options(scheme=W4A4, backend="reference")


@pytest.fixture(scope="module")
def lenet_exe():
    prog = repro.Program.from_model("lenet", key=jax.random.PRNGKey(0))
    return prog, prog.compile(REFERENCE)


@pytest.fixture(scope="module")
def frames28():
    rng = np.random.default_rng(0)
    f = rng.random((9, 28, 28, 1)).astype(np.float32)
    f[1] *= 0.05        # a dim frame: per-tensor calibration would couple it
    return f


def _singles(exe, frames):
    """Per-request ground truth: each frame through a batch-1 run."""
    return np.concatenate(
        [np.asarray(exe.run(frames[i][None])) for i in range(len(frames))])


# -- per-frame calibration: the soundness base --------------------------------

def test_per_frame_equals_batch1_and_isolates_neighbours(lenet_exe, frames28):
    _, exe = lenet_exe
    singles = _singles(exe, frames28)
    pf = np.asarray(exe.run_per_frame(frames28))
    np.testing.assert_array_equal(pf, singles)
    # while the seed per-tensor path couples batch neighbours (the dim
    # frame's codes shift under the bright frames' shared scale)
    pt = np.asarray(exe.run(frames28))
    assert not np.array_equal(pt, singles)
    # at batch 1 the two calibrations are the same reduction — bit-identical
    one = frames28[:1]
    np.testing.assert_array_equal(np.asarray(exe.run_per_frame(one)),
                                  np.asarray(exe.run(one)))


@pytest.mark.parametrize("n", [1, 3, 5, 7, 9])
def test_run_padded_round_trip_bit_identical(lenet_exe, frames28, n):
    """Satellite property test: pad -> bucket -> split == per-request runs
    across odd batch sizes (n=9 > bucket exercises the chunked path)."""
    _, exe = lenet_exe
    frames = frames28[:n]
    out = np.asarray(exe.run_padded(frames, bucket=8))
    np.testing.assert_array_equal(out, _singles(exe, frames))


def test_run_padded_pad_content_is_inert(lenet_exe, frames28):
    """The pad frames are zeros, but ANY content must be inert — prove it
    by comparing a padded run against the same frames alone."""
    _, exe = lenet_exe
    a = np.asarray(exe.run_padded(frames28[:3], bucket=4))
    b = np.asarray(exe.run_padded(frames28[:4], bucket=4))[:3]
    np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="bucket"):
        exe.run_padded(frames28[:2], bucket=0)


def test_warm_traces_every_bucket(lenet_exe):
    _, exe = lenet_exe
    assert exe.warm((1, 2, 4)) is exe
    with pytest.raises(ValueError, match="bucket"):
        exe.warm((0,))


# -- batcher helpers ----------------------------------------------------------

def test_bucket_helpers():
    assert batcher.power_of_two_buckets(8) == (1, 2, 4, 8)
    assert batcher.power_of_two_buckets(12) == (1, 2, 4, 8, 12)
    assert batcher.pick_bucket(3, (1, 2, 4, 8)) == 4
    assert batcher.pick_bucket(9, (1, 2, 4, 8)) == 8     # chunked upstream
    assert batcher.padded_slots(3, 4) == 4
    assert batcher.padded_slots(9, 8) == 16
    parts = batcher.split_results(np.arange(6), [1, 2, 3])
    assert [p.tolist() for p in parts] == [[0], [1, 2], [3, 4, 5]]
    with pytest.raises(ValueError, match="sum of request sizes"):
        batcher.split_results(np.arange(6), [1, 2])
    with pytest.raises(ValueError, match="max_batch"):
        batcher.power_of_two_buckets(0)


def test_should_close_early_predicate():
    # idle device + drained queue with a partial batch: close now
    assert batcher.should_close_early(3, 8, inflight_batches=0)
    # a batch is still computing: keep the window open (coalescing is free)
    assert not batcher.should_close_early(3, 8, inflight_batches=1)
    # device pool: close while ANY device in the pool is idle
    assert batcher.should_close_early(3, 8, inflight_batches=3, devices=4)
    assert not batcher.should_close_early(3, 8, inflight_batches=4, devices=4)
    # feature switched off
    assert not batcher.should_close_early(3, 8, 0, speculative=False)
    # nothing queued / batch already full: the predicate defers to the
    # normal collection logic
    assert not batcher.should_close_early(0, 8, 0)
    assert not batcher.should_close_early(8, 8, 0)


def test_virtual_clock():
    clk = serve.VirtualClock()
    assert clk.now() == 0.0
    assert clk.advance(1.5) == 1.5
    with pytest.raises(ValueError, match="backwards"):
        clk.advance(-1.0)
    cond = threading.Condition()
    with cond:
        t0 = time.monotonic()
        woke = clk.wait(cond, timeout=60.0)   # jumps, never sleeps 60s
        assert time.monotonic() - t0 < 5.0
    assert not woke and clk.now() == 61.5


def test_speculative_close_dispatches_before_window(lenet_exe, frames28):
    """With a long hold-open window and an idle device, a lone request must
    close speculatively — asserted via the batch-close reason hook and the
    virtual clock (zero window time burned), not a racy wall-clock bound."""
    prog, exe = lenet_exe
    clk = serve.VirtualClock()
    closes = []
    cfg = serve.ServeConfig(max_batch=8, max_wait_ms=5000.0)
    server = serve.Server(cfg, clock=clk, hooks=serve.Hooks(
        batch_close=lambda name, reason, n: closes.append((name, reason, n))))
    server.register("lenet", prog, REFERENCE)
    server.start()
    try:
        t0 = clk.now()
        out = server.submit("lenet", frames28[:1]).result(timeout=30)
        held = clk.now() - t0
        assert closes and closes[0] == ("lenet", "speculative", 1), closes
        assert held < 5.0, (
            f"speculative close should beat the 5s window, held {held:.2f}s "
            f"of virtual time")
        np.testing.assert_array_equal(out, np.asarray(exe.run(frames28[:1])))
    finally:
        server.stop()


def test_speculative_close_off_waits_out_window(lenet_exe, frames28):
    """With the feature off, the scheduler honours max_wait_ms — the batch
    closes with reason "window" after >= 400ms of *virtual* hold time."""
    prog, _ = lenet_exe
    clk = serve.VirtualClock()
    closes = []
    cfg = serve.ServeConfig(max_batch=8, max_wait_ms=400.0,
                            speculative_close=False)
    server = serve.Server(cfg, clock=clk, hooks=serve.Hooks(
        batch_close=lambda name, reason, n: closes.append((name, reason, n))))
    server.register("lenet", prog, REFERENCE)
    server.start()
    try:
        t0 = clk.now()
        server.submit("lenet", frames28[:1]).result(timeout=30)
        held = clk.now() - t0
        assert closes and closes[0] == ("lenet", "window", 1), closes
        assert held >= 0.4, (
            f"window should have held for 400ms of virtual time, "
            f"closed after {held:.3f}s")
    finally:
        server.stop()


# -- the server: bit-identity under concurrency -------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_server_round_trip_bit_identical_mixed_programs(backend):
    """Acceptance: micro-batched serving == direct Executable.run, with two
    programs interleaved (router) and odd request sizes (padding), on both
    kernel backends (pallas runs in interpret mode off-TPU)."""
    options = repro.Options(scheme=W4A4, backend=backend)
    lenet = repro.Program.from_model("lenet", key=jax.random.PRNGKey(0))
    edge = repro.Program.from_pipeline("edge_detect", 16, 16, 3)
    rng = np.random.default_rng(1)
    n_each = 4 if backend == "pallas" else 9
    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=2.0))
    hl = server.register("lenet", lenet, options)
    he = server.register("edge", edge, options)
    server.start()
    subs = []
    for i in range(n_each):
        f = rng.random((28, 28, 1), np.float32)
        subs.append((hl.executable, f, server.submit("lenet", f)))
        g = rng.random(((i % 3) + 1, 16, 16, 3), np.float32)   # 1..3 frames
        subs.append((he.executable, g, server.submit("edge", g)))
    for exe, f, fut in subs:
        got = fut.result(timeout=120)
        want = _singles(exe, f if f.ndim == 4 else f[None])
        np.testing.assert_array_equal(got, want)
    st = server.stats()
    assert st["requests"]["served"] == 2 * n_each
    server.stop()


def test_server_smoke_32_requests_stats_sane(lenet_exe, frames28):
    """The CI-smoke contract: submit 32 async requests, all served, stats
    snapshot internally consistent."""
    prog, exe = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=8, max_wait_ms=1.0))
    server.register("lenet", prog, REFERENCE)
    server.start()
    futs = [server.submit("lenet", frames28[i % len(frames28)])
            for i in range(32)]
    outs = [f.result(timeout=120) for f in futs]
    assert all(o.shape == (1, 10) for o in outs)
    snap = server.stats()
    p = snap["programs"]["lenet"]
    assert p["requests"]["served"] == 32 == snap["requests"]["served"]
    assert p["requests"]["pending"] == 0 and snap["queue_depth"] == 0
    lat = p["latency_ms"]
    assert lat["count"] == 32
    assert 0 < lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
    assert 0.0 <= p["padding_waste"] < 1.0
    assert p["achieved_fps"] > 0 and p["avg_batch"] >= 1.0
    assert p["model"]["kfps_per_w"] > 0
    server.stop()


def test_server_validates_at_submit(lenet_exe):
    prog, _ = lenet_exe
    server = serve.Server()
    server.register("lenet", prog, REFERENCE)
    with pytest.raises(ValueError, match="unknown program"):
        server.submit("bogus", np.zeros((28, 28, 1), np.float32))
    with pytest.raises(ValueError, match="do not match"):
        server.submit("lenet", np.zeros((32, 32, 3), np.float32))
    with pytest.raises(ValueError, match="no frames"):
        server.submit("lenet", np.zeros((0, 28, 28, 1), np.float32))
    too_big = np.zeros((serve.ServeConfig().max_queue + 1, 28, 28, 1),
                       np.float32)
    with pytest.raises(ValueError, match="exceeds max_queue"):
        server.submit("lenet", too_big)     # blocking wait is unsatisfiable
    with pytest.raises(ValueError, match="already registered"):
        server.register("lenet", prog, REFERENCE)
    with pytest.raises(RuntimeError, match="no programs"):
        serve.Server().start()


def test_admission_control_and_backpressure(lenet_exe, frames28):
    """Bounded queue: non-blocking submits are rejected when full, blocking
    submits time out (virtual backpressure wait — no real sleeping);
    starting the server drains the backlog."""
    prog, _ = lenet_exe
    clk = serve.VirtualClock()
    server = serve.Server(serve.ServeConfig(max_batch=2, max_queue=2,
                                            max_wait_ms=0.0), clock=clk)
    server.register("lenet", prog, REFERENCE)
    # not started: nothing drains the queue, so the bound must bite
    f1 = server.submit("lenet", frames28[0])
    f2 = server.submit("lenet", frames28[1])
    with pytest.raises(serve.AdmissionError, match="queue full"):
        server.submit("lenet", frames28[2], block=False)
    t0 = clk.now()
    with pytest.raises(serve.AdmissionError, match="backpressure"):
        server.submit("lenet", frames28[2], block=True, timeout=0.05)
    assert clk.now() - t0 >= 0.05        # waited the timeout out (virtually)
    server.start()                       # backlog drains once started
    assert f1.result(timeout=120).shape == (1, 10)
    assert f2.result(timeout=120).shape == (1, 10)
    assert server.stats()["programs"]["lenet"]["requests"]["rejected"] == 2
    server.stop()


def test_backpressure_unblocks_when_queue_drains(lenet_exe, frames28):
    """A blocking submit into a full queue must complete once the
    scheduler makes room — the producer-throttling path."""
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=1, max_queue=1,
                                            max_wait_ms=0.0))
    server.register("lenet", prog, REFERENCE)
    server.start()
    futs = []

    def producer():
        for i in range(6):
            futs.append(server.submit("lenet", frames28[i], block=True))

    t = threading.Thread(target=producer)
    t.start()
    t.join(timeout=120)
    assert not t.is_alive()
    assert all(f.result(timeout=120).shape == (1, 10) for f in futs)
    server.stop()


def test_deadline_shedding(lenet_exe, frames28):
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.0))
    server.register("lenet", prog, REFERENCE)
    server.start()
    expired = server.submit("lenet", frames28[0], deadline_ms=0.0)
    ok = server.submit("lenet", frames28[1], deadline_ms=60_000.0)
    with pytest.raises(serve.DeadlineExceeded, match="deadline missed"):
        expired.result(timeout=120)
    assert ok.result(timeout=120).shape == (1, 10)
    p = server.stats()["programs"]["lenet"]
    assert p["requests"]["shed_deadline"] == 1
    assert p["requests"]["served"] == 1
    server.stop()


def test_deadline_shed_virtual_clock(lenet_exe, frames28):
    """Deterministic deadline expiry: queue a request with a 50ms budget,
    advance *virtual* time past it before the scheduler ever runs — it
    must shed without any real sleeping or timing races."""
    prog, _ = lenet_exe
    clk = serve.VirtualClock()
    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.0),
                          clock=clk)
    server.register("lenet", prog, REFERENCE)
    expired = server.submit("lenet", frames28[0], deadline_ms=50.0)
    clk.advance(0.051)                   # past due before the server starts
    server.start()
    ok = server.submit("lenet", frames28[1], deadline_ms=60_000.0)
    with pytest.raises(serve.DeadlineExceeded, match="deadline missed"):
        expired.result(timeout=120)
    assert ok.result(timeout=120).shape == (1, 10)
    assert server.stats()["programs"]["lenet"]["requests"]["shed_deadline"] == 1
    server.stop()


def test_stop_drain_serves_backlog_and_rejects_after(lenet_exe, frames28):
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=5.0))
    server.register("lenet", prog, REFERENCE)
    server.start()
    futs = [server.submit("lenet", frames28[i]) for i in range(6)]
    server.stop(drain=True)
    assert all(f.result(timeout=1).shape == (1, 10) for f in futs)
    with pytest.raises(serve.ServerClosed):
        server.submit("lenet", frames28[0])


def test_stop_no_drain_fails_pending(lenet_exe, frames28):
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=4))
    server.register("lenet", prog, REFERENCE)
    # never started: queued requests must fail, not hang
    fut = server.submit("lenet", frames28[0])
    server._started = True               # allow stop() to run the teardown
    server._scheduler = server._completer = None
    server.stop(drain=False)
    with pytest.raises(serve.ServerClosed):
        fut.result(timeout=1)


def test_stop_no_drain_resets_queue_accounting(lenet_exe, frames28):
    """Failing the queue on stop(drain=False) must give the admitted
    frames back: queue_depth and the per-program queued gauge drop to
    zero instead of reporting stale nonzero values after shutdown."""
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=4))
    server.register("lenet", prog, REFERENCE)
    # never started: nothing drains, stop(drain=False) fails the backlog
    futs = [server.submit("lenet", frames28[:2]) for _ in range(3)]
    assert server.stats()["queue_depth"] == 6
    server._started = True
    server._scheduler = server._completer = None
    server.stop(drain=False)
    for fut in futs:
        with pytest.raises(serve.ServerClosed):
            fut.result(timeout=1)
    st = server.stats()
    assert st["queue_depth"] == 0
    assert st["programs"]["lenet"]["queue_depth"] == 0
    assert st["programs"]["lenet"]["requests"]["failed"] == 3


def test_context_manager_and_oversize_request(lenet_exe, frames28):
    """Requests larger than every bucket run chunked — same results."""
    prog, exe = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.0))
    server.register("lenet", prog, REFERENCE)
    with server:
        fut = server.submit("lenet", frames28[:7])      # 7 > max_batch 4
        np.testing.assert_array_equal(fut.result(timeout=120),
                                      _singles(exe, frames28[:7]))


# -- load generator -----------------------------------------------------------

def test_poisson_load_accounting(lenet_exe, frames28):
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(max_batch=8, max_wait_ms=1.0))
    server.register("lenet", prog, REFERENCE)
    server.start()
    rep = serve.poisson_load(server, "lenet", frames28, rate_rps=400.0,
                             n_requests=32, seed=3)
    server.stop()
    assert rep.submitted + rep.rejected == 32
    assert rep.served + rep.shed == rep.submitted
    assert rep.served == rep.latency_ms["count"] > 0
    assert rep.achieved_rps > 0 and rep.duration_s > 0
    with pytest.raises(ValueError, match="rate_rps"):
        serve.poisson_load(server, "lenet", frames28, rate_rps=0,
                           n_requests=1)


@pytest.mark.slow
def test_load_sweep_microbatching_speedup(lenet_exe, frames28):
    """The long sweep (slow-marked): micro-batching must beat the batch=1
    request-at-a-time path at saturating load. The checked-in
    BENCH_serving.json carries the full curve; this asserts a conservative
    floor so CI noise doesn't flake."""
    from benchmarks import bench_serving
    payload = bench_serving.run(csv=False, quick=True)
    ab = payload["ablation"]
    assert ab["microbatch_fps"] > 1.5 * ab["batch1_fps"], ab
    for point in payload["sweep"]:
        assert point["latency_ms"]["count"] > 0
        assert {"p50", "p95", "p99"} <= set(point["latency_ms"])
