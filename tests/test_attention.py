"""Attention tests: flash-blockwise vs naive oracle, RoPE, cache decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A


def _qkv(seed, b, t, h, kv, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, kv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("t,kv_block", [(64, 16), (96, 32), (128, 128),
                                        (100, 32)])
@pytest.mark.parametrize("h,kv", [(8, 8), (8, 2), (15, 5)])
def test_flash_matches_naive_causal(t, kv_block, h, kv):
    q, k, v = _qkv(t + h, 2, t, h, kv, 32)
    got = A.attention(q, k, v, causal=True, kv_block=kv_block)
    want = A.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 32, 1000])
def test_sliding_window(window):
    q, k, v = _qkv(0, 1, 64, 4, 2, 16)
    got = A.attention(q, k, v, causal=True, window=window, kv_block=16)
    want = A.attention_naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_non_causal_encoder():
    q, k, v = _qkv(1, 2, 48, 4, 4, 16)
    got = A.attention(q, k, v, causal=False, kv_block=16)
    want = A.attention_naive(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_rope_relative_property():
    """RoPE scores depend only on relative distance."""
    d = 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(k1, (1, 1, 1, d))
    k = jax.random.normal(k2, (1, 1, 1, d))
    def score(qpos, kpos):
        qr = A.apply_rope(q, jnp.asarray([qpos]))
        kr = A.apply_rope(k, jnp.asarray([kpos]))
        return float(jnp.sum(qr * kr))
    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_decode_matches_full_attention():
    b, t, h, kv, d = 2, 33, 8, 4, 16
    q, k, v = _qkv(3, b, t, h, kv, d)
    cache = A.KVCache.init(b, t, kv, d, dtype=jnp.float32)
    outs = []
    for i in range(t):
        cache = A.cache_update(cache, k[:, i:i + 1], v[:, i:i + 1])
        outs.append(A.decode_attention(q[:, i:i + 1], cache))
    got = jnp.concatenate(outs, axis=1)
    want = A.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ring_buffer_decode_matches_windowed():
    """Ring cache of window size == full cache with window mask."""
    b, t, h, kv, d, w = 1, 40, 4, 2, 16, 8
    q, k, v = _qkv(4, b, t, h, kv, d)
    ring = A.KVCache.init(b, w, kv, d, dtype=jnp.float32)
    full = A.KVCache.init(b, t, kv, d, dtype=jnp.float32)
    for i in range(t):
        ring = A.cache_update(ring, k[:, i:i + 1], v[:, i:i + 1], ring=True)
        full = A.cache_update(full, k[:, i:i + 1], v[:, i:i + 1])
    got = A.decode_attention(q[:, -1:], ring)
    want = A.attention_naive(q[:, -1:], k, v, causal=True, window=w,
                             q_offset=t - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16_stability():
    q, k, v = _qkv(5, 1, 64, 4, 2, 32, jnp.bfloat16)
    got = A.attention(q, k, v, kv_block=16)
    want = A.attention_naive(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)
