"""Property-style randomized invariant tests.

(hypothesis isn't installed in this container, so properties are checked
over seeded random sweeps — same invariants, explicit generators.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optical_core as oc
from repro.core.compressive import compressive_acquire
from repro.core.quant import WASpec, fake_quant_act, fake_quant_weight, quantize_weight
from repro.kernels.photonic_mvm.ops import photonic_mvm
from repro.kernels.photonic_mvm.ref import photonic_mvm_ref

RNG = np.random.default_rng(0)


def _rand_shape(rng, lo=1, hi=200, dims=2):
    return tuple(int(rng.integers(lo, hi)) for _ in range(dims))


@pytest.mark.parametrize("trial", range(10))
def test_property_weight_quant_idempotent(trial):
    """quant(dequant(quant(w))) == quant(w)."""
    rng = np.random.default_rng(trial)
    shape = _rand_shape(rng, 2, 64)
    bits = int(rng.choice([2, 3, 4]))
    w = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    spec = WASpec(bits, 4)
    q1, s1 = quantize_weight(w, spec)
    q2, s2 = quantize_weight(q1.astype(jnp.float32) * s1, spec)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@pytest.mark.parametrize("trial", range(10))
def test_property_act_quant_monotone(trial):
    """CRC quantization preserves ordering (monotone non-decreasing)."""
    rng = np.random.default_rng(100 + trial)
    x = jnp.asarray(np.sort(rng.uniform(0, 2, 64)), jnp.float32)
    y = fake_quant_act(x, scale=0.1)
    assert bool(jnp.all(jnp.diff(y) >= -1e-7))


@pytest.mark.parametrize("trial", range(8))
def test_property_kernel_equals_oracle_random_shapes(trial):
    rng = np.random.default_rng(200 + trial)
    m, k, n = (int(rng.integers(1, 100)), int(rng.integers(1, 300)),
               int(rng.integers(1, 100)))
    bits = int(rng.choice([2, 3, 4]))
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.1, jnp.float32)
    spec = WASpec(bits, 4)
    got = photonic_mvm(x, w, spec)
    want = photonic_mvm_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("trial", range(6))
def test_property_ca_linearity(trial):
    """CA is linear: CA(a*x + b*y) == a*CA(x) + b*CA(y)."""
    rng = np.random.default_rng(300 + trial)
    x = jnp.asarray(rng.uniform(0, 1, (1, 8, 8, 3)), jnp.float32)
    y = jnp.asarray(rng.uniform(0, 1, (1, 8, 8, 3)), jnp.float32)
    a, b = float(rng.uniform(0.1, 2)), float(rng.uniform(0.1, 2))
    lhs = compressive_acquire(a * x + b * y, 2)
    rhs = a * compressive_acquire(x, 2) + b * compressive_acquire(y, 2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("trial", range(10))
def test_property_scheduler_macs_conserved(trial):
    """Scheduled MACs == mathematical MACs for random conv shapes."""
    rng = np.random.default_rng(400 + trial)
    h = w = int(rng.integers(2, 64))
    cin = int(rng.integers(1, 128))
    cout = int(rng.integers(1, 256))
    k = int(rng.choice([1, 3, 5, 7]))
    s = oc.schedule_conv("t", h, w, cin, cout, k)
    assert s.macs == h * w * cout * k * k * cin
    assert s.utilization <= 1.0 + 1e-9
    # at least one cycle per weight-remap round
    assert s.cycles >= s.weight_remaps


@pytest.mark.parametrize("trial", range(6))
def test_property_ste_gradient_bounded(trial):
    """STE gradient magnitude stays within clip region (no explosion)."""
    rng = np.random.default_rng(500 + trial)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    g = jax.grad(lambda w: jnp.sum(fake_quant_weight(w, WASpec(4, 4))))(w)
    assert float(jnp.max(jnp.abs(g))) < 10.0
