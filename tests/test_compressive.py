"""Compressive Acquisitor tests — paper eq. (1) semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressive as ca


def test_rgb_coefficients():
    c = ca.ca_coefficients(pool=2, channels=3)
    assert c.shape == (2, 2, 3)
    # each pixel contributes 0.25 * (0.299, 0.587, 0.114)
    np.testing.assert_allclose(np.asarray(c[0, 0]),
                               np.asarray([0.299, 0.587, 0.114]) / 4,
                               rtol=1e-6)
    # total weight = sum of grayscale coefficients
    assert float(c.sum()) == pytest.approx(sum(ca.RGB_COEFFS), rel=1e-6)


def test_compressive_acquire_matches_manual():
    img = jax.random.uniform(jax.random.PRNGKey(0), (2, 8, 8, 3))
    out = ca.compressive_acquire(img, pool=2)
    assert out.shape == (2, 4, 4)
    gray = (0.299 * img[..., 0] + 0.587 * img[..., 1] + 0.114 * img[..., 2])
    pooled = gray.reshape(2, 4, 2, 4, 2).mean(axis=(2, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(pooled), rtol=1e-5)


def test_compressive_acquire_single_cycle_equivalence():
    """Fused = gray-then-pool = pool-then-gray (linearity, the paper's point)."""
    img = jax.random.uniform(jax.random.PRNGKey(1), (1, 16, 16, 3))
    fused = ca.compressive_acquire(img, pool=4)
    per_chan = img.reshape(1, 4, 4, 4, 4, 3).mean(axis=(2, 4))
    gray_after = (0.299 * per_chan[..., 0] + 0.587 * per_chan[..., 1]
                  + 0.114 * per_chan[..., 2])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(gray_after),
                               rtol=1e-5)


def test_pool_only_mode():
    img = jax.random.uniform(jax.random.PRNGKey(2), (2, 8, 8, 4))
    out = ca.compressive_acquire(img, pool=2, rgb_to_gray=False)
    assert out.shape == (2, 4, 4, 4)


def test_strided_conv_acquire():
    img = jax.random.uniform(jax.random.PRNGKey(3), (1, 10, 10, 3))
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 3))
    out = ca.strided_conv_acquire(img, w, stride=2)
    assert out.shape == (1, 4, 4)
    # check one output position manually
    manual = float(jnp.sum(img[0, 2:5, 4:7, :] * w))
    assert float(out[0, 1, 2]) == pytest.approx(manual, rel=1e-5)


@pytest.mark.parametrize("seed,hw,k,c,stride", [
    (0, 10, 3, 3, 1), (1, 12, 3, 1, 2), (2, 16, 5, 3, 3),
    (3, 9, 2, 4, 2), (4, 17, 7, 2, 4),
])
def test_strided_conv_acquire_matches_lax(seed, hw, k, c, stride):
    """Property: the CA's configurable strided acquisition == a VALID
    strided conv (``lax.conv_general_dilated``) collapsing all channels."""
    img = jax.random.uniform(jax.random.PRNGKey(seed), (2, hw, hw, c))
    w = jax.random.normal(jax.random.PRNGKey(seed + 100), (k, k, c))
    out = ca.strided_conv_acquire(img, w, stride=stride)
    ref = jax.lax.conv_general_dilated(
        img, w[..., None], (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[..., 0]
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=1e-5)


def test_upsample_reconstruct_shapes_and_modes():
    img = jax.random.uniform(jax.random.PRNGKey(5), (2, 4, 4, 3))
    up = ca.upsample_reconstruct(img, 2, "bilinear")
    assert up.shape == (2, 8, 8, 3)
    near = ca.upsample_reconstruct(img, 3, "nearest")
    assert near.shape == (2, 12, 12, 3)
    # nearest is a pure copy
    np.testing.assert_allclose(np.asarray(near[:, ::3, ::3]),
                               np.asarray(img), rtol=1e-6)
    # bilinear preserves constants exactly
    const = jnp.full((1, 4, 4, 1), 0.7)
    np.testing.assert_allclose(
        np.asarray(ca.upsample_reconstruct(const, 2, "bilinear")), 0.7,
        rtol=1e-6)
    with pytest.raises(ValueError, match="method"):
        ca.upsample_reconstruct(img, 2, "bicubic")


def test_sequence_ca():
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, 8))
    out = ca.sequence_ca(x, 3)
    assert out.shape == (2, 4, 8)
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(x[:, :3].mean(axis=1)), rtol=1e-5)
    with pytest.raises(ValueError):
        ca.sequence_ca(x, 5)
