"""Compressive Acquisitor tests — paper eq. (1) semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressive as ca


def test_rgb_coefficients():
    c = ca.ca_coefficients(pool=2, channels=3)
    assert c.shape == (2, 2, 3)
    # each pixel contributes 0.25 * (0.299, 0.587, 0.114)
    np.testing.assert_allclose(np.asarray(c[0, 0]),
                               np.asarray([0.299, 0.587, 0.114]) / 4,
                               rtol=1e-6)
    # total weight = sum of grayscale coefficients
    assert float(c.sum()) == pytest.approx(sum(ca.RGB_COEFFS), rel=1e-6)


def test_compressive_acquire_matches_manual():
    img = jax.random.uniform(jax.random.PRNGKey(0), (2, 8, 8, 3))
    out = ca.compressive_acquire(img, pool=2)
    assert out.shape == (2, 4, 4)
    gray = (0.299 * img[..., 0] + 0.587 * img[..., 1] + 0.114 * img[..., 2])
    pooled = gray.reshape(2, 4, 2, 4, 2).mean(axis=(2, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(pooled), rtol=1e-5)


def test_compressive_acquire_single_cycle_equivalence():
    """Fused = gray-then-pool = pool-then-gray (linearity, the paper's point)."""
    img = jax.random.uniform(jax.random.PRNGKey(1), (1, 16, 16, 3))
    fused = ca.compressive_acquire(img, pool=4)
    per_chan = img.reshape(1, 4, 4, 4, 4, 3).mean(axis=(2, 4))
    gray_after = (0.299 * per_chan[..., 0] + 0.587 * per_chan[..., 1]
                  + 0.114 * per_chan[..., 2])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(gray_after),
                               rtol=1e-5)


def test_pool_only_mode():
    img = jax.random.uniform(jax.random.PRNGKey(2), (2, 8, 8, 4))
    out = ca.compressive_acquire(img, pool=2, rgb_to_gray=False)
    assert out.shape == (2, 4, 4, 4)


def test_strided_conv_acquire():
    img = jax.random.uniform(jax.random.PRNGKey(3), (1, 10, 10, 3))
    w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 3))
    out = ca.strided_conv_acquire(img, w, stride=2)
    assert out.shape == (1, 4, 4)
    # check one output position manually
    manual = float(jnp.sum(img[0, 2:5, 4:7, :] * w))
    assert float(out[0, 1, 2]) == pytest.approx(manual, rel=1e-5)


def test_sequence_ca():
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, 8))
    out = ca.sequence_ca(x, 3)
    assert out.shape == (2, 4, 8)
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(x[:, :3].mean(axis=1)), rtol=1e-5)
    with pytest.raises(ValueError):
        ca.sequence_ca(x, 5)
