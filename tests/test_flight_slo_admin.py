"""The production observability plane: flight recorder, SLOs, ops endpoint.

What is pinned here (the PR-10 acceptance criteria):

* the flight recorder records **with tracing off**, never allocates a
  slot on the hot path semantics it claims (overwrite-oldest, per-ring
  contiguous seqs), and its dumps pass ``check_trace.py --flight``;
* an induced SLO breach (deadline-shed spike under ``VirtualClock``)
  and an injected ``WorkerError`` each auto-produce a flight dump that
  contains spans from *before* the trigger;
* ``/healthz`` flips unhealthy when the pool loses a worker; the whole
  ops surface (``/metrics`` ``/readyz`` ``/statusz`` ``/tracez``)
  round-trips; an empty latency window stays ``{"count": 0}`` all the
  way through ``/statusz``;
* automatic dumps are rate-limited and the suppression is counted.
"""

import importlib.util
import json
import threading
import urllib.error
import urllib.request
from pathlib import Path

import numpy as np
import pytest

import repro
from repro import obs, serve

ROOT = Path(__file__).resolve().parent.parent
REFERENCE = repro.Options(backend="reference")


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "scripts" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture()
def flight():
    """A fresh flight recorder for the test; the previous one restored."""
    prev = obs.get_flight()
    recorder = obs.install(obs.FlightRecorder(capacity=512, name="test"))
    try:
        yield recorder
    finally:
        if prev is not None:
            obs.install(prev)
        else:
            obs.uninstall()


@pytest.fixture(scope="module")
def edge_program():
    return repro.Program.from_pipeline("edge_detect", 16, 16, 3)


@pytest.fixture()
def frame():
    return np.random.default_rng(0).random((16, 16, 3), np.float32)


def _get(url, expect=200):
    try:
        r = urllib.request.urlopen(url, timeout=30)
        code, body = r.status, r.read()
    except urllib.error.HTTPError as e:
        code, body = e.code, e.read()
    assert code == expect, f"{url}: {code} != {expect}: {body[:200]}"
    return body


# ---------------------------------------------------------------------------
# Flight recorder core
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_records_with_tracing_off(self, flight):
        assert obs.get_trace() is None           # no collector installed
        with obs.use_mode("off"):                # and the mode pinned off
            with obs.span("t.black_box", attrs={"k": 1}):
                obs.event("t.instant")
        assert obs.get_trace() is None           # nothing leaked a Trace
        d = flight.dump(reason="unit")
        names = {e["name"] for e in d["traceEvents"] if e["ph"] != "M"}
        assert {"t.black_box", "t.instant"} <= names
        span = next(e for e in d["traceEvents"]
                    if e["name"] == "t.black_box")
        assert span["ph"] == "X" and span["args"]["k"] == 1
        assert d["otherData"]["reason"] == "unit"

    def test_trace_and_flight_both_record_when_enabled(self, flight):
        trace = obs.enable()
        try:
            with obs.span("t.both"):
                pass
        finally:
            obs.disable()
        assert len(trace.spans("t.both")) == 1
        assert any(e["name"] == "t.both" for e in
                   flight.dump()["traceEvents"])

    def test_overwrite_oldest_keeps_contiguous_tail(self, flight):
        small = obs.install(obs.FlightRecorder(capacity=8))
        try:
            for i in range(20):
                obs.event("t.tick", attrs={"i": i})
            d = small.dump()
        finally:
            obs.install(flight)
        recs = [e for e in d["traceEvents"] if e["ph"] == "i"]
        assert len(recs) == 8                    # capacity, not 20
        assert [e["args"]["i"] for e in recs] == list(range(12, 20))
        assert [e["args"]["seq"] for e in recs] == list(range(12, 20))
        assert d["otherData"]["dropped_total"] == 12

    def test_per_thread_rings_and_lane_meta(self, flight):
        def worker():
            obs.event("t.from_thread")

        t = threading.Thread(target=worker, name="test-lane")
        t.start()
        t.join()
        obs.event("t.from_main")
        d = flight.dump()
        lanes = {e["args"]["name"] for e in d["traceEvents"]
                 if e["ph"] == "M"}
        assert any("test-lane" in ln for ln in lanes)
        rings = {e["args"]["ring"] for e in d["traceEvents"]
                 if e["ph"] != "M"}
        assert len(rings) == 2
        assert d["otherData"]["rings"] == 2

    def test_span_at_lands_on_synthetic_lane(self, flight):
        obs.span_at("t.retro", 1.0, 2.0, trace_id="req-7",
                    lane_tid=12345, lane="req-7-lane")
        d = flight.dump()
        retro = next(e for e in d["traceEvents"] if e["name"] == "t.retro")
        assert retro["tid"] == 12345
        assert retro["args"]["trace_id"] == "req-7"
        assert any(e["ph"] == "M" and e["args"]["name"] == "req-7-lane"
                   for e in d["traceEvents"])

    def test_dump_passes_flight_validator(self, flight, tmp_path):
        with obs.span("t.outer"):
            with obs.span("t.inner"):
                obs.event("t.mark")
        path = tmp_path / "flight.json"
        path.write_text(json.dumps(flight.dump(reason="unit")))
        check_trace = _load_script("check_trace")
        assert check_trace.flight_check(str(path)) == []
        # and via the CLI entry point
        assert check_trace.main([str(path), "--flight"]) == 0

    def test_validator_rejects_gapped_history(self, flight, tmp_path):
        obs.event("t.a")
        obs.event("t.b")
        obs.event("t.c")
        d = flight.dump()
        recs = [e for e in d["traceEvents"] if e["ph"] != "M"]
        del d["traceEvents"][d["traceEvents"].index(recs[1])]  # punch a hole
        path = tmp_path / "gapped.json"
        path.write_text(json.dumps(d))
        check_trace = _load_script("check_trace")
        errors = check_trace.flight_check(str(path))
        assert any("gap inside retained history" in e for e in errors)

    def test_capacity_validation_and_env_gate(self, monkeypatch):
        with pytest.raises(ValueError, match="capacity"):
            obs.FlightRecorder(capacity=0)
        from repro.obs import flight as flight_mod
        prev = obs.get_flight()
        try:
            monkeypatch.setenv("REPRO_FLIGHT", "off")
            assert flight_mod.install_default() is None
            monkeypatch.setenv("REPRO_FLIGHT", "")
            monkeypatch.setenv("REPRO_FLIGHT_SLOTS", "64")
            rec = flight_mod.install_default()
            assert rec is not None and rec.capacity == 64
        finally:
            if prev is not None:
                obs.install(prev)
            else:
                obs.uninstall()


# ---------------------------------------------------------------------------
# SLO engine (pure, clock-injected)
# ---------------------------------------------------------------------------

class TestSLO:
    def test_validation(self):
        with pytest.raises(ValueError, match="at least one objective"):
            obs.SLO()
        with pytest.raises(ValueError, match="p99_ms"):
            obs.SLO(p99_ms=-1.0)
        with pytest.raises(ValueError, match="max_shed_rate"):
            obs.SLO(max_shed_rate=1.5)
        with pytest.raises(ValueError, match="window_s"):
            obs.SLO(p99_ms=10.0, window_s=0.0)
        assert obs.SLO(p99_ms=50.0).eval_spacing_s == 60.0 / 8

    def test_p99_breach_reports_value_and_limit(self):
        mon = obs.SLOMonitor("p", obs.SLO(p99_ms=10.0, window_s=60.0,
                                          eval_every_s=0.0))
        for i in range(99):
            assert mon.observe("served", float(i) * 1e-3, latency_ms=1.0) == []
        breaches = mon.observe("served", 0.1, latency_ms=1000.0)
        assert len(breaches) == 1
        b = breaches[0]
        assert b["objective"] == "p99_ms" and b["limit"] == 10.0
        assert b["value"] > 10.0 and b["n"] == 100

    def test_shed_and_error_rates(self):
        mon = obs.SLOMonitor("p", obs.SLO(max_shed_rate=0.5,
                                          max_error_rate=0.5,
                                          eval_every_s=0.0))
        assert mon.observe("served", 0.0, latency_ms=1.0) == []
        assert mon.observe("shed", 0.01) == []          # rate 0.5, not > 0.5
        breaches = mon.observe("shed", 0.02)            # shed 2/3
        assert [b["objective"] for b in breaches] == ["shed_rate"]
        assert mon.observe("failed", 0.03) == []        # shed 2/4, errors 1/4
        assert mon.observe("failed", 0.04) == []        # shed 2/5, errors 2/5
        assert mon.observe("failed", 0.05) == []        # shed 2/6, errors 3/6
        breaches = mon.observe("failed", 0.06)          # errors 4/7 > 0.5
        assert [b["objective"] for b in breaches] == ["error_rate"]

    def test_window_prunes_old_outcomes(self):
        mon = obs.SLOMonitor("p", obs.SLO(max_shed_rate=0.1, window_s=1.0,
                                          eval_every_s=0.0))
        assert len(mon.observe("shed", 0.0)) == 1       # 1/1 shed
        state = mon.state(t=10.0)                       # window slid past it
        assert state["n"] == 0
        assert state["objectives"]["shed_rate"]["value"] is None
        assert mon.observe("served", 10.0, latency_ms=1.0) == []

    def test_min_count_gates_evaluation(self):
        mon = obs.SLOMonitor("p", obs.SLO(max_shed_rate=0.0, min_count=3,
                                          eval_every_s=0.0))
        assert mon.observe("shed", 0.0) == []
        assert mon.observe("shed", 0.1) == []
        assert len(mon.observe("shed", 0.2)) == 1

    def test_eval_throttle(self):
        mon = obs.SLOMonitor("p", obs.SLO(max_shed_rate=0.0, window_s=100.0,
                                          eval_every_s=5.0))
        assert len(mon.observe("shed", 0.0)) == 1       # first always evals
        assert mon.observe("shed", 1.0) == []           # throttled
        assert len(mon.observe("shed", 6.0)) == 1       # spacing elapsed
        assert mon.state()["breaches"]["shed_rate"] == 2

    def test_unknown_kind_rejected(self):
        mon = obs.SLOMonitor("p", obs.SLO(p99_ms=1.0))
        with pytest.raises(ValueError, match="unknown outcome"):
            mon.observe("lost", 0.0)


# ---------------------------------------------------------------------------
# Incident capture through the Server (the acceptance criteria)
# ---------------------------------------------------------------------------

class TestServerIncidents:
    def test_slo_breach_on_shed_spike_dumps_flight(self, flight, edge_program,
                                                   frame, tmp_path):
        """VirtualClock shed spike -> breach -> counter + auto dump whose
        timeline passes ``check_trace.py --flight`` with pre-trigger
        spans present."""
        breach_counter = obs.counter("slo.breach.edge")
        n0 = breach_counter.get()
        clk = serve.VirtualClock()
        server = serve.Server(serve.ServeConfig(
            max_batch=4, max_wait_ms=100.0, speculative_close=False,
            flight_dump_dir=str(tmp_path)), clock=clk)
        server.register("edge", edge_program, REFERENCE,
                        slo=obs.SLO(max_shed_rate=0.3, window_s=1000.0,
                                    eval_every_s=0.0))
        server.start()
        try:
            # one healthy request first: its timeline spans are the
            # pre-breach history the dump must retain
            ok = server.submit("edge", frame)
            assert ok.result(timeout=120).shape == (1, 16, 16, 1)
            # the shed spike: the scheduler's 100ms hold-open wait jumps
            # virtual time past the 50ms deadline deterministically
            doomed = server.submit("edge", frame, deadline_ms=50.0)
            with pytest.raises(serve.DeadlineExceeded):
                doomed.result(timeout=120)
        finally:
            server.stop()
        assert breach_counter.get() == n0 + 1
        stats = server.stats()
        assert stats["flight"]["dumps"] >= 1
        assert stats["flight"]["last_reason"].startswith("slo:edge:shed_rate")
        slo_state = stats["programs"]["edge"]["slo"]
        assert slo_state["breaches"]["shed_rate"] == 1
        # the dump file passes the flight validator, trigger required
        dumps = server.flight_dumps()
        assert dumps and dumps[0]["path"] is not None
        check_trace = _load_script("check_trace")
        assert check_trace.flight_check(dumps[0]["path"],
                                        require_trigger=True) == []
        # ...and really contains the pre-breach request timeline
        events = json.loads(Path(dumps[0]["path"]).read_text())["traceEvents"]
        assert any(e["name"] == "serve.request.device" for e in events)
        # the breach was logged, correlated fields intact
        logged = [r for r in server.log.recent()
                  if r["event"] == "serve.slo.breach"]
        assert logged and logged[0]["objective"] == "shed_rate"

    def test_worker_error_dumps_flight_with_history(self, flight,
                                                    edge_program, frame,
                                                    tmp_path):
        """An injected WorkerError auto-produces a triggered dump that
        retains spans from before the failure."""
        calls = []

        def execute(program, device, frames, bucket, default):
            calls.append(bucket)
            if len(calls) >= 2:
                raise ValueError("injected device fault")
            return default()

        server = serve.Server(serve.ServeConfig(
            max_batch=2, max_wait_ms=0.0, flight_dump_dir=str(tmp_path)),
            hooks=serve.Hooks(execute=execute))
        server.register("edge", edge_program, REFERENCE)
        server.start()
        try:
            ok = server.submit("edge", frame)
            assert ok.result(timeout=120).shape == (1, 16, 16, 1)
            failed = server.submit("edge", frame)
            with pytest.raises(serve.WorkerError, match="injected"):
                failed.result(timeout=120)
        finally:
            server.stop()
        stats = server.stats()
        assert stats["flight"]["last_reason"] == "worker_error:edge"
        assert stats["programs"]["edge"]["requests"]["failed"] == 1
        dumps = server.flight_dumps()
        assert len(dumps) == 1
        check_trace = _load_script("check_trace")
        assert check_trace.flight_check(dumps[0]["path"],
                                        require_trigger=True) == []
        # pre-trigger history: the first (successful) request's spans
        events = json.loads(Path(dumps[0]["path"]).read_text())["traceEvents"]
        trigger_ts = min(e["ts"] for e in events
                         if e.get("name") == "flight.trigger")
        pre = [e for e in events if e["ph"] == "X"
               and e["ts"] + e.get("dur", 0.0) <= trigger_ts
               and e["name"].startswith("serve.request.")]
        assert pre, "no serving spans from before the worker failure"
        assert any(r["event"] == "serve.worker.failure"
                   for r in server.log.recent())

    def test_dump_rate_limit_suppresses_and_counts(self, flight,
                                                   edge_program):
        clk = serve.VirtualClock()
        server = serve.Server(serve.ServeConfig(
            flight_dump_interval_s=30.0), clock=clk)
        server.register("edge", edge_program, REFERENCE)
        assert server._flight_dump("first") is not None
        assert server._flight_dump("too_soon") is None
        clk.advance(31.0)
        assert server._flight_dump("after_interval") is not None
        st_flight = server.stats()["flight"]
        assert st_flight["dumps"] == 2
        assert st_flight["suppressed"] == 1
        assert [d["reason"] for d in server.flight_dumps()] == \
            ["first", "after_interval"]

    def test_stop_timeout_stranding_triggers_dump(self, flight, edge_program,
                                                  frame):
        gate = threading.Event()
        entered = threading.Event()

        def execute(program, device, frames, bucket, default):
            entered.set()
            assert gate.wait(30)
            return default()

        server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.0),
                              hooks=serve.Hooks(execute=execute))
        server.register("edge", edge_program, REFERENCE)
        server.start()
        try:
            fut = server.submit("edge", frame)
            assert entered.wait(30)
            server.stop(drain=False, timeout=0.2)
            with pytest.raises(serve.ServerClosed):
                fut.result(timeout=30)
            assert server.stats()["flight"]["last_reason"] == "stop_timeout"
            assert len(server.flight_dumps()) == 1
        finally:
            gate.set()

    def test_healthz_flips_when_pool_loses_worker(self, flight, edge_program,
                                                  frame):
        """A worker killed outside the Exception fault model (BaseException
        from the execute seam) must flip health() — and /healthz — to
        unhealthy while the process keeps running."""

        class KillWorker(BaseException):
            pass

        armed = threading.Event()

        def execute(program, device, frames, bucket, default):
            if armed.is_set():
                raise KillWorker()
            return default()

        server = serve.Server(serve.ServeConfig(
            max_batch=2, max_wait_ms=0.0, admin_port=0),
            hooks=serve.Hooks(execute=execute))
        server.register("edge", edge_program, REFERENCE)
        prev_hook = threading.excepthook
        threading.excepthook = lambda a: None     # silence the worker death
        try:
            server.start()
            url = server.admin.url
            assert server.health()["healthy"]
            _get(url + "/healthz", expect=200)
            armed.set()
            server.submit("edge", frame)          # kills the only worker
            deadline = 30.0
            import time
            t0 = time.monotonic()
            while server._pool.healthy():
                assert time.monotonic() - t0 < deadline
                time.sleep(0.01)
            h = server.health()
            assert not h["healthy"]
            assert h["checks"]["pool_workers"] == 0
            body = json.loads(_get(url + "/healthz", expect=503))
            assert body["healthy"] is False
            _get(url + "/readyz", expect=503)
        finally:
            threading.excepthook = prev_hook
            server.stop(drain=False, timeout=1.0)


# ---------------------------------------------------------------------------
# Ops endpoint
# ---------------------------------------------------------------------------

@pytest.fixture()
def admin_server(flight, edge_program, tmp_path):
    server = serve.Server(serve.ServeConfig(
        max_batch=4, admin_port=0,
        log_path=str(tmp_path / "serve.jsonl")))
    server.register("edge", edge_program, REFERENCE,
                    slo=obs.SLO(p99_ms=60_000.0))
    # a second hosted program that never sees traffic: its latency
    # summary must stay {"count": 0} end-to-end through /statusz
    server.register("idle", repro.Program.from_pipeline("sharpen", 16, 16, 3),
                    REFERENCE)
    server.start()
    try:
        yield server
    finally:
        server.stop()


class TestAdminEndpoint:
    def test_all_routes(self, admin_server, frame):
        url = admin_server.admin.url
        out = admin_server.submit("edge", frame).result(timeout=120)
        assert out.shape == (1, 16, 16, 1)

        health = json.loads(_get(url + "/healthz"))
        assert health["healthy"] and health["checks"]["pool_workers"] == 1
        ready = json.loads(_get(url + "/readyz"))
        assert ready["ready"] and ready["checks"]["warmed"]

        metrics = _get(url + "/metrics").decode()
        assert "# HELP serve_edge_served repro metric 'serve.edge.served'" \
            in metrics
        assert "# TYPE serve_edge_served counter" in metrics
        assert "serve_edge_served 1" in metrics
        assert "serve_pool_device0_batches" in metrics

        status = json.loads(_get(url + "/statusz"))
        assert status["programs"]["edge"]["requests"]["served"] == 1
        assert status["programs"]["edge"]["slo"]["objectives"]["p99_ms"][
            "limit"] == 60_000.0
        assert "fused_segments" in status["programs"]["edge"]
        assert "plan_cache" in status
        # the never-trafficked program keeps the empty-window shape
        assert status["programs"]["idle"]["latency_ms"] == {"count": 0}
        assert status["programs"]["idle"]["requests"]["served"] == 0
        assert any(r["event"] == "serve.start"
                   for r in status["log_tail"])

        text = _get(url + "/statusz?format=text").decode()
        assert "edge" in text

        dump = json.loads(_get(url + "/tracez"))
        assert dump["otherData"]["reason"] == "tracez"
        assert any(e.get("name") == "serve.request.device"
                   for e in dump["traceEvents"])

        _get(url + "/nonsense", expect=404)

    def test_tracez_503_without_recorder(self, admin_server):
        url = admin_server.admin.url
        prev = obs.uninstall()
        try:
            body = json.loads(_get(url + "/tracez", expect=503))
            assert "no flight recorder" in body["error"]
        finally:
            obs.install(prev)

    def test_structured_log_file_written(self, admin_server, frame,
                                         tmp_path):
        admin_server.submit("edge", frame).result(timeout=120)
        lines = (tmp_path / "serve.jsonl").read_text().splitlines()
        recs = [json.loads(ln) for ln in lines]
        assert any(r["event"] == "serve.start" for r in recs)
        assert all({"ts", "mono_s", "level", "event"} <= set(r)
                   for r in recs)

    def test_admin_port_conflict_raises(self, admin_server, edge_program):
        taken = admin_server.admin.port
        clash = serve.Server(serve.ServeConfig(admin_port=taken))
        clash.register("edge", edge_program, REFERENCE)
        with pytest.raises(OSError):
            clash.start()
