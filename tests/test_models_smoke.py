"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs, and a decode step
where the family supports it."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, smoke_variant
from repro.data.synthetic import modality_batch
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

ARCHS = list_configs()


def _batch(cfg, b=2, t=32, seed=0):
    return {k: jnp.asarray(v) for k, v in
            modality_batch(cfg, b, t, seed).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_variant(arch)
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = lm_mod.lm_forward(params, batch, cfg)
    t_expected = 32 if cfg.frontend != "vision" else cfg.n_patches + (32 - cfg.n_patches)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    for v in aux.values():
        assert bool(jnp.isfinite(v))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = smoke_variant(arch)
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    batch = _batch(cfg)

    def loss_fn(p):
        return lm_mod.lm_loss(p, batch, cfg)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    new_params, new_opt, om = adamw_update(params, grads, opt, opt_cfg)
    # params actually moved
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(delta)) > 0
    assert bool(jnp.isfinite(om["grad_norm"]))
    # loss decreases after a few steps on the same batch (overfit sanity)
    p, o = new_params, new_opt
    for _ in range(3):
        (_, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p)
        p, o, _ = adamw_update(p, g, o, opt_cfg)
    loss2, _ = lm_mod.lm_loss(p, batch, cfg)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family != "encoder"])
def test_decode_step(arch):
    cfg = smoke_variant(arch)
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    cache = lm_mod.init_cache(cfg, 2, 48)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(3):
        logits, cache = lm_mod.decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-1.3b",
                                  "grok-1-314b"])
def test_photonic_quantized_train_step(arch):
    """The paper's technique as a first-class feature on LM archs."""
    cfg = dataclasses.replace(smoke_variant(arch), quant_scheme="w4a4")
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm_mod.lm_loss(p, batch, cfg), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0                        # STE keeps gradients alive


def test_prefill_decode_consistency():
    """Greedy continuation from decode equals argmax of teacher-forced
    forward logits (same positions, same cache math)."""
    cfg = smoke_variant("tinyllama-1.1b")
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    logits, _ = lm_mod.lm_forward(params, {"tokens": toks}, cfg)
    cache = lm_mod.init_cache(cfg, 1, 16)
    outs = []
    for i in range(8):
        lg, cache = lm_mod.decode_step(params, cache, toks[:, i:i + 1], cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(logits, np.float32),
        rtol=5e-2, atol=5e-2)


def test_full_configs_match_assigned_table():
    """The exact assigned dims (guards against accidental edits)."""
    spec = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("grok-1-314b").n_experts == 8
    assert get_config("grok-1-314b").top_k == 2
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("kimi-k2-1t-a32b").top_k == 8
