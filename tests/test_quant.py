"""Quantization unit + property tests (seeded randomized sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as Q


def test_crc_levels():
    assert Q.CRC_LEVELS == 16 and Q.CRC_COMPARATORS == 15
    x = jnp.linspace(0, 1.5, 100)
    codes = Q.crc_quantize_act(x, scale=0.1)
    assert codes.dtype == jnp.int8
    assert int(codes.min()) >= 0 and int(codes.max()) <= 15


def test_waspec_qmax():
    assert Q.W4A4.w_qmax == 7 and Q.W3A4.w_qmax == 3 and Q.W2A4.w_qmax == 1
    assert Q.W4A4.a_qmax == 15


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_weight_quant_roundtrip_bound(bits, seed):
    """|w - dequant(quant(w))| <= scale/2 (property over random tensors)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 32))
    spec = Q.WASpec(bits, 4)
    q, s = Q.quantize_weight(w, spec)
    deq = q.astype(jnp.float32) * s
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) <= spec.w_qmax
    assert float(jnp.max(jnp.abs(w - deq))) <= float(jnp.max(s)) * 0.5 + 1e-6


def test_fake_quant_weight_ste_gradient():
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    g = jax.grad(lambda w: jnp.sum(Q.fake_quant_weight(w, Q.W4A4)))(w)
    # STE: gradient flows (not identically zero, mostly ~1 per element via scale)
    assert float(jnp.mean(jnp.abs(g))) > 0.1


def test_fake_quant_act_unsigned_and_clipped():
    x = jnp.array([-1.0, 0.0, 0.5, 10.0])
    y = Q.fake_quant_act(x, scale=0.1)
    assert float(y[0]) == 0.0                      # negatives clip to 0
    assert float(y[-1]) == pytest.approx(1.5)      # 15 * 0.1
    assert float(y[2]) == pytest.approx(0.5)


def test_mixed_precision_resolution():
    specs = Q.resolve_layer_specs(4, Q.MX_43)
    assert specs[0].w_bits == 4
    assert all(s.w_bits == 3 for s in specs[1:])
    uni = Q.resolve_layer_specs(3, Q.W2A4)
    assert all(s.w_bits == 2 for s in uni)


@pytest.mark.parametrize("seed", range(5))
def test_qmatmul_reference_integer_exact(seed):
    """The reference MAC is integer math exactly (scales factor out)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (8, 24), minval=0.0, maxval=1.0)
    w = jax.random.normal(k2, (24, 12))
    y = Q.qmatmul_reference(x, w, Q.W4A4, act_scale=1.0 / 15)
    codes = jnp.round(jnp.clip(x / (1.0 / 15), 0, 15))
    wq, ws = Q.quantize_weight(w, Q.W4A4)
    manual = (codes @ wq.astype(jnp.float32)) * (1.0 / 15) * ws.reshape(1, -1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(manual), rtol=1e-6)


def test_mr_noise_perturbs_weights():
    spec = Q.WASpec(4, 4, mr_noise_std=0.5)
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    clean = Q.fake_quant_weight(w, Q.W4A4)
    noisy = Q.fake_quant_weight(w, spec, noise_key=jax.random.PRNGKey(1))
    assert float(jnp.max(jnp.abs(clean - noisy))) > 0.0
