"""photonic_mvm kernel vs pure-jnp oracle: shape/dtype/spec sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import W4A4, W3A4, W2A4
from repro.kernels.photonic_mvm.kernel import mvm_int_kernel
from repro.kernels.photonic_mvm.ops import photonic_mvm, photonic_mvm_prequant
from repro.kernels.photonic_mvm.ref import mvm_int_ref, photonic_mvm_ref

SPECS = [W4A4, W3A4, W2A4]
SHAPES = [(8, 64, 32), (128, 512, 128), (33, 130, 57), (1, 9, 1),
          (256, 960, 240)]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_float_api_matches_ref(spec, shape):
    m, k, n = shape
    key = jax.random.PRNGKey(m * 1000 + k)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n)) * 0.1
    got = photonic_mvm(x, w, spec)
    want = photonic_mvm_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (16, 96)).astype(dtype)
    w = (jax.random.normal(k2, (96, 48)) * 0.1).astype(dtype)
    got = photonic_mvm(x, w, W4A4)
    want = photonic_mvm_ref(x, w, W4A4)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_int_kernel_exact_vs_int_ref():
    """Integer path is bit-exact (the photonic MAC is integer math)."""
    rng = np.random.default_rng(0)
    a = rng.integers(-15, 16, (128, 512)).astype(np.int8)
    wq = rng.integers(-7, 8, (512, 128)).astype(np.int8)
    ws = rng.random(128).astype(np.float32)
    got = mvm_int_kernel(jnp.asarray(a), jnp.asarray(wq), jnp.asarray(ws),
                         act_scale=0.5)
    want = mvm_int_ref(jnp.asarray(a), jnp.asarray(wq), jnp.asarray(ws),
                       act_scale=0.5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_leading_dims():
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (2, 5, 40))
    w = jax.random.normal(k2, (40, 24)) * 0.2
    got = photonic_mvm(x, w, W4A4)
    want = photonic_mvm_ref(x, w, W4A4)
    assert got.shape == (2, 5, 24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_prequant_path():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 16, (20, 100)).astype(np.int8)
    wq = rng.integers(-7, 8, (100, 30)).astype(np.int8)
    ws = np.full(30, 0.01, np.float32)
    got = photonic_mvm_prequant(jnp.asarray(a), jnp.asarray(wq),
                                jnp.asarray(ws), act_scale=1 / 15)
    want = mvm_int_ref(jnp.asarray(a), jnp.asarray(wq), jnp.asarray(ws),
                       act_scale=1 / 15)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("blocks", [(64, 64, 128), (128, 128, 512),
                                    (256, 128, 256)])
def test_block_shape_sweep(blocks):
    """Different BlockSpec tilings must not change results."""
    bm, bn, bk = blocks
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (100, 300))
    w = jax.random.normal(k2, (300, 70)) * 0.1
    got = photonic_mvm(x, w, W4A4, bm=bm, bn=bn, bk=bk)
    want = photonic_mvm_ref(x, w, W4A4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
