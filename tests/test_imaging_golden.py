"""Golden-image regression: every imaging pipeline vs stored arrays.

The analytic identities in test_imaging.py prove the filters' math on
special inputs (constants, steps, impulses); these tests pin the *complete*
output on a textured batch, so any unintended numerics change anywhere in
the stack — filter weights, plan compile/execute, quantization, upsample —
shows up as a diff against ``tests/golden/<pipeline>.npz``.

Regenerate after an intentional numerics change:
``PYTHONPATH=src python scripts/gen_golden.py`` (see docs/imaging.md).
"""

from pathlib import Path

import numpy as np
import pytest

import repro
from repro.core.quant import W4A4
from repro.imaging import PIPELINES, apply_float

GOLDEN_DIR = Path(__file__).resolve().parent / "golden"


def test_every_pipeline_has_a_golden_file():
    missing = [n for n in PIPELINES
               if not (GOLDEN_DIR / f"{n}.npz").exists()]
    assert not missing, (f"no golden arrays for {missing}; run "
                         f"scripts/gen_golden.py")


@pytest.mark.parametrize("name", sorted(PIPELINES))
def test_pipeline_matches_golden(name):
    data = np.load(GOLDEN_DIR / f"{name}.npz")
    frames = data["frames"]            # goldens are self-contained
    prog = PIPELINES[name].program(int(data["hw"]), int(data["hw"]), 3)
    got_float = np.asarray(apply_float(prog.layers, prog.params, frames),
                           np.float32)
    np.testing.assert_allclose(got_float, data["float_out"],
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{name}: float path drifted from "
                                       f"golden")
    exe = prog.compile(repro.Options(scheme=W4A4, backend="reference"))
    got_quant = np.asarray(exe.run(frames), np.float32)
    np.testing.assert_allclose(got_quant, data["quant_out"],
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{name}: quantized device path "
                                       f"drifted from golden")
