"""End-to-end behaviour tests for the Lightator system.

The full stack in one place: sensor acquisition -> CA -> quantized OC
execution -> power report, and the QAT forward path over the paper's models.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core.accelerator import LightatorDevice
from repro.core.quant import W4A4, W3A4, W2A4, MX_43
from repro.models.vision import lenet_ir, vgg9_ir, init_vision, apply_vision

# The fast compile/execute coverage lives in test_plan_compile.py; this
# module keeps the full-stack sweeps and runs in the slow tier.
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def lenet():
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(0), layers)
    return layers, params


def test_lightator_device_end_to_end(lenet):
    layers, params = lenet
    img = jax.random.uniform(jax.random.PRNGKey(1), (2, 28, 28, 1))
    dev = LightatorDevice()
    logits, report = dev.run(layers, params, img, W4A4)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert report.exec_time_s > 0 and report.avg_power_w > 0
    assert report.kfps_per_w > 0


def test_device_power_decreases_with_weight_bits(lenet):
    layers, params = lenet
    img = jax.random.uniform(jax.random.PRNGKey(1), (1, 28, 28, 1))
    dev = LightatorDevice()
    powers = []
    for scheme in (W4A4, W3A4, W2A4):
        _, report = dev.run(layers, params, img, scheme)
        powers.append(report.avg_power_w)
    assert powers[0] > powers[1] > powers[2], powers


def test_mixed_precision_between_pure_configs(lenet):
    layers, params = lenet
    img = jax.random.uniform(jax.random.PRNGKey(1), (1, 28, 28, 1))
    dev = LightatorDevice()
    _, r44 = dev.run(layers, params, img, W4A4)
    _, r34 = dev.run(layers, params, img, W3A4)
    _, rmx = dev.run(layers, params, img, MX_43)
    assert r34.avg_power_w <= rmx.avg_power_w <= r44.avg_power_w * 1.05


def test_vgg9_with_and_without_ca():
    """CA compression shrinks layer-1 work (the paper's 42.2% claim axis)."""
    from repro.models.vision import vision_schedules
    s_ca = vision_schedules(vgg9_ir(use_ca=True), 32)
    s_no = vision_schedules(vgg9_ir(use_ca=False), 32)
    l1_ca = next(s for s in s_ca if s.name == "conv1")
    l1_no = next(s for s in s_no if s.name == "conv1")
    assert l1_ca.cycles < l1_no.cycles
    assert l1_ca.macs < l1_no.macs


def test_qat_forward_matches_shapes(lenet):
    layers, params = lenet
    img = jax.random.uniform(jax.random.PRNGKey(1), (4, 28, 28, 1))
    for scheme in (None, W4A4, MX_43):
        out = apply_vision(params, layers, img, scheme)
        assert out.shape == (4, 10)
        assert bool(jnp.all(jnp.isfinite(out)))
