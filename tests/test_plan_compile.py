"""Compile/execute split regression tests (core.plan + kernels.dispatch).

Contract under test: the compiled pipeline (static compile pass + single
jitted batched execute pass routed through the kernel dispatch layer) is
*bit-identical* — logits and power report — to the seed eager interpreter
``LightatorDevice.run_eager``, and compiles exactly once per
(model, scheme, shape).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.accelerator import LightatorDevice
from repro.core.quant import W4A4, W3A4, MX_43
from repro.kernels import dispatch
from repro.models.vision import lenet_ir, vgg9_ir, init_vision


@pytest.fixture(scope="module")
def lenet():
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(0), layers)
    img = jax.random.uniform(jax.random.PRNGKey(1), (2, 28, 28, 1))
    return layers, params, img


@pytest.mark.parametrize("scheme", [W4A4, W3A4], ids=["w4a4", "w3a4"])
def test_execute_bit_identical_to_eager(lenet, scheme):
    """Logits AND power report must match the seed eager path exactly."""
    layers, params, img = lenet
    dev = LightatorDevice()
    logits_e, report_e = dev.run_eager(layers, params, img, scheme)
    logits_c, report_c = dev.run(layers, params, img, scheme)
    np.testing.assert_array_equal(np.asarray(logits_e), np.asarray(logits_c))
    assert dataclasses.asdict(report_e) == dataclasses.asdict(report_c)


@pytest.mark.parametrize("make", [
    pytest.param(lambda: (lenet_ir(in_hw=32, use_ca=True), (2, 32, 32, 1)),
                 id="lenet_ca"),
    pytest.param(lambda: (vgg9_ir(in_hw=32, n_classes=10), (2, 32, 32, 3)),
                 id="vgg9_ca"),
])
def test_ca_models_bit_identical_to_eager(make):
    """The CAStep branch (fused gray/pool + requant) matches eager too."""
    layers, shape = make()
    params = init_vision(jax.random.PRNGKey(0), layers)
    img = jax.random.uniform(jax.random.PRNGKey(2), shape)
    dev = LightatorDevice()
    logits_e, report_e = dev.run_eager(layers, params, img, W4A4)
    logits_c, report_c = dev.run(layers, params, img, W4A4)
    np.testing.assert_array_equal(np.asarray(logits_e), np.asarray(logits_c))
    assert dataclasses.asdict(report_e) == dataclasses.asdict(report_c)


def test_mx_scheme_bit_identical(lenet):
    layers, params, img = lenet
    dev = LightatorDevice()
    logits_e, report_e = dev.run_eager(layers, params, img, MX_43)
    logits_c, report_c = dev.run(layers, params, img, MX_43)
    np.testing.assert_array_equal(np.asarray(logits_e), np.asarray(logits_c))
    assert dataclasses.asdict(report_e) == dataclasses.asdict(report_c)


def test_compile_is_cached_and_schedules_once(lenet, monkeypatch):
    """Repeated runs reuse the plan: no re-scheduling, same executor."""
    layers, params, img = lenet
    plan_mod.clear_plan_cache()
    calls = {"n": 0}
    import repro.core.optical_core as ocore
    orig = ocore.schedule_conv

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(ocore, "schedule_conv", counting)
    p1 = plan_mod.compile_model(tuple(layers), img.shape, W4A4)
    after_first = calls["n"]
    assert after_first > 0
    p2 = plan_mod.compile_model(tuple(layers), img.shape, W4A4)
    assert p2 is p1                       # same object, executor preserved
    assert calls["n"] == after_first      # no re-scheduling on the hit
    stats = plan_mod.plan_cache_stats()
    assert stats["hits"] >= 1

    # repeated execute: one traced executable per (backend, shape)
    f1 = p1.executor()
    plan_mod.execute(p1, params, img)
    plan_mod.execute(p1, params, img)
    assert p1.executor() is f1


def test_execute_batch_consistency(lenet):
    """Batched execute equals the same batch through the eager path."""
    layers, params, _ = lenet
    dev = LightatorDevice()
    imgs = jax.random.uniform(jax.random.PRNGKey(3), (8, 28, 28, 1))
    le, _ = dev.run_eager(layers, params, imgs, W4A4)
    lc, _ = dev.run(layers, params, imgs, W4A4)
    assert le.shape == (8, 10)
    np.testing.assert_array_equal(np.asarray(le), np.asarray(lc))


def test_pallas_backend_bit_identical(lenet):
    """Forcing the Pallas kernels (interpret mode on CPU) changes nothing:
    the OC accumulate is exact integer math on every backend."""
    layers, params, img = lenet
    dev = LightatorDevice()
    logits_ref, _ = dev.run_eager(layers, params, img, W4A4)
    plan_mod.clear_plan_cache()
    with dispatch.use_backend("pallas"):
        logits_pl, _ = dev.run(layers, params, img, W4A4)
    plan_mod.clear_plan_cache()
    np.testing.assert_array_equal(np.asarray(logits_ref),
                                  np.asarray(logits_pl))


def test_fc_batch_amortizes_only_remaps(lenet):
    """Batched schedule_fc: scheduling FC layers at the served batch size
    must change the per-frame report ONLY in the amortized terms — the
    per-cycle power breakdown of every layer is untouched, non-FC layers are
    completely untouched, and FC remap (DAC settle) cycles shrink ~1/N."""
    layers, _, img = lenet
    p1 = plan_mod.compile_model(tuple(layers), img.shape, W4A4, fc_batch=1)
    p8 = plan_mod.compile_model(tuple(layers), img.shape, W4A4, fc_batch=8)
    assert p8 is not p1                      # fc_batch is part of the key
    for s, l1, l8 in zip(p1.schedules, p1.report.layers, p8.report.layers):
        assert l1.breakdown_w == l8.breakdown_w       # power rates invariant
        if s.kind == "fc":
            # per-frame streaming cycles are batch-invariant (rounds * N
            # windows / N frames); only the remap (DAC settle) term amortizes
            assert l8.cycles == l1.cycles
            assert l8.remap_cycles == -(-l1.remap_cycles // 8)
            assert l8.remap_cycles < l1.remap_cycles
        else:
            assert (l1.cycles, l1.remap_cycles) == (l8.cycles,
                                                    l8.remap_cycles)
    assert p8.report.fps > p1.report.fps
    assert p8.report.exec_time_s < p1.report.exec_time_s
    with pytest.raises(ValueError, match="fc_batch"):
        plan_mod.compile_model(tuple(layers), img.shape, W4A4, fc_batch=0)


def test_fc_batch_default_matches_eager_report(lenet):
    """fc_batch=1 (the default) keeps the seed's bit-identical report."""
    layers, params, img = lenet
    dev = LightatorDevice()
    _, report_e = dev.run_eager(layers, params, img, W4A4)
    plan = plan_mod.compile_model(tuple(layers), img.shape, W4A4)
    assert dataclasses.asdict(report_e) == dataclasses.asdict(plan.report)


def test_execute_rejects_wrong_frame_shape(lenet):
    layers, params, img = lenet
    dev = LightatorDevice()
    plan = dev.compile(layers, img.shape, W4A4)
    bad = jnp.zeros((2, 14, 14, 1))
    with pytest.raises(ValueError, match="do not match plan"):
        plan_mod.execute(plan, params, bad)


# -- dispatch layer ---------------------------------------------------------

def test_default_interpret_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.default_interpret() == (not on_tpu)
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert dispatch.default_interpret() is True
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert dispatch.default_interpret() is False


def test_backend_selection_env_and_override(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    assert dispatch.get_backend() == "pallas"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
    assert dispatch.get_backend() == "reference"
    with dispatch.use_backend("pallas"):
        assert dispatch.get_backend() == "pallas"      # override beats env
    assert dispatch.get_backend() == "reference"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "bogus")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        dispatch.get_backend()
    with pytest.raises(ValueError, match="unknown backend"):
        dispatch.set_backend("bogus")


def test_matmul_int_backends_agree():
    k = jax.random.PRNGKey(0)
    a = jnp.round(jax.random.uniform(k, (5, 40)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(1), (40, 7)) * 14) - 7
    with dispatch.use_backend("reference"):
        ref = dispatch.matmul_int(a, wq)
    with dispatch.use_backend("pallas"):
        pal = dispatch.matmul_int(a, wq)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
