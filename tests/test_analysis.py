"""repro.analysis — the plan verifier and the concurrency lint.

Contracts under test:

* the 2^24 accumulator proof draws the line exactly: an adversarial
  program one fan-in notch over the f32 exact-integer window is rejected
  at compile (``LTR001``), while its just-inside twin compiles, gets a
  low-headroom warning, and runs **bit-identically** to an unverified
  compile (verification must observe, never perturb);
* ``Options(verify=)`` tri-state: "auto" proves on first compile, "on"
  re-checks cache hits, "off" bypasses; warnings land in
  ``ModelReport.verification``; bad modes (option or env) are named;
* the N-version property: ``select_fused_segments`` output always passes
  the verifier's independent halo/VMEM/legality audit on randomized
  conv chains (``audit_fused_segments``);
* the concurrency lint flags the exact bug classes past review rounds
  caught by hand (unlocked aug-assign, unjoined thread, future settled
  outside ``_settle``) and the real serve/obs tree is clean under it;
* regression: the deadline-shed path survives losing a settle race (the
  pre-lint code called ``set_exception`` directly and would crash the
  scheduler thread with ``InvalidStateError``).
"""

import dataclasses
import textwrap
import threading
from pathlib import Path

import jax
import numpy as np
import pytest

import repro
from repro import analysis, serve
from repro.core import plan as plan_mod
from repro.core.accelerator import (ConvSpec, DenseSpec, FlattenSpec)
from repro.core.program import Options, Program
from repro.core.quant import W4A4, WASpec
from repro.kernels import dispatch

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"

# a_qmax is global (plan.consts feeds one divisor): 2^4 - 1
A_QMAX = 15
W8_QMAX = WASpec(8, 4).w_qmax                       # 127
# smallest fan_in with 15 * 127 * fan_in >= 2^24 is 8808; use a margin
FAN_IN_OVER = 8810                                  # 16_783_050 >= 2^24
FAN_IN_UNDER = 8806                                 # 16_775_430 <  2^24


def _dense_program(fan_in: int, params=None) -> Program:
    layers = (FlattenSpec(), DenseSpec("fc", fan_in, 4, act="none"))
    return Program(layers, params or {}, (1, 1, fan_in),
                   name=f"dense{fan_in}")


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    plan_mod.clear_plan_cache()
    yield
    plan_mod.clear_plan_cache()


# -- the accumulator proof draws the line at 2^24 ----------------------------

def test_bound_arithmetic_brackets_the_window():
    assert analysis.acc_bound(A_QMAX, W8_QMAX, FAN_IN_OVER) >= 1 << 24
    assert analysis.acc_bound(A_QMAX, W8_QMAX, FAN_IN_UNDER) < 1 << 24
    assert analysis.headroom_bits(1 << 23) == pytest.approx(1.0)


def test_adversarial_overflow_rejected_at_compile():
    prog = _dense_program(FAN_IN_OVER)
    with pytest.raises(analysis.PlanVerificationError) as ei:
        prog.compile(Options(scheme=WASpec(8, 4)))     # default verify=auto
    err = ei.value
    assert [d.code for d in err.diagnostics if d.severity == "error"] \
        == ["LTR001"]
    d = next(d for d in err.diagnostics if d.code == "LTR001")
    assert d.step == "fc"
    assert f"{A_QMAX} * {W8_QMAX} * {FAN_IN_OVER}" in d.message
    assert "verify=\"off\"" in str(err)               # bypass is named
    # the failing plan must NOT have been cached as good
    plan_mod.clear_plan_cache()
    with pytest.raises(analysis.PlanVerificationError):
        prog.compile(Options(scheme=WASpec(8, 4), verify="on"))


def test_just_inside_twin_runs_bit_identically():
    """One fan-in notch inside the window: compiles (with the 0-headroom
    warning recorded, not raised) and runs bit-identically to a compile
    with verification off — the verifier observes, never perturbs."""
    from repro.models.vision import init_vision
    layers = (FlattenSpec(), DenseSpec("fc", FAN_IN_UNDER, 4, act="none"))
    params = init_vision(jax.random.PRNGKey(0), layers)
    prog = Program(layers, params, (1, 1, FAN_IN_UNDER), name="twin")
    frames = np.random.default_rng(0).random(
        (2, 1, 1, FAN_IN_UNDER)).astype(np.float32)
    opts = dict(scheme=WASpec(8, 4), backend="reference")
    exe_off = prog.compile(Options(verify="off", **opts))
    out_off = np.asarray(exe_off.run(frames))
    exe_on = prog.compile(Options(verify="on", **opts))   # cache-hit verify
    out_on = np.asarray(exe_on.run(frames))
    np.testing.assert_array_equal(out_off, out_on)
    warns = [d for d in exe_on.report.verification
             if d["code"] == "LTR002"]
    assert warns and warns[0]["step"] == "fc"
    assert "headroom" in warns[0]["message"]
    assert not [d for d in exe_on.report.verification
                if d["severity"] == "error"]


def test_headroom_report_on_lenet():
    exe = Program.from_model("lenet", params={}).compile(Options(scheme=W4A4))
    diags = analysis.verify_plan(exe.plan)
    assert not analysis.errors(diags)
    per_step = {d.step: d for d in diags if d.code == "LTR003"}
    assert set(per_step) == {"conv1", "conv2", "fc1", "fc2", "fc3"}
    hrs = [float(d.message.split("headroom ")[1].split(" bits")[0])
           for d in per_step.values()]
    assert min(hrs) > 8.0                       # lenet is comfortably exact
    # info stays out of the report: the eager/compiled identity contract
    assert exe.report.verification == []


# -- shape legality: caught at compile, not inside the jit -------------------

def test_channel_mismatch_rejected_at_compile():
    layers = (ConvSpec("c1", c_in=3, c_out=8),
              ConvSpec("c2", c_in=4, c_out=8))       # c2 receives 8, not 4
    prog = Program(layers, {}, (16, 16, 3), name="badchan")
    with pytest.raises(analysis.PlanVerificationError) as ei:
        prog.compile(Options(scheme=W4A4))
    d = next(d for d in ei.value.diagnostics if d.code == "LTR013")
    assert d.step == "c2" and "c_in=4" in d.message


def test_fan_in_mismatch_rejected_at_compile():
    layers = (ConvSpec("c1", c_in=1, c_out=4, padding="SAME"),
              FlattenSpec(),
              DenseSpec("fc", fan_in=99, fan_out=10))  # gets 8*8*4 = 256
    prog = Program(layers, {}, (8, 8, 1), name="badfan")
    with pytest.raises(analysis.PlanVerificationError) as ei:
        prog.compile(Options(scheme=W4A4))
    d = next(d for d in ei.value.diagnostics if d.code == "LTR014")
    assert d.step == "fc" and "fan_in=256" in d.hint


# -- Options(verify=) wiring -------------------------------------------------

def test_verify_option_validated_and_resolved(monkeypatch):
    with pytest.raises(ValueError, match="verify"):
        Options(verify="sometimes")
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    assert Options().resolve().verify == "auto"
    assert Options(verify="off").resolve().verify == "off"
    monkeypatch.setenv("REPRO_VERIFY", "on")
    assert Options().resolve().verify == "on"
    monkeypatch.setenv("REPRO_VERIFY", "bogus")
    with pytest.raises(ValueError, match="REPRO_VERIFY"):
        Options().resolve()
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    assert "verify=off" in Options(verify="off").resolve().describe()
    assert "verify" not in Options().resolve().describe()


def test_verify_off_skips_and_on_rechecks_cache_hits():
    prog = _dense_program(FAN_IN_OVER)
    # "off" lets the over-the-line plan compile (the documented bypass)
    exe = prog.compile(Options(scheme=WASpec(8, 4), verify="off"))
    assert exe.report.verification == []           # never inspected
    # "on" re-checks the now-cached plan and raises from the same plan
    with pytest.raises(analysis.PlanVerificationError):
        prog.compile(Options(scheme=WASpec(8, 4), verify="on"))
    # and raises again on the next hit (stored findings re-raise)
    with pytest.raises(analysis.PlanVerificationError):
        prog.compile(Options(scheme=WASpec(8, 4), verify="on"))
    # but "auto" on the cache hit stays quiet: first-compile-only
    exe2 = prog.compile(Options(scheme=WASpec(8, 4)))
    assert exe2.plan is exe.plan


def test_warning_surfaces_in_report_without_raising():
    """A forced-resident conv over a tiny budget is a warning (LTR021):
    recorded in ModelReport.verification, compile succeeds."""
    prog = Program.from_model("lenet", params={})
    exe = prog.compile(Options(scheme=W4A4, conv_strategy="resident",
                               conv_vmem_budget=1024, verify="on"))
    codes = {d["code"] for d in exe.report.verification}
    assert "LTR021" in codes
    assert all(d["severity"] == "warning" for d in exe.report.verification)


# -- satellite: conv_vmem_budget env validation ------------------------------

def test_conv_vmem_budget_rejects_non_integer(monkeypatch):
    monkeypatch.setenv("REPRO_CONV_VMEM_BUDGET", "lots")
    with pytest.raises(ValueError, match="REPRO_CONV_VMEM_BUDGET"):
        dispatch.conv_vmem_budget()


@pytest.mark.parametrize("bad", ["0", "-4194304"])
def test_conv_vmem_budget_rejects_non_positive(monkeypatch, bad):
    monkeypatch.setenv("REPRO_CONV_VMEM_BUDGET", bad)
    with pytest.raises(ValueError, match="must be > 0"):
        dispatch.conv_vmem_budget()


# -- the N-version property: fusion output always passes the audit -----------

def _random_chain(rng):
    """A shape-consistent conv chain with Nones (non-conv steps) mixed in,
    spanning the selector's whole legality vocabulary (depthwise, grouped,
    tanh, strides, pools)."""
    geoms = []
    h, w = int(rng.integers(8, 33)), int(rng.integers(8, 33))
    c = int(rng.choice([1, 3, 4, 8]))
    for i in range(int(rng.integers(1, 7))):
        if rng.random() < 0.15:
            geoms.append(None)                     # CA/flatten/dense break
            continue
        k = int(rng.choice([1, 3, 5]))
        if k > min(h, w):
            k = 1
        stride = int(rng.choice([1, 1, 1, 2]))
        depthwise = rng.random() < 0.2
        grouped = (not depthwise) and rng.random() < 0.1
        if depthwise:
            groups, c_out = c, c
        elif grouped and c % 2 == 0 and c > 1:
            groups, c_out = 2, int(rng.choice([4, 8]))
        else:
            groups, c_out = 1, int(rng.choice([1, 3, 4, 8, 16]))
        act = str(rng.choice(["relu", "abs", "sign", "none", "tanh"]))
        pads = (((k // 2,) * 2, (k // 2,) * 2) if rng.random() < 0.5
                else ((0, 0), (0, 0)))
        g = dispatch.ChainGeom(f"c{i}", h, w, c, c_out, k, stride, pads,
                               groups=groups, act=act, pool=None)
        h_out, w_out = g.out_hw()
        if (rng.random() < 0.3 and h_out >= 2 and w_out >= 2
                and h_out % 2 == 0 and w_out % 2 == 0):
            g = dataclasses.replace(
                g, pool=(str(rng.choice(["max", "avg"])), 2))
        h, w = g.out_hw()
        c = c_out
        geoms.append(g)
        if h < 2 or w < 2:
            break
    return geoms


@pytest.mark.parametrize("seed", range(8))
def test_fused_segments_always_pass_audit(seed):
    """Property: whatever segments select_fused_segments emits, the
    verifier's independent halo/VMEM/legality re-derivation agrees —
    across modes and budgets, on randomized chains."""
    rng = np.random.default_rng(seed)
    for _ in range(25):
        geoms = _random_chain(rng)
        for mode in ("auto", "on", "off"):
            for budget in (64 * 1024, 4 << 20, dispatch.conv_vmem_budget()):
                segs = dispatch.select_fused_segments(geoms, mode=mode,
                                                      budget=budget)
                diags = analysis.audit_fused_segments(geoms, segs, budget)
                errs = analysis.errors(diags)
                assert not errs, (mode, budget, geoms, segs,
                                  [str(d) for d in errs])


def test_audit_catches_planted_inconsistencies():
    """The audit is not vacuous: corrupt a legal segment set each way the
    fused kernel could go wrong and the matching code fires."""
    g = dispatch.ChainGeom("c0", 16, 16, 3, 8, 3, 1, ((1, 1), (1, 1)))
    g2 = dispatch.ChainGeom("c1", 16, 16, 8, 8, 3, 1, ((1, 1), (1, 1)))
    geoms = [g, g2]
    budget = dispatch.conv_vmem_budget()
    good = dispatch.select_fused_segments(geoms, mode="on", budget=budget)
    assert good and not analysis.errors(
        analysis.audit_fused_segments(geoms, good, budget))
    seg = good[0]

    def codes(segments, geoms=geoms):
        return {d.code for d in analysis.audit_fused_segments(
            geoms, segments, budget) if d.severity == "error"}

    assert "LTR024" in codes(
        [dataclasses.replace(seg, halo_rows=seg.halo_rows + 1)])
    assert "LTR024" in codes(
        [dataclasses.replace(seg, vmem_bytes=seg.vmem_bytes - 4)])
    assert "LTR023" in codes([dataclasses.replace(seg, start=1)])
    assert "LTR023" in codes([seg, seg])            # overlapping claims
    assert "LTR023" in codes(
        good, [dataclasses.replace(g, act="tanh"), g2])  # no fused tanh


# -- the concurrency lint ----------------------------------------------------

def _codes(src):
    return [d.code for d in analysis.lint_source(textwrap.dedent(src))]


def test_lint_unlocked_augassign():
    assert _codes("""
        class C:
            def hit(self):
                self.count += 1
    """) == ["LTC101"]


def test_lint_locked_and_local_augassign_clean():
    assert _codes("""
        class C:
            def __init__(self):
                self.count = 0
                self.count += 1          # unpublished: exempt
            def ok(self):
                with self._lock:
                    self.count += 1
            def ok_cond(self):
                with self._cond:
                    self.inflight[0] -= 1
            def local(self):
                n = 0
                n += 1
                return n
    """) == []


def test_lint_nested_def_resets_lock_context():
    # the closure body runs at call time, outside the with block
    assert _codes("""
        class C:
            def work(self):
                with self._lock:
                    def cb():
                        self.count += 1
                    return cb
    """) == ["LTC101"]


def test_lint_unjoined_thread():
    src = """
        import threading
        class S:
            def start(self):
                self._t = threading.Thread(target=self._run)
                self._t.start()
    """
    assert _codes(src) == ["LTC102"]
    assert _codes(src + """
            def stop(self):
                self._t.join(timeout=5.0)
    """) == []
    assert _codes("""
        import threading
        def fire():
            threading.Thread(target=work).start()
    """) == ["LTC102"]


def test_lint_settle_outside_helper():
    assert _codes("""
        def resolve(fut, out):
            fut.set_result(out)
    """) == ["LTC103"]
    assert _codes("""
        def _settle(fut, result=None, exc=None):
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(result)
    """) == []


def test_lint_suppression_is_per_code():
    assert _codes("""
        class C:
            def hit(self):
                self.count += 1          # lint: ok
    """) == []
    assert _codes("""
        class C:
            def hit(self):
                self.count += 1          # lint: ok[LTC102]
    """) == ["LTC101"]                   # wrong code: still flagged


def test_lint_serve_and_obs_trees_are_clean():
    """The gate ci.sh runs: the real serving/observability runtime has no
    error-severity concurrency findings."""
    findings = analysis.lint_paths([SRC / "serve", SRC / "obs"])
    assert analysis.errors(findings) == (), \
        "\n".join(str(d) for d in findings)


# -- regression: deadline shed must survive losing the settle race -----------

REFERENCE = Options(scheme=W4A4, backend="reference")


def test_shed_survives_presettled_future():
    """The scheduler's deadline shed races external settlers (timed-out
    stop, cancellation). Pre-settle the future from the batch_close hook
    (which runs on the scheduler thread between collect and shed): the
    old direct set_exception crashed the scheduler with
    InvalidStateError; via _settle it must be a counted no-op."""
    prog = repro.Program.from_model("lenet", key=jax.random.PRNGKey(0))
    clk = serve.VirtualClock()
    external = RuntimeError("externally cancelled")
    fut_box, fired = {}, threading.Event()

    def close_hook(name, reason, n):
        if not fired.is_set():
            fired.set()
            clk.advance(1.0)             # now past the 50ms deadline
            fut_box["f"].set_exception(external)   # win the settle race

    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.0),
                          clock=clk,
                          hooks=serve.Hooks(batch_close=close_hook))
    server.register("lenet", prog, REFERENCE)
    frames = np.random.default_rng(0).random(
        (1, 28, 28, 1)).astype(np.float32)
    fut_box["f"] = server.submit("lenet", frames, deadline_ms=50.0)
    server.start()
    try:
        with pytest.raises(RuntimeError, match="externally cancelled"):
            fut_box["f"].result(timeout=120)
        # the scheduler thread survived: later work still gets served
        ok = server.submit("lenet", frames, deadline_ms=600_000.0)
        assert ok.result(timeout=120).shape == (1, 10)
        reqs = server.stats()["programs"]["lenet"]["requests"]
        assert reqs["shed_deadline"] == 0   # the race loser must not count
        assert reqs["served"] == 1
    finally:
        server.stop()


# -- diagnostics plumbing ----------------------------------------------------

def test_diagnostic_formatting_and_severity_order():
    d = analysis.Diagnostic("LTR001", "error", "fc", "boom", hint="fix it")
    assert str(d) == "LTR001 [error] fc: boom (hint: fix it)"
    assert d.asdict()["code"] == "LTR001"
    with pytest.raises(ValueError):
        analysis.Diagnostic("LTR001", "fatal", "fc", "boom")
    diags = [analysis.Diagnostic("LTR003", "info", "a", "m"),
             analysis.Diagnostic("LTR002", "warning", "b", "m")]
    assert analysis.worst_severity(diags) == "warning"
    assert analysis.errors(diags) == ()
    text = analysis.format_diagnostics(diags, min_severity="warning")
    assert "LTR002" in text and "LTR003" not in text
