"""Optimizer + gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compression import (compress_int8, decompress_int8,
                                     init_error_state)
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine


def test_adamw_converges_on_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=10.0)
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_bf16_params_with_fp32_master():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    cfg = AdamWConfig(lr=1e-4)
    state = adamw_init(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    for _ in range(10):
        params, state, _ = adamw_update(params, grads, state, cfg)
    # master accumulates sub-bf16-resolution updates
    assert params["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(state["master"]["w"] - 1.0))) > 0


def test_grad_clip():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    import numpy as np
    s0 = float(linear_warmup_cosine(jnp.asarray(0), 10, 100, 1.0))
    s10 = float(linear_warmup_cosine(jnp.asarray(10), 10, 100, 1.0))
    s100 = float(linear_warmup_cosine(jnp.asarray(100), 10, 100, 1.0))
    assert s0 == 0.0 and s10 == pytest.approx(1.0)
    assert s100 == pytest.approx(0.1, rel=1e-2)
    c = [float(cosine_schedule(jnp.asarray(i), 50, 1.0)) for i in range(51)]
    assert all(np.diff(c) <= 1e-9)


@pytest.mark.parametrize("shape", [(100,), (33, 7), (256, 256)])
def test_int8_compression_roundtrip(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    codes, scales = compress_int8(x)
    assert codes.dtype == jnp.int8
    y = decompress_int8(codes, scales, shape)
    # error bounded by scale/2 per block
    err = jnp.abs(x - y)
    bound = jnp.repeat(scales, 256)[:x.size].reshape(shape) * 0.5 + 1e-7
    assert bool(jnp.all(err <= bound))


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the accumulated decompressed sum tracks the
    accumulated true gradient (residual stays bounded)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 1e-3
    e = jnp.zeros_like(g)
    total_true = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for i in range(20):
        gi = g * (1 + 0.1 * i)
        comp_in = gi + e
        codes, scales = compress_int8(comp_in)
        deq = decompress_int8(codes, scales, g.shape)
        e = comp_in - deq
        total_true += gi
        total_sent += deq
    # residual equals the final error state: sum_sent + e == sum_true
    np.testing.assert_allclose(np.asarray(total_sent + e),
                               np.asarray(total_true), rtol=1e-5, atol=1e-7)
