"""Device-level photonic model tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photonics as ph


def test_transmission_monotone_in_detuning():
    d = jnp.linspace(0, 0.5, 50)
    t = ph.mr_through_transmission(d, fwhm_nm=0.1)
    assert float(t[0]) == 0.0                      # on resonance: full drop
    assert bool(jnp.all(jnp.diff(t) >= 0))         # monotone
    assert float(t[-1]) > 0.9                      # far off resonance


def test_weight_to_detuning_roundtrip():
    targets = jnp.linspace(0.01, 0.95, 20)
    d = ph.weight_to_detuning(targets, fwhm_nm=0.1)
    realized = ph.mr_through_transmission(d, fwhm_nm=0.1)
    np.testing.assert_allclose(np.asarray(realized), np.asarray(targets),
                               rtol=1e-5)


def test_half_transmission_at_half_fwhm():
    t = ph.mr_through_transmission(jnp.asarray(0.05), fwhm_nm=0.1)
    assert abs(float(t) - 0.5) < 1e-6


def test_vcsel_li_curve():
    codes = jnp.arange(16)
    p = ph.vcsel_intensity(codes)
    assert float(p[0]) == 0.0                      # below threshold
    diffs = jnp.diff(p)
    assert bool(jnp.all(diffs >= 0))               # monotone in drive code
    assert float(p[15]) > 0


def test_drift_noise_changes_transmission():
    t = jnp.full((128,), 0.5)
    noisy = ph.photonic_noise(jax.random.PRNGKey(0), t, drift_std_nm=0.02)
    assert float(jnp.std(noisy)) > 0.0
    assert bool(jnp.all((noisy >= 0) & (noisy <= 1)))


def test_bpd_differential_signed():
    pos = jnp.asarray([1.0, 0.0, 2.0])
    neg = jnp.asarray([0.0, 1.0, 2.0])
    i = ph.bpd_differential(pos, neg)
    assert float(i[0]) > 0 and float(i[1]) < 0 and abs(float(i[2])) < 1e-12


def test_q_factor():
    dev = ph.MRDevice(lambda_res_nm=1550.0, fwhm_nm=0.1)
    assert abs(dev.q_factor - 15500) < 1
