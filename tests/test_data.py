"""Data pipeline tests: determinism, shapes, prefetch."""

import numpy as np
import pytest

from repro.configs import smoke_variant
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import (SyntheticTextConfig, modality_batch,
                                  synthetic_digits, synthetic_lm_batches,
                                  synthetic_textures)


def test_lm_stream_deterministic():
    cfg = SyntheticTextConfig(vocab=100, seq=16, batch=4, seed=7)
    a = [next(synthetic_lm_batches(cfg)) for _ in range(1)][0]
    b = [next(synthetic_lm_batches(cfg)) for _ in range(1)][0]
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_lm_stream_has_planted_structure():
    cfg = SyntheticTextConfig(vocab=1000, seq=256, batch=8, seed=0)
    batch = next(synthetic_lm_batches(cfg))
    toks, labels = batch["tokens"], batch["labels"]
    follow = (toks * 7 + 3) % cfg.vocab
    frac = float(np.mean(labels == follow))
    # ~26% of transitions follow the planted bigram (consecutive rewrites
    # break some chains); chance level is 1/vocab = 0.1%.
    assert frac > 0.2                      # learnable bigram structure


def test_digits():
    imgs, labels = synthetic_digits(64, seed=0)
    assert imgs.shape == (64, 28, 28, 1)
    assert imgs.min() >= 0 and imgs.max() <= 1
    assert set(np.unique(labels)).issubset(set(range(10)))
    # deterministic
    imgs2, labels2 = synthetic_digits(64, seed=0)
    np.testing.assert_array_equal(imgs, imgs2)
    # digit classes differ visually (mean images are distinct)
    m1 = imgs[labels == 1].mean(0)
    m8 = imgs[labels == 8].mean(0)
    assert np.abs(m1 - m8).mean() > 0.02


def test_textures():
    imgs, labels = synthetic_textures(32, n_classes=10, seed=1)
    assert imgs.shape == (32, 32, 32, 3)
    assert imgs.dtype == np.float32


def test_modality_batch_per_arch():
    for arch in ("smollm-360m", "hubert-xlarge", "internvl2-26b"):
        cfg = smoke_variant(arch)
        b = modality_batch(cfg, 2, 16, seed=0)
        assert "labels" in b
        if cfg.frontend == "audio":
            assert b["frames"].shape == (2, 16, cfg.frontend_dim)
        if cfg.frontend == "vision":
            assert b["patches"].shape == (2, cfg.n_patches, cfg.frontend_dim)


def test_pipeline_prefetch_order_and_determinism():
    def batch_fn(step):
        return {"x": np.full((2,), step, np.float32)}

    p = DataPipeline(batch_fn, prefetch=2, start_step=0)
    steps = []
    for _ in range(5):
        s, b = p.next()
        steps.append(s)
        assert float(b["x"][0]) == s
    p.stop()
    assert steps == [0, 1, 2, 3, 4]


def test_pipeline_resume_from_step():
    def batch_fn(step):
        return {"x": np.full((1,), step, np.float32)}

    p = DataPipeline(batch_fn, prefetch=1, start_step=10)
    s, b = p.next()
    p.stop()
    assert s == 10 and float(b["x"][0]) == 10.0


def test_process_slice():
    sl = DataPipeline.process_slice(256, process_index=3, process_count=8)
    assert sl == slice(96, 128)
