"""Fused megakernel chain: bit-identity to the unfused path + segmentation.

The fusion pass's correctness bar is absolute: a fused segment (one Pallas
launch running tap-loop conv accumulates with the full in-kernel epilogue)
must produce the SAME BITS as the step-by-step executor it replaces, on
both backends, under both calibration modes that admit fusion (per-frame at
any batch, per-tensor at batch 1). The property suite here drives randomly
generated chains — lengths, kernels, strides, pools, activations, bias,
depthwise — through Options(fuse="on") vs fuse="off" and asserts exact
equality; the unit tests pin the segment-selection heuristic and the
report plumbing.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import ConvSpec
from repro.core.program import Options, Program
from repro.kernels import dispatch


# ---------------------------------------------------------------------------
# Random chain generator
# ---------------------------------------------------------------------------

def _random_chain(rng: np.random.RandomState, hw: int = 32):
    """A random fusable conv chain program + matching input frames."""
    n_stages = rng.randint(1, 5)
    layers, params = [], {}
    h = w = hw
    c = int(rng.choice([1, 2, 3]))
    c_in0 = c
    for i in range(n_stages):
        name = f"conv{i}"
        depthwise = bool(rng.rand() < 0.25)
        k = int(rng.choice([1, 3, 5]))
        stride = 1
        pool = None
        act = str(rng.choice(dispatch.FUSABLE_ACTS))
        if depthwise:
            c_out = c
            wshape = (k, k, 1, c)
        else:
            c_out = int(rng.choice([1, 2, 4]))
            wshape = (k, k, c, c_out)
            # strides/pools only where the dims stay divisible
            if h % 2 == 0 and rng.rand() < 0.3:
                stride = 2
            h_out = -(-h // stride)
            if h_out % 2 == 0 and rng.rand() < 0.3:
                pool = (str(rng.choice(["max", "avg"])), 2)
        layers.append(ConvSpec(name, c, c_out, kernel=k, stride=stride,
                               padding="SAME", act=act, pool=pool,
                               depthwise=depthwise))
        params[name] = {"w": rng.randn(*wshape).astype(np.float32) * 0.4}
        if rng.rand() < 0.5:
            params[name]["b"] = rng.randn(c_out).astype(np.float32) * 0.1
        h = w = -(-h // stride) // (pool[1] if pool else 1)
        c = c_out
    prog = Program(tuple(layers), params, (hw, hw, c_in0),
                   name=f"chain{n_stages}")
    frames = rng.rand(3, hw, hw, c_in0).astype(np.float32)
    return prog, frames


def _assert_bitwise(a, b, msg):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, msg
    if not np.array_equal(a, b):
        diff = float(np.max(np.abs(a - b)))
        raise AssertionError(f"{msg}: max |diff| = {diff:g}")


# ---------------------------------------------------------------------------
# Property suite: fused == unfused, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("seed", range(6))
def test_random_chain_fused_bit_identical(backend, seed):
    rng = np.random.RandomState(seed)
    prog, frames = _random_chain(rng)
    on = prog.compile(Options(backend=backend, fuse="on"))
    off = prog.compile(Options(backend=backend, fuse="off"))
    assert len(on.plan.fused_segments) >= 1
    assert not off.plan.fused_segments
    _assert_bitwise(on.run_per_frame(frames), off.run_per_frame(frames),
                    f"{prog.name} per-frame fused vs unfused ({backend})")
    _assert_bitwise(on.run(frames[:1]), off.run(frames[:1]),
                    f"{prog.name} B=1 per-tensor fused vs unfused ({backend})")


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_imaging_chain_fused_bit_identical(backend):
    """The acceptance chain: denoise -> edge_detect -> sharpen."""
    prog = Program.from_pipeline("denoise_gauss", 64, 64, 1).then(
        Program.from_pipeline("edge_detect", 64, 64, 1)).then(
        Program.from_pipeline("sharpen", 64, 64, 1))
    frames = np.random.RandomState(7).rand(4, 64, 64, 1).astype(np.float32)
    on = prog.compile(Options(backend=backend, fuse="on"))
    off = prog.compile(Options(backend=backend, fuse="off"))
    # every conv in the chain fuses into one segment = one launch
    assert [s.names for s in on.plan.fused_segments] == \
        [("gauss", "grad", "edge_mag", "sharpen")]
    _assert_bitwise(on.run_per_frame(frames), off.run_per_frame(frames),
                    f"imaging chain per-frame ({backend})")
    _assert_bitwise(on.run(frames[:1]), off.run(frames[:1]),
                    f"imaging chain B=1 ({backend})")


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_lenet_convs_fuse_bit_identical(backend):
    """LeNet's two 5x5+avg-pool+bias convs fuse under auto and stay exact."""
    prog = Program.from_model("lenet", key=jax.random.PRNGKey(0))
    auto = prog.compile(Options(backend=backend))
    off = prog.compile(Options(backend=backend, fuse="off"))
    assert [s.names for s in auto.plan.fused_segments] == \
        [("conv1", "conv2")]
    frames = np.random.RandomState(3).rand(2, 28, 28, 1).astype(np.float32)
    _assert_bitwise(auto.run_per_frame(frames), off.run_per_frame(frames),
                    f"lenet per-frame ({backend})")
    _assert_bitwise(auto.run(frames[:1]), off.run(frames[:1]),
                    f"lenet B=1 ({backend})")


def test_per_tensor_large_batch_falls_back_unfused():
    """Per-tensor calibration at B>1 couples frames through the requant max:
    the executor must run unfused (trace-time fallback) and stay exact."""
    rng = np.random.RandomState(11)
    prog, frames = _random_chain(rng)
    on = prog.compile(Options(backend="reference", fuse="on"))
    off = prog.compile(Options(backend="reference", fuse="off"))
    _assert_bitwise(on.run(frames), off.run(frames),
                    "B>1 per-tensor must fall back to the unfused trace")


def test_conv_chain_rejects_coupled_batch():
    g = dispatch.ChainGeom("c", 8, 8, 1, 1, 3, 1, ((1, 1), (1, 1)))
    wq = jnp.ones((3, 3, 1, 1), jnp.int8)
    ws = jnp.ones((1, 1, 1, 1), jnp.float32)
    codes = jnp.ones((2, 8, 8, 1), jnp.float32)
    with pytest.raises(ValueError, match="batch 1"):
        dispatch.conv_chain(codes, jnp.float32(0.1), [(g, wq, ws, None)],
                            jnp.float32(15.0), per_frame=False)


# ---------------------------------------------------------------------------
# Segment selection heuristic
# ---------------------------------------------------------------------------

def _geom(name, cin=1, cout=1, k=3, stride=1, hw=32, act="relu", pool=None,
          groups=1):
    return dispatch.ChainGeom(name, hw, hw, cin, cout, k, stride,
                              ((k // 2, k // 2), (k // 2, k // 2)),
                              groups=groups, act=act, pool=pool)


def test_auto_needs_runs_of_two():
    segs = dispatch.select_fused_segments([_geom("a")], mode="auto")
    assert segs == ()
    segs = dispatch.select_fused_segments([_geom("a"), _geom("b")],
                                          mode="auto")
    assert [s.names for s in segs] == [("a", "b")]


def test_on_fuses_singletons_and_off_disables():
    geoms = [_geom("a"), None, _geom("b")]
    on = dispatch.select_fused_segments(geoms, mode="on")
    assert [(s.start, s.names) for s in on] == [(0, ("a",)), (2, ("b",))]
    assert dispatch.select_fused_segments(geoms, mode="off") == ()


def test_non_conv_steps_break_runs():
    geoms = [_geom("a"), _geom("b"), None, _geom("c"), _geom("d")]
    segs = dispatch.select_fused_segments(geoms, mode="auto")
    assert [(s.start, s.names) for s in segs] == \
        [(0, ("a", "b")), (3, ("c", "d"))]


def test_auto_channel_cap_and_budget_exclude_stages():
    big = _geom("big", cin=64, cout=64)          # 4096 > channel cap
    segs = dispatch.select_fused_segments([_geom("a"), big, _geom("b")],
                                          mode="auto")
    assert segs == ()                            # no adjacent runs survive
    # "on" ignores the cap: the caller asked for one launch
    segs = dispatch.select_fused_segments([_geom("a"), big], mode="on")
    assert [s.names for s in segs] == [("a", "big")]
    # budget excludes oversized frames in auto
    huge = _geom("huge", hw=4096)
    assert dispatch.select_fused_segments([huge, huge], mode="auto") == ()


def test_unfusable_act_and_grouped_convs_break_runs():
    tanh = _geom("t", act="tanh")
    assert dispatch.select_fused_segments([_geom("a"), tanh], mode="on") \
        == (dispatch.FusedSegmentSpec(0, ("a",), 2, _geom("a").stage_bytes()),)
    grouped = _geom("g", cin=4, cout=4, groups=2)
    assert dispatch.select_fused_segments([grouped], mode="on") == ()
    dw = _geom("dw", cin=4, cout=4, groups=4)
    assert [s.names for s in
            dispatch.select_fused_segments([dw], mode="on")] == [("dw",)]


def test_halo_growth_recurrence():
    # two stride-1 3x3 stages: (k-1) rows each -> 4
    segs = dispatch.select_fused_segments([_geom("a"), _geom("b")],
                                          mode="auto")
    assert segs[0].halo_rows == 4
    # stride-2 first stage doubles the downstream halo: one output row of b
    # needs 3 rows of its input; those 3 rows need (3-1)*2+3 = 7 of a's
    # input -> halo 6
    segs = dispatch.select_fused_segments(
        [_geom("a", stride=2), _geom("b", hw=16)], mode="auto")
    assert segs[0].halo_rows == 6
    # pool expands rows before the conv recurrence: one output row of b
    # needs 3 pooled rows of a = 6 pre-pool conv rows = (6-1)*1+3 = 8 input
    # rows -> halo 7
    segs = dispatch.select_fused_segments(
        [_geom("a", pool=("max", 2)), _geom("b", hw=16)], mode="auto")
    assert segs[0].halo_rows == 7


def test_fuse_mode_derivation():
    assert dispatch.conv_fuse_mode("fused") == "on"
    assert dispatch.conv_fuse_mode("resident") == "off"
    assert dispatch.conv_fuse_mode("strip") == "off"
    assert dispatch.conv_fuse_mode("auto") == "auto"


def test_options_validates_fuse_mode():
    with pytest.raises(ValueError, match="fuse mode"):
        Options(fuse="always")
    assert Options(fuse="on").resolve().fuse == "on"
    assert Options(conv_strategy="strip").resolve().fuse == "off"
    assert Options(conv_strategy="fused").resolve().fuse == "on"


# ---------------------------------------------------------------------------
# Report plumbing
# ---------------------------------------------------------------------------

def test_report_records_fused_segments_and_cache_keys_on_fuse():
    prog = Program.from_pipeline("edge_detect", 32, 32, 1).then(
        Program.from_pipeline("sharpen", 32, 32, 1))
    on = prog.compile(Options(fuse="on"))
    off = prog.compile(Options(fuse="off"))
    assert on.plan is not off.plan            # fuse mode is in the cache key
    assert on.report.fused_segments == [
        dataclasses.asdict(s) for s in on.plan.fused_segments]
    assert off.report.fused_segments == []
    names = [n for s in on.report.fused_segments for n in s["names"]]
    assert set(names) <= set(on.report.conv_strategy)


def test_eager_report_mirrors_fused_segments():
    """run_eager resolves the same fused segments as the compile pass."""
    from repro.core.accelerator import LightatorDevice
    from repro.core.quant import W4A4
    prog = Program.from_model("lenet", key=jax.random.PRNGKey(1))
    img = np.random.RandomState(5).rand(1, 28, 28, 1).astype(np.float32)
    dev = LightatorDevice()
    _, report_e = dev.run_eager(prog.layers, prog.params, jnp.asarray(img),
                                W4A4)
    exe = prog.compile(Options())
    assert report_e.fused_segments == exe.report.fused_segments
    assert len(report_e.fused_segments) == 1
