"""repro.imaging — golden tests for the fixed-function pipelines.

Float path: analytic expectations (classical filter identities) on
deterministic synthetic frames. Quantized path: every pipeline compiled via
core.plan under [4:4] must stay within a per-pipeline PSNR floor of the
float reference — the device's 4-bit CRC + MR quantization budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.accelerator import ConvSpec, UpsampleSpec
from repro.core.quant import W4A4
from repro.imaging import (PIPELINES, apply_float, fit_recon_head,
                           gray_target, psnr, ssim,
                           recon_head_identity_params)
from repro.kernels import dispatch

HW = 32


@pytest.fixture(scope="module")
def frames():
    from repro.data.synthetic import synthetic_textures
    imgs, _ = synthetic_textures(2, hw=HW, seed=0)
    return jnp.asarray(imgs)


def _const_rgb(val=0.5, hw=HW):
    return jnp.full((1, hw, hw, 3), val, jnp.float32)


# -- float-path golden identities -------------------------------------------

def test_edge_detect_zero_on_constant():
    layers, params = PIPELINES["edge_detect"].build(HW, HW, 3)
    out = apply_float(layers, params, _const_rgb())
    # gradient of a constant is zero away from the border padding
    np.testing.assert_allclose(np.asarray(out[:, 2:-2, 2:-2]), 0.0,
                               atol=1e-5)


@pytest.mark.parametrize("name", ["edge_detect", "prewitt_edge"])
def test_edge_detect_peaks_on_step(name):
    img = jnp.zeros((1, HW, HW, 3)).at[:, :, HW // 2:, :].set(1.0)
    layers, params = PIPELINES[name].build(HW, HW, 3)
    out = apply_float(layers, params, img)[0, :, :, 0]
    # response is maximal on the two columns adjacent to the vertical step
    # and zero in the flat regions (away from the zero-padded border, which
    # itself reads as an edge of the bright half)
    peak = np.asarray(out[2:-2, HW // 2 - 1: HW // 2 + 1])
    assert peak.min() > 1.0
    np.testing.assert_allclose(np.asarray(out[2:-2, 2:HW // 2 - 2]), 0.0,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[2:-2, HW // 2 + 2:-2]), 0.0,
                               atol=1e-5)


@pytest.mark.parametrize("name", ["sharpen", "unsharp_mask"])
def test_sharpen_preserves_constant(name):
    """Sharpening kernels sum to 1: flat regions pass through unchanged."""
    layers, params = PIPELINES[name].build(HW, HW, 3)
    out = apply_float(layers, params, _const_rgb(0.4))
    gray = float(gray_target(_const_rgb(0.4))[0, HW // 2, HW // 2, 0])
    margin = 3                       # outside border-padding influence
    np.testing.assert_allclose(
        np.asarray(out[:, margin:-margin, margin:-margin, 0]), gray,
        rtol=1e-5)


def test_denoise_impulse_response():
    """A unit impulse spreads to exactly the kernel coefficients."""
    from repro.imaging.filters import gaussian_kernel
    img = jnp.zeros((1, HW, HW, 3)).at[0, HW // 2, HW // 2, 1].set(1.0)
    layers, params = PIPELINES["denoise_gauss"].build(HW, HW, 3)
    out = apply_float(layers, params, img)
    k = gaussian_kernel(5, 1.0)
    got = np.asarray(out[0, HW // 2 - 2:HW // 2 + 3,
                         HW // 2 - 2:HW // 2 + 3, 1])
    np.testing.assert_allclose(got, k, rtol=1e-5)
    # untouched channels stay zero (depthwise: no cross-channel mixing)
    np.testing.assert_allclose(np.asarray(out[..., 0]), 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out[..., 2]), 0.0, atol=1e-7)


def test_compress_recon_constant_roundtrip():
    layers, params = PIPELINES["compress_recon"].build(HW, HW, 3)
    out = apply_float(layers, params, _const_rgb(0.6))
    gray = float(gray_target(_const_rgb(0.6))[0, 0, 0, 0])
    np.testing.assert_allclose(np.asarray(out), gray, rtol=1e-5)


def test_deconv_head_identity_at_init(frames):
    """Identity-initialized head == plain bilinear reconstruction."""
    l_bi, p_bi = PIPELINES["compress_recon"].build(HW, HW, 3)
    l_dc, p_dc = PIPELINES["compress_recon_deconv"].build(HW, HW, 3)
    np.testing.assert_allclose(np.asarray(apply_float(l_dc, p_dc, frames)),
                               np.asarray(apply_float(l_bi, p_bi, frames)),
                               atol=1e-6)


def test_fit_recon_head_improves_psnr(frames):
    layers, params = PIPELINES["compress_recon_deconv"].build(HW, HW, 3)
    tgt = gray_target(frames)
    before = float(psnr(tgt, apply_float(layers, params, frames)))
    fitted = fit_recon_head(layers, params, frames, steps=60)
    after = float(psnr(tgt, apply_float(layers, fitted, frames)))
    assert after > before


# -- quantized device path vs float reference --------------------------------

# Per-pipeline PSNR floors (dB) for [4:4] on 32x32 textures: the device's
# 4-bit activation budget. The sharpen family sits lowest because its
# outputs overshoot negative and the CRC's non-negativity clamp (absent
# from the float oracle) adds clipping error on top of quantization.
PSNR_FLOORS = {
    "edge_detect": 20.0, "prewitt_edge": 20.0,
    "sharpen": 10.0, "unsharp_mask": 10.0,
    "denoise_gauss": 20.0, "denoise_box": 24.0,
    "compress_recon": 24.0, "compress_recon_deconv": 24.0,
}


@pytest.mark.parametrize("name", sorted(PIPELINES))
def test_quantized_tracks_float(frames, name):
    exe = PIPELINES[name].program(HW, HW, 3).compile(
        repro.Options(scheme=W4A4))
    out = exe.run(frames)
    ref = apply_float(exe.program.layers, exe.program.params, frames)
    assert out.shape == ref.shape
    p = float(psnr(ref, out))
    assert p > PSNR_FLOORS[name], f"{name}: PSNR {p:.2f} dB under floor"
    assert float(ssim(ref, out)) > 0.5
    # image-valued plans report spatial outputs, power report is populated
    assert out.ndim == 4 and exe.report.fps > 0


def test_registry_entries_consistent():
    for name, pipe in PIPELINES.items():
        assert pipe.name == name
        assert pipe.kind in ("filter", "recon")
        with pytest.raises(ValueError, match="channels"):
            pipe.build(HW, HW, 2)


def test_pipelines_accept_grayscale_input(frames):
    gray = gray_target(frames)
    for name in ("edge_detect", "denoise_box", "compress_recon"):
        prog = PIPELINES[name].program(HW, HW, 1)
        out = prog.compile(repro.Options(scheme=W4A4)).run(gray)
        ref = apply_float(prog.layers, prog.params, gray)
        assert out.shape == ref.shape
        assert float(psnr(ref, out)) > 15.0


# -- plan-runtime growth: depthwise conv + upsample step ---------------------

def test_depthwise_conv_int_matches_manual():
    key = jax.random.PRNGKey(0)
    codes = jnp.round(jax.random.uniform(key, (2, 8, 8, 3)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(1),
                                      (3, 3, 1, 3)) * 14) - 7
    pads = ((1, 1), (1, 1))
    out = dispatch.conv_int(codes, wq, 1, pads, groups=3)
    assert out.shape == (2, 8, 8, 3)
    for ch in range(3):
        ref = jax.lax.conv_general_dilated(
            codes[..., ch:ch + 1], wq[..., ch:ch + 1], (1, 1), pads,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_array_equal(np.asarray(out[..., ch]),
                                      np.asarray(ref[..., 0]))


def test_depthwise_conv_int_backends_agree():
    codes = jnp.round(jax.random.uniform(jax.random.PRNGKey(2),
                                         (1, 8, 8, 3)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(3),
                                      (3, 3, 1, 3)) * 14) - 7
    pads = ((1, 1), (1, 1))
    with dispatch.use_backend("reference"):
        ref = dispatch.conv_int(codes, wq, 1, pads, groups=3)
    with dispatch.use_backend("pallas"):
        pal = dispatch.conv_int(codes, wq, 1, pads, groups=3)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_depthwise_requires_matching_channels():
    layers = (ConvSpec("dw", 3, 4, kernel=3, depthwise=True),)
    with pytest.raises(ValueError, match="depthwise"):
        repro.Program(layers, {}, (8, 8, 3)).compile()


def test_upsample_step_shapes_and_schedule():
    from repro.core.compressive import upsample_reconstruct
    layers = (UpsampleSpec(factor=2, method="bilinear"),)
    exe = repro.Program(layers, {}, (8, 8, 1)).compile(
        repro.Options(scheme=W4A4))
    assert exe.plan.schedules[-1].kind == "ca"      # preset banks, no remaps
    assert exe.plan.schedules[-1].weight_remaps == 0
    x = jax.random.uniform(jax.random.PRNGKey(4), (1, 8, 8, 1))
    out = exe.run(x)
    assert out.shape == (1, 16, 16, 1)
    # quantization aside, the step is the shared upsample_reconstruct
    ref = upsample_reconstruct(x, 2, "bilinear")
    assert float(psnr(ref, out)) > 25.0
    with pytest.raises(ValueError, match="method"):
        repro.Program((UpsampleSpec(2, "bicubic"),), {}, (8, 8, 1)).compile()
    # multi-channel upsample: windows (and the report's cycle count) scale
    # with C — each channel interpolates independently on the preset banks
    e3 = repro.Program(layers, {}, (8, 8, 3)).compile(
        repro.Options(scheme=W4A4))
    assert e3.plan.schedules[-1].cycles == 3 * exe.plan.schedules[-1].cycles
    out3 = e3.run(jax.random.uniform(jax.random.PRNGKey(5), (1, 8, 8, 3)))
    assert out3.shape == (1, 16, 16, 3)


def test_conv_int_rejects_bad_groups():
    codes = jnp.zeros((1, 4, 4, 3))
    wq = jnp.zeros((3, 3, 1, 4))
    with pytest.raises(ValueError, match="groups"):
        dispatch.conv_int(codes, wq, 1, ((1, 1), (1, 1)), groups=3)


def test_run_eager_rejects_imaging_ir(frames):
    """The eager interpreter covers the seed IR only; imaging runs compiled."""
    from repro.core.accelerator import LightatorDevice
    dev = LightatorDevice()
    layers, params = PIPELINES["denoise_box"].build(HW, HW, 3)
    with pytest.raises(NotImplementedError, match="depthwise"):
        dev.run_eager(layers, params, frames, W4A4)
    layers, params = PIPELINES["compress_recon"].build(HW, HW, 3)
    with pytest.raises(TypeError, match="unknown layer IR"):
        dev.run_eager(layers, params, frames, W4A4)


# -- serving smoke -----------------------------------------------------------

@pytest.mark.parametrize("wait_ms", ["0", "2"])
def test_serve_vision_pipeline_smoke(wait_ms):
    """The acceptance-criteria entry point, tiny: immediate-dispatch +
    micro-batched collection through the repro.serve runtime."""
    from repro.launch import serve_vision
    fps = serve_vision.main(["--pipeline", "edge_detect", "--batch", "2",
                             "--batches", "2", "--size", "16",
                             "--max-wait-ms", wait_ms])
    assert fps > 0
