"""Mamba2 SSD tests: chunked vs sequential oracle, decode, conv cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import ssm as S


def _inputs(seed, b, t, h, p, g, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, t, g, n))
    cm = jax.random.normal(ks[4], (b, t, g, n))
    return x, dt, a, bm, cm


@pytest.mark.parametrize("b,t,h,p,g,n,chunk", [
    (1, 32, 1, 1, 1, 1, 8), (2, 64, 4, 16, 1, 8, 16),
    (2, 64, 4, 16, 2, 8, 16), (1, 128, 8, 32, 4, 16, 32),
    (2, 96, 6, 8, 3, 4, 48),
])
def test_chunked_matches_reference(b, t, h, p, g, n, chunk):
    x, dt, a, bm, cm = _inputs(t + h, b, t, h, p, g, n)
    y1, s1 = S.ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    y2, s2 = S.ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    x, dt, a, bm, cm = _inputs(0, 2, 64, 4, 8, 2, 8)
    y16, _ = S.ssd_chunked(x, dt, a, bm, cm, chunk=16)
    y64, _ = S.ssd_chunked(x, dt, a, bm, cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               rtol=2e-4, atol=2e-4)


def test_initial_state_continuation():
    """Splitting a sequence across two calls == one call (state carry)."""
    x, dt, a, bm, cm = _inputs(1, 1, 64, 2, 4, 1, 4)
    y_full, s_full = S.ssd_chunked(x, dt, a, bm, cm, chunk=16)
    y1, s1 = S.ssd_chunked(x[:, :32], dt[:, :32], a, bm[:, :32], cm[:, :32],
                           chunk=16)
    y2, s2 = S.ssd_chunked(x[:, 32:], dt[:, 32:], a, bm[:, 32:], cm[:, 32:],
                           chunk=16, initial_state=s1)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


def test_decode_steps_match_chunked():
    b, t, h, p, g, n = 2, 32, 4, 8, 2, 4
    x, dt, a, bm, cm = _inputs(2, b, t, h, p, g, n)
    y_ref, _ = S.ssd_chunked(x, dt, a, bm, cm, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for i in range(t):
        yi, state = S.ssd_decode_step(state, x[:, i], dt[:, i], a,
                                      bm[:, i], cm[:, i])
        ys.append(yi)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_and_step():
    b, t, c, k = 2, 16, 6, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (b, t, c))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, c)) * 0.3
    bias = jax.random.normal(jax.random.PRNGKey(2), (c,)) * 0.1
    y_full = S.causal_conv1d(x, w, bias)
    state = jnp.zeros((b, k - 1, c))
    ys = []
    for i in range(t):
        yi, state = S.causal_conv1d_step(state, x[:, i], w, bias)
        ys.append(yi)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-5, atol=1e-5)


def test_segsum_semantics():
    a = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
    s = S.segsum(a)[0]
    assert float(s[0, 0]) == 0.0
    assert float(s[2, 0]) == 5.0           # a[1] + a[2]
    assert float(s[3, 1]) == 7.0           # a[2] + a[3]
    assert bool(jnp.isneginf(s[0, 1]))
