"""repro.obs — tracing/metrics contracts, and the serving integration.

The load-bearing contracts:

* spans on one tid always **nest, never interleave** — including across
  the serving scheduler/completer thread boundary (each thread keeps its
  own span stack; cross-thread request timelines go on synthetic lanes);
* a request's ``trace_id`` survives the whole pad -> bucket -> split trip
  and its four ``serve.request.*`` spans reassemble into one contiguous,
  ordered timeline;
* the exported Chrome-trace JSON round-trips ``json.loads`` and passes
  the same schema check the CI smoke runs (scripts/check_trace.py);
* the disabled path records nothing and ``Options(trace=)`` maps onto
  the per-thread mode pin;
* ``ProgramMetrics`` (now an obs-registry facade) keeps its snapshot
  shape, its empty-reservoir ``{"count": 0}`` latency summary and a
  finite ``achieved_fps`` even on a degenerate zero-width window;
* ``scripts/check_bench.py`` rejects NaN/inf scalars in BENCH files.
"""

import importlib.util
import json
import re
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

import repro
from repro import obs, serve
from repro.core.quant import W4A4
from repro.serve.metrics import ProgramMetrics, latency_summary

ROOT = Path(__file__).resolve().parent.parent
REFERENCE = repro.Options(scheme=W4A4, backend="reference")


@pytest.fixture()
def collector():
    """A fresh installed collector; always uninstalled afterwards."""
    trace = obs.enable()
    try:
        yield trace
    finally:
        obs.disable()


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "scripts" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Trace core
# ---------------------------------------------------------------------------

class TestTrace:
    def test_disabled_records_nothing(self):
        assert obs.get_trace() is None
        with obs.span("t.outer", attrs={"k": 1}):
            obs.event("t.inner")
        assert obs.get_trace() is None          # no lazy install in auto

    def test_span_nesting_single_thread(self, collector):
        with obs.span("t.outer"):
            with obs.span("t.mid"):
                with obs.span("t.leaf"):
                    pass
        spans = {s["name"]: s for s in collector.spans()}
        assert spans["t.leaf"]["parent"] == spans["t.mid"]["id"]
        assert spans["t.mid"]["parent"] == spans["t.outer"]["id"]
        assert spans["t.outer"]["parent"] is None
        # children close inside the parent's window
        for child, parent in (("t.leaf", "t.mid"), ("t.mid", "t.outer")):
            assert spans[parent]["t0_ns"] <= spans[child]["t0_ns"]
            assert spans[child]["t1_ns"] <= spans[parent]["t1_ns"]

    def test_spans_never_interleave_per_tid(self, collector):
        """On every tid, spans form a proper nesting (no partial overlap) —
        pinned with concurrent recording threads."""
        def worker(i):
            for _ in range(20):
                with obs.span(f"t.w{i}.outer"):
                    with obs.span(f"t.w{i}.inner"):
                        pass
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        _assert_tid_spans_nest(collector.spans())

    def test_trace_id_inherits_to_children_and_events(self, collector):
        with obs.span("t.outer", trace_id="req-7"):
            with obs.span("t.child"):
                obs.event("t.evt")
        child = collector.spans("t.child")[0]
        evt = collector.events("t.evt")[0]
        assert child["trace_id"] == "req-7"
        assert evt["trace_id"] == "req-7"
        assert obs.current_trace_id() is None   # restored on exit

    def test_use_mode_off_suppresses_while_collecting(self, collector):
        with obs.use_mode("off"):
            with obs.span("t.hidden"):
                obs.event("t.hidden_evt")
        assert collector.records() == []

    def test_use_mode_on_installs_collector(self):
        assert obs.get_trace() is None
        try:
            with obs.use_mode("on"):
                assert obs.enabled()
                with obs.span("t.forced"):
                    pass
            trace = obs.get_trace()
            assert trace is not None
            assert trace.spans("t.forced")
        finally:
            obs.disable()

    def test_chrome_export_roundtrip(self, tmp_path, collector):
        with obs.span("t.outer", attrs={"n": 3}):
            obs.event("t.mark")
        collector.add_span("t.lane", 100, 200, trace_id="req-0",
                           tid=999_000, lane="req-0")
        path = tmp_path / "trace.json"
        collector.export(path)
        data = json.loads(path.read_text())
        evs = data["traceEvents"]
        assert data["displayTimeUnit"] == "ms"
        by_ph = {}
        for e in evs:
            by_ph.setdefault(e["ph"], []).append(e)
            assert "name" in e and "pid" in e and "tid" in e
            if e["ph"] == "X":
                assert "ts" in e and "dur" in e and e["dur"] >= 0
        assert {"X", "i", "M"} <= set(by_ph)
        lane_meta = [e for e in by_ph["M"] if e["args"]["name"] == "req-0"]
        assert lane_meta and lane_meta[0]["tid"] == 999_000

    def test_summary_rollup(self, collector):
        collector.add_span("t.a", 0, 2_000_000)
        collector.add_span("t.a", 0, 1_000_000)
        collector.add_span("t.b", 0, 500_000)
        s = collector.summary()
        assert s["t.a"]["count"] == 2
        assert s["t.a"]["total_ms"] == pytest.approx(3.0)
        assert s["t.b"]["total_ms"] == pytest.approx(0.5)


def _assert_tid_spans_nest(spans):
    by_tid = {}
    for s in spans:
        by_tid.setdefault(s["tid"], []).append(s)
    for tid, ss in by_tid.items():
        for a in ss:
            for b in ss:
                if a is b:
                    continue
                # any two spans on one tid: disjoint or fully nested
                disjoint = (a["t1_ns"] <= b["t0_ns"]
                            or b["t1_ns"] <= a["t0_ns"])
                nested = ((a["t0_ns"] >= b["t0_ns"]
                           and a["t1_ns"] <= b["t1_ns"])
                          or (b["t0_ns"] >= a["t0_ns"]
                              and b["t1_ns"] <= a["t1_ns"]))
                assert disjoint or nested, (
                    f"tid {tid}: spans {a['name']} and {b['name']} "
                    f"interleave")


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = obs.Registry()
        c = reg.counter("c")
        c.inc()
        c.inc(4)
        assert c.get() == 5
        g = reg.gauge("g")
        g.set(3.0)
        g.add(-1.0)
        assert g.get() == 2.0
        h = reg.histogram("h", buckets=(0.5, 1.0))
        for v in (0.2, 0.7, 2.0):
            h.observe(v)
        s = h.summary()
        assert s["count"] == 3
        assert s["sum"] == pytest.approx(2.9)
        assert s["min"] == pytest.approx(0.2)
        assert s["max"] == pytest.approx(2.0)

    def test_same_name_same_metric_type_mismatch_raises(self):
        reg = obs.Registry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_snapshot_and_reset(self):
        reg = obs.Registry()
        reg.counter("a").inc(2)
        reg.gauge("b").set(7)
        snap = reg.snapshot()
        assert snap["a"] == 2 and snap["b"] == 7
        reg.reset()
        assert reg.counter("a").get() == 0

    def test_prometheus_text(self):
        reg = obs.Registry()
        reg.counter("serve.lenet.served").inc(3)
        reg.histogram("waste", buckets=(0.5, 1.0)).observe(0.25)
        text = obs.prometheus_text(reg)
        assert "# TYPE serve_lenet_served counter" in text
        assert "serve_lenet_served 3" in text
        assert 'waste_bucket{le="0.5"} 1' in text
        assert 'waste_bucket{le="+Inf"} 1' in text
        assert "waste_count 1" in text

    def test_write_jsonl(self, tmp_path):
        path = tmp_path / "out.jsonl"
        obs.write_jsonl(path, [{"a": 1}, {"b": 2}], append=False)
        obs.write_jsonl(path, [{"c": 3}])
        lines = [json.loads(l) for l in path.read_text().splitlines()]
        assert lines == [{"a": 1}, {"b": 2}, {"c": 3}]

    def test_write_jsonl_concurrent_writers_never_tear_lines(self, tmp_path):
        """8 threads x 50 records each: every line parses, none torn."""
        path = tmp_path / "log.jsonl"
        n_threads, n_records = 8, 50

        def writer(tid):
            for i in range(n_records):
                obs.write_jsonl(path, [{"tid": tid, "i": i,
                                        "pad": "x" * 200}])

        threads = [threading.Thread(target=writer, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        recs = [json.loads(l) for l in path.read_text().splitlines()]
        assert len(recs) == n_threads * n_records
        # every (tid, i) pair present exactly once — no lost appends
        assert {(r["tid"], r["i"]) for r in recs} == {
            (t, i) for t in range(n_threads) for i in range(n_records)}

    def test_prometheus_help_lines_carry_dotted_names(self):
        reg = obs.Registry()
        reg.counter("slo.breach.edge-detect").inc()
        reg.gauge("pool/depth").set(2)
        text = obs.prometheus_text(reg)
        assert "# HELP slo_breach_edge_detect " \
               "repro metric 'slo.breach.edge-detect'" in text
        assert "# TYPE slo_breach_edge_detect counter" in text
        assert "# HELP pool_depth repro metric 'pool/depth'" in text

    def test_prometheus_name_escaping_full_grammar(self):
        reg = obs.Registry()
        reg.counter("4k.frames served").inc(7)   # digit-first + space
        text = obs.prometheus_text(reg)
        assert "_4k_frames_served 7" in text
        for line in text.splitlines():
            if line.startswith("#") or not line:
                continue
            name = line.split("{")[0].split()[0]
            assert re.fullmatch(r"[a-zA-Z_:][a-zA-Z0-9_:]*", name), line

    def test_prometheus_exposition_parses_back_to_snapshot(self):
        """The text exposition is not write-only: parsing it back
        recovers every scalar the registry snapshot reports."""
        reg = obs.Registry()
        reg.counter("served").inc(5)
        reg.gauge("depth").set(3.5)
        h = reg.histogram("lat.ms", buckets=(1.0, 10.0))
        for v in (0.5, 2.0, 50.0):
            h.observe(v)
        parsed = {}
        for line in obs.prometheus_text(reg).splitlines():
            if line.startswith("#") or not line:
                continue
            key, val = line.rsplit(" ", 1)
            parsed[key] = float(val)
        assert parsed["served"] == 5
        assert parsed["depth"] == 3.5
        assert parsed['lat_ms_bucket{le="1"}'] == 1
        assert parsed['lat_ms_bucket{le="10"}'] == 2     # cumulative
        assert parsed['lat_ms_bucket{le="+Inf"}'] == 3
        assert parsed["lat_ms_count"] == 3
        assert parsed["lat_ms_sum"] == pytest.approx(52.5)
        snap = reg.snapshot()
        assert parsed["served"] == snap["served"]
        assert parsed["lat_ms_count"] == snap["lat.ms"]["count"]

    def test_histogram_concurrent_writers_property(self):
        """8 threads x 1000 observes: the histogram loses nothing and
        its exposition stays internally consistent (exact count/sum,
        monotone non-decreasing cumulative buckets summing to count)."""
        reg = obs.Registry()
        h = reg.histogram("lat", buckets=(0.25, 0.5, 0.75))
        n_threads, n_obs = 8, 1000
        values = [[(i * 7919 % 1000) / 1000.0 for i in range(n_obs)]
                  for _ in range(n_threads)]

        def worker(vs):
            for v in vs:
                h.observe(v)

        threads = [threading.Thread(target=worker, args=(vs,))
                   for vs in values]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = n_threads * n_obs
        s = h.summary()
        assert s["count"] == total
        assert s["sum"] == pytest.approx(
            sum(v for vs in values for v in vs))
        cumulative = []
        for line in obs.prometheus_text(reg).splitlines():
            if line.startswith("lat_bucket"):
                cumulative.append(float(line.rsplit(" ", 1)[1]))
        assert cumulative == sorted(cumulative)      # monotone
        assert cumulative[-1] == total               # +Inf == count
        # and the latency reservoir agrees with the histogram count
        # when fed through the serving facade under the same contention
        m = ProgramMetrics(name="p")

        def served(vs):
            for v in vs:
                m.record_served(v, 1, t_done=v)

        threads = [threading.Thread(target=served, args=(vs,))
                   for vs in values]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert m.snapshot()["latency_ms"]["count"] == total


# ---------------------------------------------------------------------------
# ProgramMetrics facade (snapshot shape preserved + satellite fixes)
# ---------------------------------------------------------------------------

class TestProgramMetrics:
    def test_snapshot_shape_preserved(self):
        m = ProgramMetrics(name="lenet")
        m.record_admit(2)
        m.add_queued(3)
        m.record_batch(4, t_dispatch=10.0, frames=3)
        m.record_served(0.010, 2, t_done=10.5)
        m.record_served(0.020, 1, t_done=11.0)
        m.add_queued(-3)
        snap = m.snapshot()
        assert set(snap) == {"requests", "frames_served", "queue_depth",
                             "batches", "avg_batch", "padding_waste",
                             "achieved_fps", "latency_ms"}
        assert snap["requests"]["submitted"] == 2
        assert snap["requests"]["served"] == 2
        assert snap["requests"]["pending"] == 0
        assert snap["frames_served"] == 3
        assert snap["queue_depth"] == 0
        assert snap["padding_waste"] == pytest.approx(0.25)
        assert snap["achieved_fps"] == pytest.approx(3.0)  # 3 frames / 1 s
        assert snap["latency_ms"]["count"] == 2

    def test_achieved_fps_zero_window_clamped(self):
        m = ProgramMetrics(name="p")
        t = 5.0
        m.record_batch(1, t_dispatch=t, frames=1)
        m.record_served(0.001, 1, t_done=t)      # t_first == t_last
        fps = m.snapshot()["achieved_fps"]
        assert np.isfinite(fps) and fps > 0

    def test_empty_latency_summary_shape(self):
        assert latency_summary(np.asarray([], np.float64)) == {"count": 0}
        assert ProgramMetrics().snapshot()["latency_ms"] == {"count": 0}

    def test_occupancy_histograms(self):
        m = ProgramMetrics(name="p")
        m.record_batch(4, t_dispatch=0.0, frames=3)
        h = m.histograms()
        assert h["batch_occupancy"]["count"] == 1
        assert h["batch_occupancy"]["mean"] == pytest.approx(0.75)
        assert h["padding_waste"]["mean"] == pytest.approx(0.25)

    def test_private_registries_do_not_alias(self):
        a, b = ProgramMetrics(name="p"), ProgramMetrics(name="p")
        a.record_admit()
        assert a.submitted == 1 and b.submitted == 0


# ---------------------------------------------------------------------------
# Options(trace=) plumbing
# ---------------------------------------------------------------------------

class TestOptionsTrace:
    def test_validation(self):
        assert repro.Options(trace="off").trace == "off"
        with pytest.raises(ValueError):
            repro.Options(trace="verbose")

    def test_resolve_defaults_to_auto(self):
        assert repro.Options().resolve().trace == "auto"

    def test_trace_off_suppresses_run_spans(self, collector):
        prog = repro.Program.from_pipeline("edge_detect", 8, 8, 3)
        frames = np.random.default_rng(0).random((1, 8, 8, 3),
                                                 ).astype(np.float32)
        exe = prog.compile(repro.Options(backend="reference", trace="off"))
        np.asarray(exe.run(frames))
        assert collector.spans() == []
        # same plan, trace back on: the run-path spans appear
        exe2 = prog.compile(repro.Options(backend="reference"))
        assert exe2.plan is exe.plan            # trace= not in the cache key

    def test_describe_mentions_non_auto_trace(self):
        assert "trace=off" in repro.Options(trace="off").describe()
        assert "trace=" not in repro.Options().describe()


# ---------------------------------------------------------------------------
# Serving integration: trace_id end to end
# ---------------------------------------------------------------------------

class TestServingTrace:
    @pytest.fixture(scope="class")
    def program(self):
        return repro.Program.from_pipeline("edge_detect", 16, 16, 3)

    def test_request_timelines_reassemble(self, tmp_path, program,
                                          collector):
        server = serve.Server(serve.ServeConfig(max_batch=4,
                                                max_wait_ms=2.0))
        server.register("edge", program, REFERENCE)
        server.start(warm=True)
        rng = np.random.default_rng(1)
        futs = [server.submit(
            "edge", rng.random((16, 16, 3)).astype(np.float32))
            for _ in range(7)]
        for f in futs:
            f.result(timeout=60)
        server.stop()

        spans = collector.spans()
        _assert_tid_spans_nest(spans)            # incl. sched/completer tids

        phases = ("serve.request.queue_wait", "serve.request.batch_assembly",
                  "serve.request.device", "serve.request.split")
        by_req = {}
        for s in spans:
            if s["name"] in phases:
                by_req.setdefault(s["trace_id"], {})[s["name"]] = s
        assert len(by_req) == 7                  # one timeline per request
        for tid, named in by_req.items():
            assert set(named) == set(phases), tid
            ordered = [named[p] for p in phases]
            for a, b in zip(ordered, ordered[1:]):
                assert a["t1_ns"] == b["t0_ns"]  # contiguous timeline
            lanes = {s["tid"] for s in ordered}
            assert len(lanes) == 1               # one synthetic lane each

        # submit events carry the same trace ids
        submit_ids = {e["trace_id"] for e in collector.events("serve.submit")}
        assert submit_ids == set(by_req)

        # the export passes the CI smoke's validator
        path = tmp_path / "serve_trace.json"
        collector.export(path)
        check_trace = _load_script("check_trace")
        assert check_trace.check(str(path), min_device_spans=1) == []

    def test_stats_report_cache_and_dispatch(self, program):
        server = serve.Server(serve.ServeConfig(max_batch=2))
        server.register("edge", program, REFERENCE)
        server.start()
        f = server.submit("edge", np.zeros((16, 16, 3), np.float32))
        f.result(timeout=60)
        stats = server.stats(verbose=True)
        server.stop()
        cache = stats["plan_cache"]
        assert cache["hits"] + cache["misses"] >= 1
        assert 0.0 <= cache["hit_rate"] <= 1.0
        assert isinstance(stats["conv_dispatch"], dict)
        snap = stats["programs"]["edge"]
        assert np.isfinite(snap["measured_kfps_per_w"])
        assert np.isfinite(snap["kfps_per_w_drift"])
        assert snap["model"]["energy_per_frame_j"] > 0
        assert "batch_occupancy" in snap["histograms"]
        assert "obs" in stats


# ---------------------------------------------------------------------------
# check_bench NaN rejection (satellite)
# ---------------------------------------------------------------------------

class TestCheckBench:
    def test_rejects_nan_and_inf_scalars(self):
        check_bench = _load_script("check_bench")
        errors = []
        check_bench.check_finite(
            "BENCH_x.json",
            {"a": {"p50": float("nan")}, "b": [1.0, float("inf")], "c": 2.0},
            errors)
        assert len(errors) == 2
        assert any("a.p50" in e for e in errors)
        errors = []
        check_bench.check_finite("BENCH_x.json", {"ok": 1.5}, errors)
        assert errors == []

    def test_obs_overhead_gate(self):
        check_bench = _load_script("check_bench")
        errors = []
        check_bench.check_invariants(
            "BENCH_obs.json",
            {"chain": {"overhead_disabled_pct": 5.0, "frame_us_raw": 100.0}},
            errors)
        assert any("overhead_disabled_pct" in e for e in errors)
        errors = []
        check_bench.check_invariants(
            "BENCH_obs.json",
            {"chain": {"overhead_disabled_pct": 0.4, "frame_us_raw": 100.0}},
            errors)
        assert errors == []

    def test_committed_bench_obs_passes(self):
        check_bench = _load_script("check_bench")
        data = json.loads((ROOT / "benchmarks" / "BENCH_obs.json")
                          .read_text())
        errors = []
        check_bench.check_finite("BENCH_obs.json", data, errors)
        check_bench.check_invariants("BENCH_obs.json", data, errors)
        assert errors == []
