"""conv_bank kernel vs XLA conv oracle: kernel-size/channel/quant sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import W4A4, W3A4, W2A4
from repro.kernels.conv_bank.ops import conv_bank
from repro.kernels.conv_bank.ref import conv_bank_ref, conv_bank_quant_ref


@pytest.mark.parametrize("kk", [3, 5, 7])
@pytest.mark.parametrize("cin,cout", [(1, 16), (8, 32), (3, 64)])
def test_float_conv(kk, cin, cout):
    key = jax.random.PRNGKey(kk * 100 + cin)
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (2, 16, 16, cin))
    w = jax.random.normal(k2, (kk, kk, cin, cout)) * 0.1
    got = conv_bank(x, w)
    want = conv_bank_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("spec", [W4A4, W3A4, W2A4], ids=lambda s: s.name)
def test_quantized_conv(spec):
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (2, 12, 12, 4))
    w = jax.random.normal(k2, (3, 3, 4, 24)) * 0.2
    got = conv_bank(x, w, spec)
    want = conv_bank_quant_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_odd_sizes_and_bn_fallback():
    x = jax.random.uniform(jax.random.PRNGKey(0), (1, 7, 9, 5))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 5, 13)) * 0.1
    got = conv_bank(x, w, bn=64)     # bn > cout -> falls back to divisor
    want = conv_bank_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_quant_integer_exactness():
    """Integer accumulation in f32 is exact for OC-scale fan-ins."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 16, (1, 8, 8, 64)).astype(np.float32))
    w = jnp.asarray(rng.integers(-7, 8, (3, 3, 64, 8)).astype(np.float32))
    got = conv_bank(x * (1 / 15), w, W4A4, act_scale=1 / 15)
    want = conv_bank_quant_ref(x * (1 / 15), w, W4A4, act_scale=1 / 15)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
