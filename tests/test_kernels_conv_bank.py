"""conv_bank kernels vs XLA conv oracle: kernel-size/channel/quant sweeps
plus the strip-mined large-frame path (halo DMA, strided/depthwise modes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import W4A4, W3A4, W2A4
from repro.kernels.conv_bank import strip_kernel as SK
from repro.kernels.conv_bank.ops import conv_bank
from repro.kernels.conv_bank.ref import conv_bank_ref, conv_bank_quant_ref


@pytest.mark.parametrize("kk", [3, 5, 7])
@pytest.mark.parametrize("cin,cout", [(1, 16), (8, 32), (3, 64)])
def test_float_conv(kk, cin, cout):
    key = jax.random.PRNGKey(kk * 100 + cin)
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (2, 16, 16, cin))
    w = jax.random.normal(k2, (kk, kk, cin, cout)) * 0.1
    got = conv_bank(x, w)
    want = conv_bank_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("spec", [W4A4, W3A4, W2A4], ids=lambda s: s.name)
def test_quantized_conv(spec):
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (2, 12, 12, 4))
    w = jax.random.normal(k2, (3, 3, 4, 24)) * 0.2
    got = conv_bank(x, w, spec)
    want = conv_bank_quant_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_odd_sizes_and_bn_fallback():
    x = jax.random.uniform(jax.random.PRNGKey(0), (1, 7, 9, 5))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 5, 13)) * 0.1
    got = conv_bank(x, w, bn=64)     # bn > cout -> falls back to divisor
    want = conv_bank_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_quant_integer_exactness():
    """Integer accumulation in f32 is exact for OC-scale fan-ins."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 16, (1, 8, 8, 64)).astype(np.float32))
    w = jnp.asarray(rng.integers(-7, 8, (3, 3, 64, 8)).astype(np.float32))
    got = conv_bank(x * (1 / 15), w, W4A4, act_scale=1 / 15)
    want = conv_bank_quant_ref(x * (1 / 15), w, W4A4, act_scale=1 / 15)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# -- strip-mined path --------------------------------------------------------

def _int_frame(key, shape):
    return jnp.round(jax.random.uniform(jax.random.PRNGKey(key), shape) * 15)


def _int_weights(key, shape):
    return jnp.round(
        jax.random.uniform(jax.random.PRNGKey(key), shape) * 14) - 7


@pytest.mark.parametrize("kk", [3, 5, 7])
def test_strip_bit_identical_to_resident(kk):
    """Same op, both kernels: the strip path accumulates the same exact
    integers as the resident path, so the quantized outputs are identical."""
    x = jax.random.uniform(jax.random.PRNGKey(kk), (2, 17, 21, 5))
    w = jax.random.normal(jax.random.PRNGKey(kk + 50), (kk, kk, 5, 12)) * 0.1
    res = conv_bank(x, w, W4A4, strategy="resident")
    stp = conv_bank(x, w, W4A4, strategy="strip")
    np.testing.assert_array_equal(np.asarray(res), np.asarray(stp))


def test_strip_float_conv_matches_oracle():
    x = jax.random.uniform(jax.random.PRNGKey(0), (1, 40, 33, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (5, 5, 3, 8)) * 0.1
    got = conv_bank(x, w, strategy="strip")
    np.testing.assert_allclose(np.asarray(got), np.asarray(conv_bank_ref(x, w)),
                               rtol=1e-4, atol=1e-5)


# the ISSUE acceptance shapes: VGG16 / AlexNet conv layers (Fig. 10) and a
# full >=256x256 sensor frame — all past the VMEM-resident assumption
LARGE_SHAPES = [
    # (name, H, W, c_in, c_out, k, stride, padding)
    ("vgg16.conv1", 224, 224, 3, 64, 3, 1, "SAME"),
    ("vgg16.conv3", 112, 112, 64, 32, 3, 1, "SAME"),
    ("alexnet.conv1", 227, 227, 3, 96, 11, 4, "VALID"),
    ("frame256", 256, 256, 1, 8, 3, 1, "SAME"),
]


@pytest.mark.parametrize("name,h,w,cin,cout,kk,stride,padding",
                         LARGE_SHAPES, ids=[s[0] for s in LARGE_SHAPES])
def test_strip_quant_bit_identity_large(name, h, w, cin, cout, kk, stride,
                                        padding):
    """Strip-mined conv is bit-identical to the integer conv oracle on the
    large shapes that motivated it (vgg16/alexnet convs, 256x256 frames)."""
    codes = _int_frame(1, (1, h, w, cin))
    wq = _int_weights(2, (kk, kk, cin, cout))
    pad = kk // 2 if padding == "SAME" else 0
    h_out = (h + 2 * pad - kk) // stride + 1
    w_out = (w + 2 * pad - kk) // stride + 1
    from repro.kernels import dispatch
    strat = dispatch.select_conv_strategy(h_out, w_out, cin, cout, kk,
                                          stride, mode="strip")
    xp = SK.pad_rows_for_strips(
        jnp.pad(codes, ((0, 0), (pad, pad), (pad, pad), (0, 0))),
        kk, stride, strat.strip_rows, strat.n_strips)
    got = SK.conv_strip_kernel(xp, wq, jnp.ones((cout,)), kk=kk,
                               stride=stride,
                               strip_h=strat.strip_rows)[:, :h_out]
    want = jax.lax.conv_general_dilated(
        codes, wq, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("hw", [64, 256])
def test_strip_depthwise_bit_identity(hw):
    """The depthwise strip kernel (no per-channel im2col) vs the grouped
    conv oracle, up to full 256x256 RGB sensor frames."""
    c, kk = 3, 5
    codes = _int_frame(3, (1, hw, hw, c))
    wq = _int_weights(4, (kk, kk, 1, c))
    pad = kk // 2
    from repro.kernels import dispatch
    strat = dispatch.select_conv_strategy(hw, hw, c, c, kk, 1, groups=c,
                                          mode="strip")
    xp = SK.pad_rows_for_strips(
        jnp.pad(codes, ((0, 0), (pad, pad), (pad, pad), (0, 0))),
        kk, 1, strat.strip_rows, strat.n_strips)
    got = SK.conv_strip_depthwise_kernel(
        xp, wq.reshape(kk * kk, c), jnp.ones((c,)), kk=kk,
        strip_h=strat.strip_rows)[:, :hw]
    want = jax.lax.conv_general_dilated(
        codes, wq, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_strip_kernel_rejects_misaligned_rows():
    x = jnp.zeros((1, 12, 12, 2))
    w = jnp.zeros((3, 3, 2, 4))
    with pytest.raises(ValueError, match="strip_h"):
        SK.conv_strip_kernel(x, w, jnp.ones((4,)), kk=3, strip_h=4)
