"""Hardware-mapping methodology tests — the paper's Fig. 6 numbers, exactly."""

import math

import pytest

from repro.core import optical_core as oc


def test_geometry():
    c = oc.OCConfig()
    assert c.mrs_per_bank == 54
    assert c.n_banks == 96
    assert c.total_mrs == 5184
    assert c.total_arms == 576
    assert c.macs_per_cycle == 5184


@pytest.mark.parametrize("k,arms,strides,idle,stages", [
    (3, 1, 6, 0, 0),      # Fig. 6(a)
    (5, 3, 2, 2, 1),      # Fig. 6(b)
    (7, 6, 1, 5, 2),      # Fig. 6(c)
])
def test_fig6_mappings(k, arms, strides, idle, stages):
    m = oc.conv_mapping(k)
    assert m.arms_per_stride == arms
    assert m.strides_per_bank == strides
    assert m.idle_mrs_per_stride == idle
    assert m.summation_stages == stages


def test_fc_mapping_segments_into_9s():
    m = oc.fc_mapping(100)
    assert m.arms_per_stride == math.ceil(100 / 9)
    assert m.idle_mrs_per_stride == m.arms_per_stride * 9 - 100


@pytest.mark.parametrize("h,w,cin,cout,k", [
    (32, 32, 3, 64, 3), (16, 16, 64, 128, 3), (8, 8, 1, 16, 5),
    (28, 28, 1, 6, 5), (4, 4, 256, 256, 3),
])
def test_schedule_conv_invariants(h, w, cin, cout, k):
    s = oc.schedule_conv("x", h, w, cin, cout, k)
    m = oc.conv_mapping(k, cin)
    assert s.macs == h * w * cout * m.kernel_taps
    assert 0.0 < s.utilization <= 1.0
    assert s.mapped_mrs_avg <= oc.DEFAULT_OC.total_mrs
    assert s.weight_remaps >= 1
    # cycles x concurrent outputs must cover all strides
    resident = min(oc.kernels_resident(m), cout)
    assert s.cycles == math.ceil(cout / resident) * h * w


def test_schedule_fc_invariants():
    s = oc.schedule_fc("fc", 1024, 512, batch=4)
    assert s.macs == 4 * 1024 * 512
    assert s.cycles >= 4
    assert 0.0 < s.utilization <= 1.0


def test_ca_schedule_has_no_dac_remaps():
    s = oc.schedule_ca("ca", 16, 16, 2, channels=3)
    assert s.weight_remaps == 0
    assert s.kind == "ca"


def test_large_kernel_multibank():
    m = oc.conv_mapping(11)          # AlexNet conv1: 121 taps -> 14 arms
    assert m.arms_per_stride == 14
    assert m.strides_per_bank == 0   # spans banks
    assert m.banks_per_stride == 3
    s = oc.schedule_conv("a1", 55, 55, 3, 96, 11)
    assert s.cycles > 0 and s.utilization <= 1.0


def test_matmul_schedule_matches_fc():
    s1 = oc.schedule_matmul("m", 16, 1024, 512)
    s2 = oc.schedule_fc("m", 1024, 512, batch=16)
    assert s1.cycles == s2.cycles and s1.macs == s2.macs
