"""Conv strategy selection (resident vs strip-mined) across the stack:
the dispatch heuristic, env overrides, plan/report recording, and
end-to-end bit-identity of strip-mined plans on large frames."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import plan as plan_mod
from repro.core.accelerator import ConvSpec
from repro.core.quant import W4A4
from repro.kernels import dispatch
from repro.models.vision import vision_program


# -- heuristic ---------------------------------------------------------------

def test_small_frames_stay_resident():
    s = dispatch.select_conv_strategy(32, 32, 64, 64, 3)
    assert s == dispatch.ConvStrategy("resident")


def test_large_frames_go_strip():
    # vgg16 conv2: the per-frame im2col patch matrix is ~115 MB
    s = dispatch.select_conv_strategy(224, 224, 64, 64, 3)
    assert s.kind == "strip"
    assert 1 <= s.strip_rows <= 224
    assert s.strip_rows * s.n_strips >= 224
    # the input strip + halo actually fits in half the budget
    wp = 223 + 3
    rows_in = s.strip_rows - 1 + 3
    assert rows_in * wp * 64 * 4 <= dispatch.conv_vmem_budget() // 2


def test_depthwise_always_strips_on_auto():
    s = dispatch.select_conv_strategy(16, 16, 3, 3, 3, groups=3)
    assert s.kind == "strip"


def test_env_override_and_validation(monkeypatch):
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "resident")
    assert dispatch.select_conv_strategy(224, 224, 64, 64, 3).kind == \
        "resident"
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "strip")
    assert dispatch.select_conv_strategy(8, 8, 2, 2, 3).kind == "strip"
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "bogus")
    with pytest.raises(ValueError, match="REPRO_CONV_STRATEGY"):
        dispatch.conv_strategy_mode()
    monkeypatch.delenv("REPRO_CONV_STRATEGY")
    with pytest.raises(ValueError, match="unknown conv strategy"):
        dispatch.select_conv_strategy(8, 8, 2, 2, 3, mode="bogus")


def test_budget_env_shrinks_strips(monkeypatch):
    wide = dispatch.select_conv_strategy(256, 256, 8, 8, 3, mode="strip")
    monkeypatch.setenv("REPRO_CONV_VMEM_BUDGET", str(64 * 1024))
    narrow = dispatch.select_conv_strategy(256, 256, 8, 8, 3, mode="strip")
    assert narrow.strip_rows < wide.strip_rows
    # and a small budget flips the auto decision to strip
    assert dispatch.select_conv_strategy(32, 32, 8, 8, 3).kind == "strip"
    monkeypatch.setenv("REPRO_CONV_VMEM_BUDGET", "-3")
    with pytest.raises(ValueError, match="REPRO_CONV_VMEM_BUDGET"):
        dispatch.conv_vmem_budget()


# -- plan / report recording -------------------------------------------------

def test_vgg16_plan_records_mixed_strategies():
    """The Fig. 10 model compiles with per-layer strategies: early 224x224
    convs strip-mined, late 14x14 convs resident — all in plan AND report."""
    # params={} skips weight init: the plan (and this test) only needs the IR
    exe = vision_program("vgg16", params={}).compile(repro.Options(scheme=W4A4))
    plan = exe.plan
    conv_steps = {s.name: s for s in plan.steps
                  if isinstance(s, plan_mod.ConvStep)}
    assert conv_steps["conv1"].strategy.kind == "strip"
    assert conv_steps["conv13"].strategy.kind == "resident"
    kinds = {k: v.strategy.kind for k, v in conv_steps.items()}
    assert "strip" in kinds.values() and "resident" in kinds.values()
    # the power report carries the same record (serving surfaces print it)
    assert plan.report.conv_strategy == {
        k: dataclasses.asdict(v.strategy) for k, v in conv_steps.items()}


def test_plan_cache_keys_on_strategy_env(monkeypatch):
    prog = repro.Program((ConvSpec("c", 1, 4, kernel=3),), {}, (16, 16, 1))
    opts = repro.Options(scheme=W4A4)
    monkeypatch.delenv("REPRO_CONV_STRATEGY", raising=False)
    p_auto = prog.compile(opts).plan
    assert p_auto.steps[0].strategy.kind == "resident"
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "strip")
    p_strip = prog.compile(opts).plan
    assert p_strip is not p_auto            # env is part of the cache key
    assert p_strip.steps[0].strategy.kind == "strip"
    # an explicit Options strategy beats the env and keys the cache the
    # same way the equivalent env setting does
    assert prog.compile(repro.Options(
        scheme=W4A4, conv_strategy="strip")).plan is p_strip
    monkeypatch.delenv("REPRO_CONV_STRATEGY")
    assert prog.compile(opts).plan is p_auto


def test_eager_report_matches_compiled_under_forced_strip(monkeypatch):
    """Report equality with run_eager holds for every strategy env."""
    from repro.core.accelerator import LightatorDevice
    from repro.models.vision import lenet_ir, init_vision
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "strip")
    layers = lenet_ir()
    params = init_vision(jax.random.PRNGKey(0), layers)
    img = jax.random.uniform(jax.random.PRNGKey(1), (2, 28, 28, 1))
    dev = LightatorDevice()
    logits_e, report_e = dev.run_eager(layers, params, img, W4A4)
    logits_c, report_c = dev.run(layers, params, img, W4A4)
    assert all(v["kind"] == "strip" for v in report_c.conv_strategy.values())
    np.testing.assert_array_equal(np.asarray(logits_e), np.asarray(logits_c))
    assert dataclasses.asdict(report_e) == dataclasses.asdict(report_c)


# -- end-to-end bit-identity on large frames ---------------------------------

def test_conv_int_auto_strips_256_frame_bit_identical():
    """dispatch.conv_int at 256x256: auto picks strip under a tight budget,
    and the pallas strip path equals the reference backend exactly."""
    codes = jnp.round(jax.random.uniform(jax.random.PRNGKey(0),
                                         (1, 256, 256, 2)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(1),
                                      (3, 3, 2, 8)) * 14) - 7
    pads = ((1, 1), (1, 1))
    strat = dispatch.select_conv_strategy(256, 256, 2, 8, 3)
    assert strat.kind == "strip"
    with dispatch.use_backend("reference"):
        ref = dispatch.conv_int(codes, wq, 1, pads)
    with dispatch.use_backend("pallas"):
        pal = dispatch.conv_int(codes, wq, 1, pads)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_strip_plan_execute_large_frame_matches_reference_backend():
    """A compiled single-conv plan on a 256x256 frame: executing through the
    pallas strip kernels returns bit-identical output to the reference."""
    layers = (ConvSpec("edge", 2, 4, kernel=3, act="abs"),)
    frames = jax.random.uniform(jax.random.PRNGKey(2), (1, 256, 256, 2))
    params = {"edge": {"w": jax.random.normal(jax.random.PRNGKey(3),
                                              (3, 3, 2, 4)) * 0.2}}
    prog = repro.Program(layers, params, (256, 256, 2))
    ref_exe = prog.compile(repro.Options(scheme=W4A4, backend="reference"))
    pal_exe = prog.compile(repro.Options(scheme=W4A4, backend="pallas"))
    assert ref_exe.plan is pal_exe.plan     # backend is not a compile key
    assert ref_exe.plan.steps[0].strategy.kind == "strip"
    np.testing.assert_array_equal(np.asarray(ref_exe.run(frames)),
                                  np.asarray(pal_exe.run(frames)))


def test_strided_valid_exact_tiling_no_crash():
    """Strided VALID conv with surplus input rows and strips tiling h_out
    exactly: the row-padding helper must not go negative (regression — this
    crashed jnp.pad before pad_rows_for_strips clamped it)."""
    codes = jnp.round(jax.random.uniform(jax.random.PRNGKey(6),
                                         (1, 34, 34, 2)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(7),
                                      (3, 3, 2, 4)) * 14) - 7
    pads = ((0, 0), (0, 0))                   # VALID, stride 2: h_out = 16
    strat = dispatch.ConvStrategy("strip", strip_rows=16, n_strips=1)
    with dispatch.use_backend("reference"):
        ref = dispatch.conv_int(codes, wq, 2, pads)
    with dispatch.use_backend("pallas"):
        pal = dispatch.conv_int(codes, wq, 2, pads, strategy=strat)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_channel_multiplier_depthwise_strip_matches_reference():
    """Depthwise with a channel multiplier (c_out = 2*groups): not the VPU
    depthwise kernel's shape — must route through the per-group strip loop
    (regression: this crashed the depthwise branch before the c_out guard)."""
    codes = jnp.round(jax.random.uniform(jax.random.PRNGKey(8),
                                         (1, 16, 16, 3)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(9),
                                      (3, 3, 1, 6)) * 14) - 7
    pads = ((1, 1), (1, 1))
    with dispatch.use_backend("reference"):
        ref = dispatch.conv_int(codes, wq, 1, pads, groups=3)
    with dispatch.use_backend("pallas"):
        pal = dispatch.conv_int(codes, wq, 1, pads, groups=3)  # auto: strip
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_grouped_strip_matches_reference():
    """General grouped conv (1 < cg < c_in) through the per-group strip path."""
    codes = jnp.round(jax.random.uniform(jax.random.PRNGKey(4),
                                         (1, 20, 20, 4)) * 15)
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(5),
                                      (3, 3, 2, 6)) * 14) - 7
    pads = ((1, 1), (1, 1))
    strat = dispatch.select_conv_strategy(20, 20, 4, 6, 3, groups=2,
                                          mode="strip")
    with dispatch.use_backend("reference"):
        ref = dispatch.conv_int(codes, wq, 1, pads, groups=2)
    with dispatch.use_backend("pallas"):
        pal = dispatch.conv_int(codes, wq, 1, pads, groups=2,
                                strategy=strat)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
