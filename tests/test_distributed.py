"""Sharding rules + elastic remesh tests (divisibility over all archs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_configs, smoke_variant
from repro.distributed.sharding import base_rules, spec_for, use_rules
from repro.launch import shardings as sh
from repro.launch import specs as specs_mod

MESH_SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MESH_MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("mesh", [MESH_SINGLE, MESH_MULTI],
                         ids=["16x16", "2x16x16"])
def test_param_shardings_divide(arch, mesh):
    """Every param leaf's spec must evenly divide its dims (pjit contract)."""
    cfg = get_config(arch)
    rules = sh.build_rules(cfg, mesh)
    params = specs_mod.params_shape(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for pathkeys, leaf in flat:
        path = "/".join(str(getattr(p, "key", "")) for p in pathkeys)
        spec = sh.param_spec(path, leaf.ndim, cfg, rules)
        spec = sh._sanitize(spec, leaf.shape, mesh)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[i] % size == 0, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["yi-34b", "kimi-k2-1t-a32b", "grok-1-314b"])
def test_big_arch_params_fit_hbm(arch):
    """Sharded param bytes per chip must fit v5e HBM (16 GiB) with headroom
    for activations; checked analytically from specs."""
    cfg = get_config(arch)
    mesh = MESH_SINGLE
    rules = sh.build_rules(cfg, mesh)
    params = specs_mod.params_shape(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    per_device = 0
    for pathkeys, leaf in flat:
        path = "/".join(str(getattr(p, "key", "")) for p in pathkeys)
        spec = sh._sanitize(sh.param_spec(path, leaf.ndim, cfg, rules),
                            leaf.shape, mesh)
        shards = 1
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            shards *= int(np.prod([mesh.shape[a] for a in axes]))
        per_device += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // shards
    assert per_device < 16 * 2**30, f"{arch}: {per_device/2**30:.1f} GiB"


def test_moe_expert_sharding_strategy():
    mesh = MESH_SINGLE
    kimi = sh.build_rules(get_config("kimi-k2-1t-a32b"), mesh)
    grok = sh.build_rules(get_config("grok-1-314b"), mesh)
    assert kimi["experts"] == ("model",)      # 384 experts -> EP
    assert kimi["moe_ffn"] is None
    assert grok["experts"] is None            # 8 experts -> shard ffn instead
    assert grok["moe_ffn"] == ("model",)


def test_spec_for_and_rules():
    rules = base_rules(multi_pod=True, fsdp=True)
    assert spec_for("batch", None, "heads", rules=rules) == \
        P(("pod", "data"), None, "model")
    assert spec_for("batch", rules=base_rules()) == P("data")


def test_shard_noop_outside_mesh():
    from repro.distributed.sharding import shard
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(shard(x, "batch", None)),
                                  np.asarray(x))


def test_elastic_remesh_single_device(tmp_path):
    """Save params, restore them onto a different (1x1) mesh sharding."""
    from repro.checkpoint.ckpt import save_checkpoint
    from repro.distributed.elastic import elastic_remesh
    cfg = smoke_variant("smollm-360m")
    from repro.models import lm as lm_mod
    params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
    save_checkpoint(tmp_path, 42, {"params": params})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    p_shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           params)
    step, restored, _ = elastic_remesh(tmp_path, cfg, mesh, p_shape)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cell_status_skips():
    cfg = get_config("hubert-xlarge")
    assert specs_mod.cell_status("hubert-xlarge", "decode_32k", cfg)
    assert specs_mod.cell_status("hubert-xlarge", "train_4k", cfg) is None
    yi = get_config("yi-34b")
    assert specs_mod.cell_status("yi-34b", "long_500k", yi)
    mam = get_config("mamba2-1.3b")
    assert specs_mod.cell_status("mamba2-1.3b", "long_500k", mam) is None
