"""The unified Program / Options / Executable front door (core.program).

Contracts under test:

* the deprecated shims (``plan.compile_model`` / ``plan.execute`` /
  ``LightatorDevice.run``) stay **bit-identical** to the new API and warn
  exactly once, naming the replacement;
* ``Options`` participates in the plan cache key through its *resolved*
  values: env-default and explicit-equivalent options hit the same cached
  plan, different strategies key fresh plans, and flipping the backend
  between runs re-traces the executor without recompiling the plan;
* ``Program.then`` fuses two programs into ONE compiled plan whose
  quantized output tracks the float reference of the composed IR;
* ``shard_batch`` is a graceful no-op on one device and bit-identical to
  the unsharded path on many (subprocess with forced host devices).
"""

import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import plan as plan_mod
from repro.core.accelerator import LightatorDevice
from repro.core.program import Options, Program, infer_output_hwc
from repro.core.quant import W4A4, MX_43
from repro.imaging import PIPELINES, apply_float, psnr
from repro.kernels import dispatch
from repro.models.vision import lenet_ir, init_vision, vision_program


@pytest.fixture(scope="module")
def lenet():
    layers = tuple(lenet_ir())
    params = init_vision(jax.random.PRNGKey(0), layers)
    img = jax.random.uniform(jax.random.PRNGKey(1), (2, 28, 28, 1))
    return layers, params, img


@pytest.fixture(scope="module")
def frames():
    from repro.data.synthetic import synthetic_textures
    imgs, _ = synthetic_textures(2, hw=32, seed=0)
    return jnp.asarray(imgs)


# -- shims are bit-identical to the new API ----------------------------------

def test_shims_bit_identical_on_lenet(lenet):
    layers, params, img = lenet
    new = Program(layers, params, (28, 28, 1), name="lenet").compile(
        Options(scheme=W4A4)).run(img)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        plan = plan_mod.compile_model(layers, img.shape, W4A4)
        old_fn = plan_mod.execute(plan, params, img)
        old_dev, _ = LightatorDevice().run(layers, params, img, W4A4)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old_fn))
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old_dev))


@pytest.mark.parametrize("name", ["edge_detect", "compress_recon"])
def test_shims_bit_identical_on_imaging(frames, name):
    prog = PIPELINES[name].program(32, 32, 3)
    new = prog.compile(Options(scheme=W4A4)).run(frames)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        plan = plan_mod.compile_model(prog.layers, frames.shape, W4A4)
        old = plan_mod.execute(plan, prog.params, frames)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


def test_shims_warn_once_naming_replacement(lenet):
    layers, params, img = lenet
    plan_mod._DEPRECATION_WARNED.clear()
    with pytest.warns(DeprecationWarning, match="repro.Program"):
        plan = plan_mod.compile_model(layers, img.shape, W4A4)
    with pytest.warns(DeprecationWarning, match="run\\(frames\\)"):
        plan_mod.execute(plan, params, img)
    with pytest.warns(DeprecationWarning, match="repro.Program"):
        LightatorDevice().run(layers, params, img, W4A4)
    # one-shot: a second round is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        plan_mod.compile_model(layers, img.shape, W4A4)
        plan_mod.execute(plan, params, img)
        LightatorDevice().run(layers, params, img, W4A4)


# -- Options -----------------------------------------------------------------

def test_options_validation():
    with pytest.raises(ValueError, match="backend"):
        Options(backend="bogus")
    with pytest.raises(ValueError, match="conv strategy"):
        Options(conv_strategy="bogus")
    with pytest.raises(ValueError, match="fc_batch"):
        Options(fc_batch=0)
    with pytest.raises(ValueError, match="conv_vmem_budget"):
        Options(conv_vmem_budget=-1)


def test_options_resolve_env_defaults(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_CONV_STRATEGY", raising=False)
    r = Options().resolve()
    assert r.backend == dispatch.get_backend()
    assert r.conv_strategy == "auto"
    assert r.conv_vmem_budget == dispatch.conv_vmem_budget()
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "strip")
    r = Options().resolve()
    assert (r.backend, r.conv_strategy) == ("pallas", "strip")
    # explicit fields survive resolution untouched
    r = Options(backend="reference", conv_strategy="resident").resolve()
    assert (r.backend, r.conv_strategy) == ("reference", "resident")
    assert "backend=reference" in Options(backend="reference").describe()


def test_options_are_part_of_the_plan_cache_key(lenet):
    layers, params, _ = lenet
    prog = Program(layers, params, (28, 28, 1))
    base = prog.compile(Options(scheme=W4A4)).plan
    # different scheme / fc_batch / strategy / budget -> fresh plans
    assert prog.compile(Options(scheme=MX_43)).plan is not base
    assert prog.compile(Options(scheme=W4A4, fc_batch=8)).plan is not base
    assert prog.compile(Options(
        scheme=W4A4, conv_strategy="strip")).plan is not base
    assert prog.compile(Options(
        scheme=W4A4, conv_vmem_budget=1 << 16)).plan is not base
    # backend / interpret / sharding are run-time knobs, not compile keys
    assert prog.compile(Options(scheme=W4A4, backend="pallas")).plan is base
    assert prog.compile(Options(scheme=W4A4, interpret=True)).plan is base
    assert prog.compile(Options(scheme=W4A4, shard_batch=True)).plan is base


def test_env_default_and_explicit_equivalent_share_a_plan(lenet, monkeypatch):
    """Options(None) resolved from env == the same values passed explicitly:
    both must hit the SAME cached plan (resolved values key the cache)."""
    layers, params, _ = lenet
    prog = Program(layers, params, (28, 28, 1))
    monkeypatch.delenv("REPRO_CONV_STRATEGY", raising=False)
    monkeypatch.delenv("REPRO_CONV_VMEM_BUDGET", raising=False)
    p_env = prog.compile(Options(scheme=W4A4)).plan
    p_explicit = prog.compile(Options(
        scheme=W4A4, conv_strategy="auto",
        conv_vmem_budget=dispatch.DEFAULT_CONV_VMEM_BUDGET)).plan
    assert p_explicit is p_env
    # and with the env set, Options(None) follows it to the explicit twin
    monkeypatch.setenv("REPRO_CONV_STRATEGY", "strip")
    p_env_strip = prog.compile(Options(scheme=W4A4)).plan
    p_exp_strip = prog.compile(Options(scheme=W4A4,
                                       conv_strategy="strip")).plan
    assert p_env_strip is p_exp_strip
    assert p_env_strip is not p_env


def test_backend_flip_gets_a_fresh_jitted_executor(lenet):
    """Regression for the ``executor()`` keying: two Executables over the
    same plan with different backends must not share a trace — and their
    logits agree exactly (integer-exact MACs on every backend)."""
    layers, params, img = lenet
    prog = Program(layers, params, (28, 28, 1))
    e_ref = prog.compile(Options(scheme=W4A4, backend="reference"))
    e_pal = prog.compile(Options(scheme=W4A4, backend="pallas"))
    assert e_ref.plan is e_pal.plan
    out_ref = e_ref.run(img)
    with dispatch.use_backend("reference"):
        f_ref = e_ref.plan.executor()
    out_pal = e_pal.run(img)
    with dispatch.use_backend("pallas"):
        f_pal = e_pal.plan.executor()
    assert f_ref is not f_pal
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pal))


# -- Program construction + composition --------------------------------------

def test_program_constructors():
    prog = vision_program("lenet")
    assert prog.input_hwc == (28, 28, 1) and prog.name == "lenet"
    assert prog.output_hwc == (1, 1, 10)
    assert Program.from_model("lenet").input_hwc == (28, 28, 1)
    pipe = Program.from_pipeline("edge_detect", 32, 32, 3)
    assert pipe.output_hwc == (32, 32, 1)
    with pytest.raises(ValueError, match="schedule-only"):
        vision_program("alexnet")
    with pytest.raises(ValueError, match="unknown pipeline"):
        Program.from_pipeline("bogus", 32, 32)
    with pytest.raises(ValueError, match="input_hwc"):
        Program((), {}, (32, 32))


def test_infer_output_hwc_matches_compiled_shapes(frames):
    """infer_output_hwc must stay in lockstep with the compile pass's own
    shape walk (it is a scheduling-free copy of the same arithmetic)."""
    for name in ("edge_detect", "denoise_box", "compress_recon",
                 "compress_recon_deconv", "sharpen"):
        prog = PIPELINES[name].program(32, 32, 3)
        out = prog.compile(Options(scheme=W4A4)).run(frames)
        assert tuple(out.shape[1:]) == infer_output_hwc(prog.layers,
                                                        prog.input_hwc)
    # vision models: the plan's own out_features vs the inferred channel dim
    for model in ("lenet", "vgg9", "vgg16"):
        prog = vision_program(model, params={})
        plan = prog.compile(Options(scheme=W4A4)).plan
        assert infer_output_hwc(prog.layers, prog.input_hwc) == \
            (1, 1, plan.out_features)


def test_then_rejects_shape_mismatch():
    den = Program.from_pipeline("denoise_box", 32, 32, 3)
    edge16 = Program.from_pipeline("edge_detect", 16, 16, 3)
    with pytest.raises(ValueError, match="cannot chain"):
        den.then(edge16)


def test_then_chain_compiles_as_one_plan(frames):
    """Acceptance: denoise -> edge chains into a single CompiledPlan, runs
    batch-first, and the quantized output tracks the float reference of the
    composed IR within the existing per-pipeline PSNR floors."""
    chain = (Program.from_pipeline("denoise_box", 32, 32, 3)
             .then(Program.from_pipeline("edge_detect", 32, 32, 3)))
    assert chain.name == "denoise_box>edge_detect"
    exe = chain.compile(Options(scheme=W4A4))
    assert isinstance(exe.plan, plan_mod.CompiledPlan)
    # one plan holds BOTH stages' schedules (box dw conv + CA + grad + mag)
    assert len(exe.plan.schedules) == 4
    out = exe.run(frames)
    assert out.shape == (frames.shape[0], 32, 32, 1)     # batch-first
    ref = apply_float(chain.layers, chain.params, frames)
    p = float(psnr(ref, out))
    floor = 20.0          # the edge_detect floor (test_imaging.PSNR_FLOORS)
    assert p > floor, f"chain PSNR {p:.2f} dB under floor {floor}"
    # float composition of the two stages == float of the fused program
    den = Program.from_pipeline("denoise_box", 32, 32, 3)
    edge = Program.from_pipeline("edge_detect", 32, 32, 3)
    staged = apply_float(edge.layers, edge.params,
                         apply_float(den.layers, den.params, frames))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(staged),
                               rtol=1e-5, atol=1e-6)


def test_then_three_stage_chain_runs(frames):
    """compress -> recon -> sharpen: a recon pipeline feeding a filter."""
    chain = (Program.from_pipeline("compress_recon", 32, 32, 3)
             .then(Program.from_pipeline("sharpen", 32, 32, 1)))
    out = chain.compile(Options(scheme=W4A4)).run(frames)
    assert out.shape == (frames.shape[0], 32, 32, 1)
    ref = apply_float(chain.layers, chain.params, frames)
    assert float(psnr(ref, out)) > 10.0   # sharpen-family floor


def test_then_renames_colliding_layers(frames):
    """Chaining two instances of the same pipeline suffixes the repeated
    layer names in IR and params consistently."""
    e3 = Program.from_pipeline("edge_detect", 32, 32, 3)
    e1 = Program.from_pipeline("edge_detect", 32, 32, 1)
    twice = e3.then(e1)
    names = [l.name for l in twice.layers if hasattr(l, "name")]
    assert names == ["grad", "edge_mag", "grad.2", "edge_mag.2"]
    assert set(names) <= set(twice.params)
    out = twice.compile(Options(scheme=W4A4)).run(frames)
    assert out.shape == (frames.shape[0], 32, 32, 1)


def test_report_mutation_does_not_corrupt_shared_plan(lenet):
    """Executable.report is a private copy: the plan is shared through the
    global cache, so caller mutations must stay local."""
    layers, params, _ = lenet
    prog = Program(layers, params, (28, 28, 1))
    e1 = prog.compile(Options(scheme=W4A4))
    e2 = prog.compile(Options(scheme=W4A4))
    assert e1.plan is e2.plan
    true_fps = e1.plan.report.fps
    e1.report.fps = -1.0
    assert e1.report.fps == -1.0            # the copy sticks per Executable
    assert e2.report.fps == true_fps        # ...without leaking across
    assert e1.plan.report.fps == true_fps   # ...or into the cached plan


# -- batch sharding ----------------------------------------------------------

def test_shard_batch_noop_on_single_device(lenet):
    """On one device (or a non-dividing batch) sharding must change nothing
    — same logits, same code path."""
    layers, params, img = lenet
    prog = Program(layers, params, (28, 28, 1))
    base = prog.compile(Options(scheme=W4A4)).run(img)
    sharded = prog.compile(Options(scheme=W4A4, shard_batch=True)).run(img)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(sharded))


_SHARD_SCRIPT = """
import jax, numpy as np
import repro
from repro.core.quant import W4A4
assert len(jax.local_devices()) == 4, jax.local_devices()
prog = repro.Program.from_model("lenet")
frames = jax.random.uniform(jax.random.PRNGKey(1), (8, 28, 28, 1))
base = prog.compile(repro.Options(scheme=W4A4)).run(frames)
exe = prog.compile(repro.Options(scheme=W4A4, shard_batch=True))
out = exe.run(frames)
assert "batch" in str(out.sharding), out.sharding
np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
# ragged batch (5 % 4 != 0): graceful no-op, still correct
np.testing.assert_array_equal(
    np.asarray(exe.run(frames[:5])),
    np.asarray(prog.compile(repro.Options(scheme=W4A4)).run(frames[:5])))
# an explicit mesh with a caller-chosen axis name shards too
mesh = jax.sharding.Mesh(np.asarray(jax.local_devices()), ("data",))
out = prog.compile(repro.Options(scheme=W4A4, shard_batch=True,
                                 mesh=mesh)).run(frames)
assert "data" in str(out.sharding), out.sharding
np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
print("SHARD_OK")
"""


def test_shard_batch_multi_device_bit_identical():
    """ROADMAP item: the batch axis shards over a mesh via NamedSharding.
    Forced 4-way host platform in a subprocess (device count is fixed at
    jax init); sharded logits must equal the single-device ones exactly."""
    import os
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=4"),
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    res = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                         cwd=Path(__file__).resolve().parent.parent,
                         env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "SHARD_OK" in res.stdout
